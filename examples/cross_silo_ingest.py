"""Cross-silo, party-first ingestion: misaligned regional extracts -> align
-> fit -> serve, with the paper's losslessness guarantee intact end to end.

Three regional silos — a bank, an e-commerce company, and a telco — each
hold their own feature columns for their own customer base.  The customer
sets overlap but don't coincide, every extract is shuffled, and only the
bank holds labels.  Nothing here starts from a centrally pre-aligned
matrix: each silo ships a ``PartyBlock`` (here round-tripped through
per-party CSV files via ``CSVSource``, the DataSource hook), the Federation
session aligns them on hashed IDs (paper §4.3), bins each block
party-locally, trains, and then serves per-party *request* blocks whose
rows arrive out of order and superset — re-aligned before dispatch.

Run:  PYTHONPATH=src python examples/cross_silo_ingest.py
"""
import os
import tempfile

import numpy as np

from repro.core import ForestParams, PartyBlock, crypto
from repro.core.partyblock import CSVSource
from repro.data import make_classification, make_party_views
from repro.data.metrics import accuracy
from repro.federation import Federation
from repro.serving import ServeConfig


def main() -> None:
    # --- three silos with partially-overlapping customers -----------------
    x, y = make_classification(3000, 30, 2, n_informative=10, seed=0)
    blocks, x_aligned, y_aligned = make_party_views(
        x, y, n_parties=3, overlap=0.8, seed=0)
    silos = ("bank", "ecom", "telco")
    blocks = [PartyBlock(name=s, x=b.x, ids=b.ids, y=b.y,
                         feature_ids=b.feature_ids)
              for s, b in zip(silos, blocks)]
    for b in blocks:
        print(f"{b.name:6s}: {b.n_samples} customers x {b.n_features} "
              f"features" + ("  [labels]" if b.y is not None else ""))

    # --- each silo dumps a CSV; ingestion loads through the DataSource ----
    with tempfile.TemporaryDirectory() as d:
        sources = [CSVSource(b.to_csv(os.path.join(d, f"{b.name}.csv")),
                             name=b.name) for b in blocks]
        fed = Federation(parties=3, n_bins=32)
        part = fed.ingest(sources, validate=True)   # align + party-local bin
    print(f"aligned {part.n_samples} common customers across "
          f"{part.n_parties} silos (hashed-ID intersection)")

    model = fed.fit(ForestParams(n_estimators=12, max_depth=6, n_bins=32,
                                 seed=42))
    acc = accuracy(fed.labels_, fed.predict(model, part.dense_raw()))
    print(f"federated forest: train acc={acc:.3f}")

    # --- losslessness: the centrally pre-aligned build is bit-identical ---
    fed_c = Federation(parties=3, n_bins=32)
    fed_c.ingest(x_aligned, y_aligned)
    central = fed_c.fit(ForestParams(n_estimators=12, max_depth=6, n_bins=32,
                                     seed=42))
    same = np.array_equal(fed.predict(model, x_aligned),
                          fed_c.predict(central, x_aligned))
    print(f"party-first ingest == centrally pre-aligned: {same}")
    assert same, "losslessness violated"

    # --- serving: per-party request blocks, out-of-order + superset -------
    server = fed.serve(model, ServeConfig(buckets=(256,)))
    xt, _ = make_classification(200, 30, 2, seed=7)
    qids = np.array([f"q{i:04d}" for i in range(len(xt))])
    rng = np.random.default_rng(1)
    req = []
    for i, name in enumerate(part.party_names):
        gid = part.feat_gid[i][part.feat_gid[i] >= 0]
        rows = rng.permutation(len(xt))             # silo-local row order
        extra = rng.normal(size=(17, len(gid)))     # rows only it holds
        req.append(PartyBlock(
            name=name,
            x=np.concatenate([xt[rows][:, gid], extra]),
            ids=np.concatenate([qids[rows],
                                [f"{name}-only-{j}" for j in range(17)]])))
    ids, preds = server.serve_parties(req)
    order = np.argsort(crypto.hash_ids(qids))
    assert np.array_equal(ids, qids[order])
    assert np.array_equal(preds, model.predict(xt[order])), \
        "served outputs diverge from the fitted model"
    print(f"served {len(preds)} rows from misaligned request blocks "
          f"(dropped {len(req[0].ids) - len(preds)} non-common rows/party)")


if __name__ == "__main__":
    main()
