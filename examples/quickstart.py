"""Quickstart: two organizations jointly train a Federated Forest.

A bank (11 features) and an e-commerce company (84 features) — the paper's
target-marketing scenario — share customers but cannot pool raw data.
They align hashed IDs, join a Federation session, train a forest where no
raw feature ever leaves its owner, and predict with ONE round of
communication for the whole forest.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ForestParams, crypto
from repro.data import make_classification
from repro.data.metrics import accuracy, f1_binary
from repro.data.tabular import train_test_split
from repro.federation import Federation


def main() -> None:
    # --- two data islands with a shared customer base --------------------
    x, y = make_classification(8000, 95, 2, n_informative=24, seed=0)
    bank_cols = np.arange(0, 11)          # 11 features at the bank
    ecom_cols = np.arange(11, 95)         # 84 features at the e-commerce co.
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=1)

    # --- private ID alignment (paper §4.3: hashed IDs only) --------------
    ids = np.arange(len(xtr))
    bank_ids = crypto.hash_ids(ids, salt="2026-07")
    ecom_ids = crypto.hash_ids(ids, salt="2026-07")
    ia, ib = crypto.align_ids(bank_ids, ecom_ids)
    print(f"aligned {len(ia)} customers via hashed IDs")

    # --- the federation session: ingest -> fit -> predict ----------------
    params = ForestParams(task="classification", n_estimators=20, max_depth=8,
                          n_bins=32, seed=42)
    fed = Federation(parties=2, n_bins=params.n_bins)
    fed.ingest(xtr, ytr)                  # vertical partition across M=2
    model = fed.fit(params)

    pred = fed.predict(model, xte)        # ONE collective for the forest
    print(f"federated forest:  acc={accuracy(yte, pred):.3f}  "
          f"f1={f1_binary(yte, pred):.3f}")

    # --- what each party could do alone (paper's RF1/RF2) ----------------
    for name, cols in (("bank alone", bank_cols), ("e-com alone", ecom_cols)):
        solo_fed = Federation(parties=1, n_bins=params.n_bins)
        solo_fed.ingest(xtr[:, cols], ytr)
        solo = solo_fed.fit(params)
        print(f"{name:12s}:  acc="
              f"{accuracy(yte, solo_fed.predict(solo, xte[:, cols])):.3f}")

    # --- the losslessness guarantee --------------------------------------
    central_fed = Federation(parties=1, n_bins=params.n_bins)
    central_fed.ingest(xtr, ytr)
    central = central_fed.fit(params)
    same = np.array_equal(central_fed.predict(central, xte), pred)
    print(f"centralized forest == federated forest: {same}")
    assert same, "losslessness violated"


if __name__ == "__main__":
    main()
