"""Quickstart: two organizations jointly train a Federated Forest.

A bank (11 features) and an e-commerce company (84 features) — the paper's
target-marketing scenario — share customers but cannot pool raw data.
They align hashed IDs, train a forest where no raw feature ever leaves its
owner, and predict with ONE round of communication for the whole forest.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ForestParams, FederatedForest, crypto, party
from repro.data import make_classification
from repro.data.metrics import accuracy, f1_binary
from repro.data.tabular import train_test_split


def main() -> None:
    # --- two data islands with a shared customer base --------------------
    x, y = make_classification(8000, 95, 2, n_informative=24, seed=0)
    bank_cols = np.arange(0, 11)          # 11 features at the bank
    ecom_cols = np.arange(11, 95)         # 84 features at the e-commerce co.
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=1)

    # --- private ID alignment (paper §4.3: hashed IDs only) --------------
    ids = np.arange(len(xtr))
    bank_ids = crypto.hash_ids(ids, salt="2026-07")
    ecom_ids = crypto.hash_ids(ids, salt="2026-07")
    ia, ib = crypto.align_ids(bank_ids, ecom_ids)
    print(f"aligned {len(ia)} customers via hashed IDs")

    # --- vertical partition + federated training -------------------------
    params = ForestParams(task="classification", n_estimators=20, max_depth=8,
                          n_bins=32, seed=42)
    partition = party.make_vertical_partition(xtr, 2, params.n_bins)
    ff = FederatedForest(params).fit(partition, ytr)

    pred = ff.predict(xte)                # ONE collective for the forest
    print(f"federated forest:  acc={accuracy(yte, pred):.3f}  "
          f"f1={f1_binary(yte, pred):.3f}")

    # --- what each party could do alone (paper's RF1/RF2) ----------------
    from repro.core import fit_federated_forest
    for name, cols in (("bank alone", bank_cols), ("e-com alone", ecom_cols)):
        solo = fit_federated_forest(xtr[:, cols], ytr, 1, params)
        print(f"{name:12s}:  acc={accuracy(yte, solo.predict(xte[:, cols])):.3f}")

    # --- the losslessness guarantee --------------------------------------
    central = fit_federated_forest(xtr, ytr, 1, params)
    same = np.array_equal(central.predict(xte), pred)
    print(f"centralized forest == federated forest: {same}")


if __name__ == "__main__":
    main()
