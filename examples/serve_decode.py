"""Serving example: batched prefill + autoregressive decode with KV cache.

Demonstrates the serve path the decode_32k / long_500k dry-runs lower:
prefill a batch of prompts, then decode tokens one at a time against the
ring-buffer cache — including a sliding-window variant (the long_500k
sub-quadratic configuration).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import reduced
from repro.models import transformer


def decode_n(cfg, params, prompts, n_new: int, cache_len: int):
    b, s = prompts.shape
    logits, cache = jax.jit(
        lambda p, t: transformer.prefill(p, t, cfg, {}, cache_len=cache_len)
    )(params, prompts)

    step = jax.jit(lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg))
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    for i in range(n_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    rng = np.random.default_rng(0)
    for arch, window in (("qwen3-32b", None), ("qwen3-32b", 64),
                         ("xlstm-350m", None)):
        cfg = reduced(registry.get(arch))
        if window:
            cfg = cfg.with_(sliding_window=window)
        params = transformer.init_params(jax.random.key(1), cfg)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)))
        t0 = time.perf_counter()
        toks = decode_n(cfg, params, prompts, n_new=16, cache_len=128)
        dt = time.perf_counter() - t0
        kind = f"SWA w={window}" if window else (
            "recurrent state" if cfg.is_subquadratic else "full KV cache")
        print(f"{arch:12s} [{kind:15s}] decoded {toks.shape} in {dt:.2f}s; "
              f"finite={bool(jnp.isfinite(toks).all())}")


if __name__ == "__main__":
    main()
