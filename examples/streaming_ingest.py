"""Streaming out-of-core ingestion: chunked silo CSVs -> sketch-binned
ingest -> bit-identity vs the in-memory build -> append -> refit.

Three silos publish wide CSV extracts.  Instead of loading each file whole
(``PartyBlock.from_csv`` materializes every row before parsing), the
session streams them in bounded chunks (``ChunkedCSVSource``): a first
pass hashes IDs and feeds mergeable quantile sketches, a second pass bins
each chunk against the sketch-derived grid — raw features are never held
densely.

Two regimes, both demonstrated under a ``tracemalloc`` peak-memory
assertion (the CI smoke gate):

  * **exact** — ``sketch_capacity >= n`` keeps the sketches
    compaction-free, so the streamed partition is BIT-IDENTICAL to the
    in-memory build (the paper's losslessness guarantee, asserted), while
    the peak stays well under the whole-file load's;
  * **bounded** — the default capacity compacts: memory drops to
    O(chunk + capacity·log n) for the feature plane and every bin edge is
    within the sketch's *tracked* rank-error bound (asserted).

Finally the silos publish versioned v2 extracts (``DataProduct``):
``ingest_append`` lands the new rows without re-scanning the old sources
and a refit equals a from-scratch fit of the union exactly.

Run:  PYTHONPATH=src python examples/streaming_ingest.py
"""
import os
import tempfile
import tracemalloc

import numpy as np

from repro.core import ForestParams, PartyBlock, partition_from_blocks
from repro.data import make_classification
from repro.federation import Federation
from repro.streaming import ArraySource, ChunkedCSVSource, DataProduct, \
    ProductSchema

N, F_PER_SILO, N_BINS = 8000, 64, 16
SILOS = ("bank", "ecom", "telco")


def _make_silos(n, seed, id_prefix="cust"):
    x, y = make_classification(n, F_PER_SILO * len(SILOS), 2,
                               n_informative=12, seed=seed)
    ids = np.array([f"{id_prefix}{i:07d}" for i in range(n)])
    rng, blocks = np.random.default_rng(seed), []
    for i, name in enumerate(SILOS):
        cols = np.arange(i * F_PER_SILO, (i + 1) * F_PER_SILO)
        order = rng.permutation(n)                 # silo-local row order
        blocks.append(PartyBlock(
            name=name, x=x[order][:, cols], ids=ids[order],
            y=y[order] if i == 0 else None, feature_ids=cols))
    return blocks


def _peak(fn):
    tracemalloc.start()
    out = fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, peak


def _trees_equal(a, b):
    import jax
    return all(np.array_equal(np.asarray(la), np.asarray(lb))
               for la, lb in zip(jax.tree_util.tree_leaves(a),
                                 jax.tree_util.tree_leaves(b)))


def main() -> None:
    blocks = _make_silos(N, seed=0)
    d = tempfile.mkdtemp()
    paths = [b.to_csv(os.path.join(d, f"{b.name}.csv")) for b in blocks]
    for b, p in zip(blocks, paths):
        print(f"{b.name:6s}: {b.n_samples} rows x {b.n_features} features, "
              f"{os.path.getsize(p) / 1e6:.1f}MB csv"
              + ("  [labels]" if b.y is not None else ""))

    # --- in-memory oracle: whole-file load + dense build ------------------
    (ref, ref_y, _), peak_inmem = _peak(
        lambda: partition_from_blocks([PartyBlock.from_csv(p) for p in paths],
                                      n_bins=N_BINS))

    # --- exact regime: lossless AND smaller-than-load ---------------------
    # capacity covers the v2 append below too: exactness holds as long as a
    # party's TOTAL streamed rows (across appends) stay within capacity
    fed = Federation(parties=len(SILOS), n_bins=N_BINS)
    _, peak_exact = _peak(
        lambda: fed.ingest([ChunkedCSVSource(p) for p in paths],
                           chunk_rows=500, sketch_capacity=N + N // 4))
    part = fed._partition
    assert np.array_equal(part.xb, ref.xb) \
        and np.array_equal(part.boundaries, ref.boundaries) \
        and np.array_equal(fed._y, ref_y), "losslessness violated"
    print(f"exact streamed ingest == in-memory build: True "
          f"(peak {peak_exact / 1e6:.1f}MB vs load {peak_inmem / 1e6:.1f}MB)")

    # --- bounded regime: default sketch capacity compacts -----------------
    fed_b = Federation(parties=len(SILOS), n_bins=N_BINS)
    _, peak_bounded = _peak(
        lambda: fed_b.ingest([ChunkedCSVSource(p) for p in paths],
                             chunk_rows=500))
    scans = [s.merged_scan() for s in fed_b._stream["streams"]]
    err = max(sc.sketches.err for sc in scans)
    agree = (fed_b._partition.xb == ref.xb).mean()
    print(f"bounded sketches: tracked rank error {err}/{N} rows "
          f"({100 * err / N:.3f}%), {100 * agree:.2f}% of binned values "
          f"unchanged (peak {peak_bounded / 1e6:.1f}MB)")
    assert 0 < err < 0.01 * N, "tracked rank-error bound out of range"

    # --- the CI memory gate: streaming must beat the whole-file load ------
    # raw features never sit densely in RAM: O(chunk) per pass plus the
    # sketch buffers (O(n) floats when exact-by-request, O(capacity log n)
    # when bounded) — the id/hash plane and the binned partition stay O(n)
    # by design on every path.
    assert peak_exact < 0.80 * peak_inmem, \
        f"exact streaming peak {peak_exact} not under load peak {peak_inmem}"
    assert peak_bounded < 0.60 * peak_inmem, \
        f"bounded streaming peak {peak_bounded} vs load peak {peak_inmem}"

    # --- v2 extracts land via ingest_append, refit == from-scratch -------
    new_blocks = _make_silos(N // 4, seed=1, id_prefix="new")
    fed.ingest_append([DataProduct(b.name, ArraySource(b),
                                   ProductSchema.of(b), version=2)
                       for b in new_blocks])
    union = [PartyBlock(name=a.name, x=np.concatenate([a.x, b.x]),
                        ids=np.concatenate([a.ids, b.ids]),
                        y=None if a.y is None else np.concatenate([a.y, b.y]),
                        feature_ids=a.feature_ids)
             for a, b in zip(blocks, new_blocks)]
    p = ForestParams(n_estimators=4, max_depth=4, n_bins=N_BINS, seed=42)
    fed_u = Federation(parties=len(SILOS), n_bins=N_BINS)
    fed_u.ingest(union)
    same = _trees_equal(fed.fit(p).trees_, fed_u.fit(p).trees_)
    print(f"append {N // 4} rows/silo + refit == from-scratch union fit: "
          f"{same}")
    assert same, "incremental refit diverged from the union build"


if __name__ == "__main__":
    main()
