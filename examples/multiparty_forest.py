"""Multi-party scenario (paper §5.3): adding domains one at a time.

Eight regional organizations each contribute a feature domain; every added
domain improves accuracy while prediction cost stays flat (the paper's
scale-free one-round predictor).  Also demonstrates regression mode and the
classical-prediction comparison.

The scaling/regression sections go through the Federation session API; the
prediction-protocol section deliberately stays on the legacy
``fit_federated_forest`` entrypoint to exercise the compatibility shims.

Run:  PYTHONPATH=src python examples/multiparty_forest.py
"""
import time

import numpy as np

from repro.core import ForestParams, fit_federated_forest
from repro.data import make_classification, make_regression
from repro.data.metrics import accuracy, rmse
from repro.data.tabular import train_test_split
from repro.federation import Federation


def classification_scaling() -> None:
    print("== classification: accuracy & time vs number of domains ==")
    x, y = make_classification(2000, 8 * 16, 2, n_informative=32, seed=5)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=2)
    p = ForestParams(n_estimators=12, max_depth=7, n_bins=16, seed=0)
    for m in (1, 2, 4, 8):
        f_use = m * 16
        fed = Federation(parties=m, n_bins=p.n_bins)
        fed.ingest(xtr[:, :f_use], ytr)
        t0 = time.perf_counter()
        model = fed.fit(p)
        t_tr = time.perf_counter() - t0
        t0 = time.perf_counter()
        acc = accuracy(yte, fed.predict(model, xte[:, :f_use]))
        t_pr = time.perf_counter() - t0
        print(f"  M={m}: acc={acc:.3f} train={t_tr:.2f}s predict={t_pr:.3f}s")


def regression_mode() -> None:
    print("== regression: federated vs centralized RMSE ==")
    x, y = make_regression(2000, 40, seed=9)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=3)
    p = ForestParams(task="regression", n_estimators=12, max_depth=7,
                     n_bins=32, seed=1)
    fed4, fed1 = Federation(parties=4), Federation(parties=1)
    fed4.ingest(xtr, ytr)
    fed1.ingest(xtr, ytr)
    fed_m, cen = fed4.fit(p), fed1.fit(p)
    pf, pc = fed4.predict(fed_m, xte), fed1.predict(cen, xte)
    print(f"  federated M=4: rmse={rmse(yte, pf):.4f}")
    print(f"  centralized : rmse={rmse(yte, pc):.4f}")
    print(f"  identical predictions: {np.allclose(pf, pc, atol=1e-5)}")


def prediction_protocols() -> None:
    """Legacy-path section: the pre-session constructors must keep working."""
    print("== one-round vs classical prediction (legacy entrypoint) ==")
    x, y = make_classification(3000, 30, 2, seed=11)
    xtr, ytr, xte, _ = train_test_split(x, y, 0.3, seed=4)
    p = ForestParams(n_estimators=16, max_depth=8, n_bins=16, seed=2)
    ff = fit_federated_forest(xtr, ytr, 5, p)
    t0 = time.perf_counter(); a = ff.predict(xte); t1 = time.perf_counter()
    b = ff.predict_classical(xte); t2 = time.perf_counter()
    print(f"  one-round : {t1 - t0:.3f}s (1 collective for the forest)")
    print(f"  classical : {t2 - t1:.3f}s "
          f"({p.n_estimators * p.max_depth} collectives)")
    print(f"  agree: {np.array_equal(a, b)}")


if __name__ == "__main__":
    classification_scaling()
    regression_mode()
    prediction_protocols()
