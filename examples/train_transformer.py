"""End-to-end driver: train a ~100M-param transformer for a few hundred steps.

Uses the same train_step / optimizer / data pipeline the production launcher
lowers for the 512-chip dry-run — just at CPU-tractable scale (internlm2
family, trimmed to ~100M params).

Run:  PYTHONPATH=src python examples/train_transformer.py [--steps 200]
"""
import argparse

from repro.configs import registry
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--tiny", action="store_true",
                    help="~10M-param smoke variant (fast CI validation)")
    args = ap.parse_args()

    # ~100M-param variant of the internlm2 family (16L·640d ≈ 103M params
    # with its 92k vocab embedding); --tiny shrinks it ~10x for CI
    cfg = registry.get(args.arch).with_(
        n_layers=16, d_model=640, n_heads=8, n_kv_heads=4, d_head=80,
        d_ff=1792, dtype="float32", remat="none")
    if args.tiny:
        cfg = cfg.with_(n_layers=4, d_model=256, d_head=32, d_ff=512,
                        vocab=4096)

    _, losses = train_loop(cfg, steps=args.steps, batch=8, seq=128,
                           lr=6e-4, log_every=10)
    print(f"cross-entropy: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'DID NOT IMPROVE'})")


if __name__ == "__main__":
    main()
