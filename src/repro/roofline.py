"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs      / (peak_FLOP/s per chip)
    memory     = HLO_bytes      / (HBM bytes/s per chip)
    collective = collective_bytes / (ICI bytes/s per chip)

All three come from the *post-SPMD per-device* program, so no further
division by chip count is needed.  We do NOT use ``compiled.cost_analysis()``
for totals: XLA counts while-loop bodies once regardless of trip count
(verified empirically), which undercounts our scan-heavy steps by orders of
magnitude.  Instead hlo_analysis.analyze_hlo() walks the optimized HLO call
graph multiplying by XLA's own known_trip_count annotations; cost_analysis
is kept in the record as a cross-check lower bound.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes appearing before the op name, e.g. "bf16[8,128]{1,0}" or tuples
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per collective kind: {count, bytes} from result shapes in the HLO."""
    out = {k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(%?\S+)\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(2)
        for kind in _COLLECTIVES:
            # match the op name at the start of the computation (after shapes)
            opm = re.search(r"\)?\s(" + kind + r")(-start|-done)?\(", " " + rhs)
            if opm is None:
                continue
            if opm.group(2) == "-done":      # avoid double counting async pairs
                continue
            shapes_part = rhs[: opm.start()]
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes_part))
            out[kind]["count"] += 1
            out[kind]["bytes"] += b
            break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops (trip-count aware)
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes
    coll_detail: dict[str, dict[str, float]]
    per_device_memory: float     # peak allocation bytes (memory_analysis)
    xla_flops: float = 0.0       # cost_analysis cross-check (loop bodies x1)
    xla_bytes: float = 0.0
    unknown_trip_loops: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def summary(self, model_flops_global: float = 0.0, n_chips: int = 1) -> dict[str, Any]:
        d = {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops_per_dev": self.flops,
            "hlo_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "mem_per_dev_gib": self.per_device_memory / 2**30,
            "xla_flops_per_dev": self.xla_flops,
            "unknown_trip_loops": self.unknown_trip_loops,
        }
        if model_flops_global:
            useful = model_flops_global / n_chips
            d["model_flops_per_dev"] = useful
            d["useful_flop_frac"] = useful / max(self.flops, 1.0)
        return d


def analyze(compiled, *, hlo_text: str | None = None) -> Roofline:
    """Build a Roofline from a jax compiled executable."""
    from repro import hlo_analysis

    text = hlo_text if hlo_text is not None else compiled.as_text()
    totals = hlo_analysis.analyze_hlo(text)

    xla_flops = xla_bytes = 0.0
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # older jax returns [dict]
            ca = ca[0]
        xla_flops = float(ca.get("flops", 0.0))
        xla_bytes = float(ca.get("bytes accessed", 0.0))
    except Exception:
        pass

    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(flops=max(totals.flops, xla_flops),
                    hbm_bytes=max(totals.bytes, xla_bytes),
                    coll_bytes=totals.coll_bytes,
                    coll_detail=totals.coll, per_device_memory=mem,
                    xla_flops=xla_flops, xla_bytes=xla_bytes,
                    unknown_trip_loops=totals.unknown_trip_loops)


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS: 6·N·D (train) or 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    tokens = batch * seq if kind == "train" else (
        batch * seq if kind == "prefill" else batch * 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
