"""Leaf-compaction planning for federated forest serving.

A fitted tree's heap arrays are mostly dead slots: a depth-``d`` heap has
``2^(d+1)-1`` nodes but at most ``2^d`` leaves, and in practice far fewer are
live (bounded by the training-sample count and shrinking as branches bottom
out).  The builder already compacts *levels* (frontier_cap); this module
compacts the *prediction* side the same way — per tree, the heap ids of its
live leaves are packed into a dense ``LeafTable`` so the one-round membership
mask, its psum, and the vote contraction all run over ``L`` live-leaf slots
instead of the full heap.

``is_leaf`` is shared structure (every party stores it identically, paper
§3.1 "keeping the node structure"), so the table is computed once from any
party's view and broadcast as a *shared* argument of the SPMD predictor —
compaction adds no per-party state and no extra communication.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.tree import PartyTree
from repro.core.types import ForestParams


class LeafTable(NamedTuple):
    """Per-tree live-leaf index table (static capacity L).

    leaf_idx: (T, L) int32 — heap node id of each live leaf in ascending
              (heap) order; -1 pads up to the shared static capacity.
    n_live:   (T,)   int32 — live-leaf count per tree (<= L).
    """

    leaf_idx: jnp.ndarray
    n_live: jnp.ndarray

    @property
    def capacity(self) -> int:
        return int(self.leaf_idx.shape[-1])


def build_leaf_table(trees: PartyTree, params: ForestParams, *,
                     pad_multiple: int = 8) -> LeafTable:
    """Plan the compact leaf layout of a fitted forest (host-side, once).

    Accepts a PartyTree stack with leading (T, ...) or (M, T, ...) axes —
    ``is_leaf`` is shared, so the first party's view is authoritative.  The
    capacity L is the max live-leaf count over trees, rounded up to
    ``pad_multiple`` (so nearby forest sizes reuse compiled executables) and
    clamped to ``params.max_leaves``.
    """
    is_leaf = np.asarray(trees.is_leaf)
    if is_leaf.ndim == 3:                       # (M, T, nn) -> shared view
        is_leaf = is_leaf[0]
    t, nn = is_leaf.shape
    counts = is_leaf.sum(axis=1).astype(np.int32)
    cap = max(1, int(counts.max()) if t else 1)
    cap = -(-cap // pad_multiple) * pad_multiple
    cap = min(cap, params.max_leaves, nn)
    cap = max(cap, int(counts.max()) if t else 1)  # clamp never loses leaves
    idx = np.full((t, cap), -1, np.int32)
    for i in range(t):
        ids = np.flatnonzero(is_leaf[i])
        idx[i, : len(ids)] = ids
    return LeafTable(jnp.asarray(idx), jnp.asarray(counts))


def compaction_ratio(table: LeafTable, params: ForestParams) -> float:
    """Dense mask columns / compact mask columns — the psum/vote shrink."""
    return params.n_nodes / max(table.capacity, 1)
