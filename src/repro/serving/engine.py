"""ForestServer — compile-once, bucketed federated forest inference engine.

Serving traffic arrives in arbitrary batch sizes; jit'd XLA executables want
static shapes.  The engine bridges the two the same way launch/serve.py does
for the transformer path:

  * requests are padded up to a small set of BUCKET row counts (default
    32/256/2048) and each bucket's prediction program is lowered and compiled
    exactly once (AOT ``jit(...).lower(...).compile()``), so steady-state
    traffic never recompiles — ``compile_count`` is the proof, asserted in
    tests/test_serving.py;
  * oversized requests are chopped into waves of the largest bucket
    (micro-batching); per-wave latency / rows-per-second / psum payload bytes
    are recorded in ``wave_stats``;
  * the prediction program is the paper's one-round protocol, SPMD over the
    party axis, built by repro.federation.programs against the server's
    Substrate — SimulatedSubstrate (vmap, single host) or ShardedSubstrate
    (shard_map over a (trees, parties) mesh, with the ``aggregate=False``
    per-tree hook and the forest vote as the cross-shard reduction);
  * with ``compact=True`` (default) a ``LeafTable`` (plan.py) switches the
    kernel to the leaf-compacted membership mask — bit-identical outputs,
    psum and vote shrunk from ``n_nodes`` to live-leaf columns.

Prefer building servers through ``Federation.serve`` — the session pre-binds
its mesh and keeps the LeafTable plan fresh across model updates.
"""
from __future__ import annotations

import collections
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core import prediction
from repro.core.tree import PartyTree
from repro.core.types import ForestParams
from repro.federation import programs
from repro.federation.substrate import ShardedSubstrate, SimulatedSubstrate
from repro.serving import plan

DEFAULT_BUCKETS = (32, 256, 2048)


def load_forest_trees(ckpt_dir: str, step: int | None = None) -> PartyTree:
    """Restore a fitted PartyTree stack (leading (M, T, ...) axes) from a
    ckpt/checkpoint.py snapshot — the exact artifact fit_resumable saves.

    PartyTree is a NamedTuple, so its checkpoint keys are the field names
    (".is_leaf", ".leaf_stats", ...) — enough to reconstruct it without a
    caller-provided ``like`` pytree."""
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    flat = ckpt.peek_checkpoint(ckpt_dir, step)
    keys = [f".{name}" for name in PartyTree._fields]
    if sorted(flat) != sorted(keys):
        raise ValueError(
            f"checkpoint at {ckpt_dir} step {step} is not a bare PartyTree "
            f"(keys {sorted(flat)})")
    return PartyTree(*(jnp.asarray(flat[k]) for k in keys))


class ForestServer:
    """Batched one-round prediction server over a fitted federated forest.

    Args:
      trees: PartyTree stack with leading (M, T, ...) axes (all parties'
        partial trees — what fit() produces and checkpoints store).
      params: the forest's ForestParams (static compile keys).
      buckets: ascending batch-row buckets; requests pad to the smallest
        fitting bucket, larger ones run in waves of the biggest.
      compact: serve through the leaf-compacted kernel (LeafTable).
      mesh: None -> run_simulated (vmap); a Mesh with ("trees", "parties")
        axes -> run_sharded party-SPMD x tree-sharded execution.
      partition: optional VerticalPartition for binning raw feature rows.
      decode: optional label decode applied to served outputs (crypto.py).
    """

    def __init__(self, trees: PartyTree, params: ForestParams, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 compact: bool = True, mask_dtype=jnp.uint8,
                 vote_impl: str = "einsum", mesh=None,
                 partition=None, decode: Callable | None = None,
                 leaf_pad_multiple: int = 8,
                 n_features_per_party: int | None = None):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be ascending/unique: {buckets}")
        self.params = params
        self.buckets = tuple(int(b) for b in buckets)
        self.compact = compact
        self.mask_dtype = mask_dtype
        self.vote_impl = vote_impl
        self.mesh = mesh
        self.substrate = (ShardedSubstrate(mesh) if mesh is not None
                          else SimulatedSubstrate())
        self.partition = partition
        self.decode = decode
        self.compile_count = 0
        # bounded: a long-running server must not leak one dict per wave
        self.wave_stats: collections.deque = collections.deque(maxlen=4096)
        self._exec: dict[int, Callable] = {}
        self._request_fp = n_features_per_party
        self._leaf_pad = leaf_pad_multiple
        self.refresh(trees)

    # ------------------------------------------------------------ factories
    @classmethod
    def from_forest(cls, forest, **kw) -> "ForestServer":
        """Wrap a fitted core.forest.FederatedForest (binning + decode ride
        along, so the server accepts raw feature rows)."""
        assert forest.trees_ is not None, "fit first"
        kw.setdefault("partition", forest.partition_)
        kw.setdefault("decode", forest._decode)
        return cls(forest.trees_, forest.params, **kw)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, params: ForestParams,
                        step: int | None = None, **kw) -> "ForestServer":
        """Checkpoint -> serving, through a Federation session: the session
        rehydrates the fitted forest handle (reconstructing the label decode
        where possible) and binds the server to the right substrate.  The
        party count comes from the checkpointed stack itself."""
        from repro.federation import Federation
        mesh = kw.pop("mesh", None)
        trees = load_forest_trees(ckpt_dir, step)
        fed = Federation(parties=int(trees.is_leaf.shape[0]),
                         substrate="sharded" if mesh is not None
                         else "simulated", mesh=mesh)
        # fit-time privacy flags steer load's decode reconstruction; the
        # rest of kw configures the server itself
        model_kw = {k: kw.pop(k) for k in ("encrypt_labels",
                                           "mask_regression") if k in kw}
        model = fed.load(ckpt_dir, params, step=step, trees=trees,
                         partition=kw.pop("partition", None),
                         decode=kw.pop("decode", None), **model_kw)
        compact = kw.pop("compact", True)
        buckets = kw.pop("buckets", None)
        return fed.serve(model, buckets=buckets, compact=compact,
                         server_cls=cls, **kw)

    # ------------------------------------------------------- compile layer
    def refresh(self, trees: PartyTree) -> "ForestServer":
        """(Re)bind the server to a PartyTree stack.

        Called at construction, and again by ``Federation.serve`` whenever a
        model's ``trees_`` changed underneath a cached server (e.g. a
        ``fit_resumable`` continuation extended the forest): the LeafTable
        plan is rebuilt and compiled executables are dropped — their shapes
        baked in the old stack.  ``compile_count`` keeps counting up, so the
        compile-once contract stays observable across refreshes."""
        self.trees = jax.tree.map(jnp.asarray, trees)
        self.n_parties = int(self.trees.is_leaf.shape[0])
        self.leaf_table = (plan.build_leaf_table(
            self.trees, self.params, pad_multiple=self._leaf_pad)
            if self.compact else None)
        self._exec = {}
        return self

    def _program(self):
        fn = programs.forest_predict_program(
            self.substrate, self.params, compact=self.leaf_table is not None,
            mask_dtype=self.mask_dtype, vote_impl=self.vote_impl)
        shared = () if self.leaf_table is None else (self.leaf_table.leaf_idx,)
        return fn, shared

    def _executable(self, bucket: int):
        if bucket in self._exec:
            return self._exec[bucket]
        xbt = jnp.zeros((self.n_parties, bucket, self._fp()), jnp.uint8)
        fn, shared = self._program()
        args = (self.trees, xbt) + shared
        with self.substrate.context():
            compiled = jax.jit(fn).lower(*args).compile()
        self.compile_count += 1
        self._exec[bucket] = compiled
        return compiled

    def _fp(self) -> int:
        """Per-party (padded) feature width of request rows."""
        if self.partition is not None:
            return int(self.partition.feat_gid.shape[1])
        if self._request_fp is None:
            raise ValueError(
                "feature width unknown: pass n_features_per_party / a "
                "partition, or serve a binned batch before warmup()")
        return int(self._request_fp)

    def warmup(self) -> "ForestServer":
        """Pre-lower + compile every bucket (the compile-once contract)."""
        for b in self.buckets:
            self._executable(b)
        return self

    # ---------------------------------------------------------- serve layer
    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def serve_binned(self, xb_parts: np.ndarray) -> np.ndarray:
        """Serve pre-binned, pre-partitioned rows: (M, n, Fp) uint8 -> (n,).

        Chops into waves of at most the largest bucket, pads each wave to
        its bucket, strips padding from the outputs."""
        xb_parts = np.asarray(xb_parts)
        m, n, fp = xb_parts.shape
        if m != self.n_parties:
            raise ValueError(f"expected {self.n_parties} parties, got {m}")
        self._request_fp = fp
        if n == 0:                                    # empty batch: no wave
            dt = (np.int32 if self.params.task == "classification"
                  else np.float32)
            return np.empty((0,), dt)
        outs = []
        lo = 0
        while lo < n:
            hi = min(lo + self.buckets[-1], n)
            outs.append(self._serve_wave(xb_parts[:, lo:hi]))
            lo = hi
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    def serve(self, x_test: np.ndarray) -> np.ndarray:
        """Serve raw feature rows (n, F) — requires a partition for binning."""
        if self.partition is None:
            raise ValueError("raw-row serving needs a VerticalPartition")
        out = self.serve_binned(self.partition.bin_test(np.asarray(x_test)))
        return self.decode(out) if self.decode is not None else out

    def _serve_wave(self, xb_parts: np.ndarray) -> np.ndarray:
        m, n, fp = xb_parts.shape
        bucket = self._bucket_for(n)
        compiled = self._executable(bucket)
        if n < bucket:
            xb_parts = np.pad(xb_parts, ((0, 0), (0, bucket - n), (0, 0)))
        shared = (() if self.leaf_table is None
                  else (self.leaf_table.leaf_idx,))
        t0 = time.perf_counter()
        out = compiled(self.trees, jnp.asarray(xb_parts), *shared)
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        n_cols = (self.params.n_nodes if self.leaf_table is None
                  else self.leaf_table.capacity)
        n_trees = int(self.trees.is_leaf.shape[1])    # actual stack, not
        self.wave_stats.append({                      # params (fit_resumable
            "bucket": bucket, "n_rows": n,            # chunks can be partial)
            "latency_s": dt,
            "rows_per_s": n / max(dt, 1e-12),
            "comm_bytes": prediction.mask_comm_bytes(
                n_trees, bucket, n_cols, self.mask_dtype),
        })
        out = np.asarray(out)
        return out[0][:n] if out.ndim > 1 else out[:n]

    # ------------------------------------------------------------ reporting
    def stats_summary(self) -> dict:
        """p50/p95 latency + aggregate throughput over recorded waves.

        ``comm_bytes_total`` sums every recorded wave's psum payload, so it
        stays honest under mixed-bucket traffic (per-wave values live in
        ``wave_stats``)."""
        if not self.wave_stats:
            return {}
        lat = np.array([w["latency_s"] for w in self.wave_stats])
        rows = sum(w["n_rows"] for w in self.wave_stats)
        return {"waves": len(lat), "rows": rows,
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p95_ms": float(np.percentile(lat, 95) * 1e3),
                "rows_per_s": rows / max(float(lat.sum()), 1e-12),
                "comm_bytes_total": sum(w["comm_bytes"]
                                        for w in self.wave_stats),
                "compile_count": self.compile_count}
