"""ForestServer — compile-once, bucketed federated forest inference engine.

Serving traffic arrives in arbitrary batch sizes; jit'd XLA executables want
static shapes.  The engine bridges the two the same way launch/serve.py does
for the transformer path:

  * requests are padded up to a small set of BUCKET row counts (default
    32/256/2048) and each bucket's prediction program is lowered and compiled
    exactly once (AOT ``jit(...).lower(...).compile()``), so steady-state
    traffic never recompiles — ``compile_count`` is the proof, asserted in
    tests/test_serving.py;
  * oversized requests are chopped into waves of the largest bucket
    (micro-batching); per-wave latency / rows-per-second / psum payload bytes
    are recorded in ``wave_stats``;
  * the prediction program is the paper's one-round protocol, SPMD over the
    party axis — ``protocol.run_simulated`` (vmap, single host) or
    ``run_sharded`` (shard_map over a (trees, parties) mesh, with the
    ``aggregate=False`` per-tree hook and the forest vote as the cross-shard
    reduction, exactly like launch/cases.forest_case);
  * with ``compact=True`` (default) a ``LeafTable`` (plan.py) switches the
    kernel to the leaf-compacted membership mask — bit-identical outputs,
    psum and vote shrunk from ``n_nodes`` to live-leaf columns.
"""
from __future__ import annotations

import collections
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.ckpt import checkpoint as ckpt
from repro.core import prediction, protocol
from repro.core.tree import PartyTree
from repro.core.types import ForestParams
from repro.serving import plan

DEFAULT_BUCKETS = (32, 256, 2048)


def load_forest_trees(ckpt_dir: str, step: int | None = None) -> PartyTree:
    """Restore a fitted PartyTree stack (leading (M, T, ...) axes) from a
    ckpt/checkpoint.py snapshot — the exact artifact fit_resumable saves.

    PartyTree is a NamedTuple, so its checkpoint keys are the field names
    (".is_leaf", ".leaf_stats", ...) — enough to reconstruct it without a
    caller-provided ``like`` pytree."""
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    flat = ckpt.peek_checkpoint(ckpt_dir, step)
    keys = [f".{name}" for name in PartyTree._fields]
    if sorted(flat) != sorted(keys):
        raise ValueError(
            f"checkpoint at {ckpt_dir} step {step} is not a bare PartyTree "
            f"(keys {sorted(flat)})")
    return PartyTree(*(jnp.asarray(flat[k]) for k in keys))


class ForestServer:
    """Batched one-round prediction server over a fitted federated forest.

    Args:
      trees: PartyTree stack with leading (M, T, ...) axes (all parties'
        partial trees — what fit() produces and checkpoints store).
      params: the forest's ForestParams (static compile keys).
      buckets: ascending batch-row buckets; requests pad to the smallest
        fitting bucket, larger ones run in waves of the biggest.
      compact: serve through the leaf-compacted kernel (LeafTable).
      mesh: None -> run_simulated (vmap); a Mesh with ("trees", "parties")
        axes -> run_sharded party-SPMD x tree-sharded execution.
      partition: optional VerticalPartition for binning raw feature rows.
      decode: optional label decode applied to served outputs (crypto.py).
    """

    def __init__(self, trees: PartyTree, params: ForestParams, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 compact: bool = True, mask_dtype=jnp.uint8,
                 vote_impl: str = "einsum", mesh=None,
                 partition=None, decode: Callable | None = None,
                 leaf_pad_multiple: int = 8,
                 n_features_per_party: int | None = None):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be ascending/unique: {buckets}")
        self.trees = jax.tree.map(jnp.asarray, trees)
        self.params = params
        self.buckets = tuple(int(b) for b in buckets)
        self.compact = compact
        self.mask_dtype = mask_dtype
        self.vote_impl = vote_impl
        self.mesh = mesh
        self.partition = partition
        self.decode = decode
        self.n_parties = int(self.trees.is_leaf.shape[0])
        self.leaf_table = (plan.build_leaf_table(
            self.trees, params, pad_multiple=leaf_pad_multiple)
            if compact else None)
        self.compile_count = 0
        # bounded: a long-running server must not leak one dict per wave
        self.wave_stats: collections.deque = collections.deque(maxlen=4096)
        self._exec: dict[int, Callable] = {}
        self._request_fp = n_features_per_party

    # ------------------------------------------------------------ factories
    @classmethod
    def from_forest(cls, forest, **kw) -> "ForestServer":
        """Wrap a fitted core.forest.FederatedForest (binning + decode ride
        along, so the server accepts raw feature rows)."""
        assert forest.trees_ is not None, "fit first"
        kw.setdefault("partition", forest.partition_)
        kw.setdefault("decode", forest._decode)
        return cls(forest.trees_, forest.params, **kw)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, params: ForestParams,
                        step: int | None = None, **kw) -> "ForestServer":
        """Load the PartyTree stack via ckpt/checkpoint.py and serve it."""
        return cls(load_forest_trees(ckpt_dir, step), params, **kw)

    # ------------------------------------------------------- compile layer
    def _predict_fn(self):
        p, vote, md, lt = self.params, self.vote_impl, self.mask_dtype, \
            self.leaf_table

        def fn(trees, xbt, *shared):
            return prediction.forest_predict_oneround(
                trees, xbt, p, aggregate=True, mask_dtype=md,
                vote_impl=vote, leaf_idx=shared[0] if shared else None)
        return fn, (() if lt is None else (lt.leaf_idx,))

    def _build_sharded(self):
        """shard_map program: parties x trees sharded, per-tree outputs
        reduced by the caller-side forest vote (the aggregate=False hook)."""
        from jax.sharding import PartitionSpec as P
        p, vote, md, lt = self.params, self.vote_impl, self.mask_dtype, \
            self.leaf_table
        tree_specs = jax.tree.map(lambda _: P("parties", "trees"), self.trees,
                                  is_leaf=lambda x: hasattr(x, "shape"))

        def predict_local(tr, xbt, *shared):
            tr = jax.tree.map(lambda a: a[0], tr)            # drop party dim
            per_tree = prediction.forest_predict_oneround(
                tr, xbt[0], p, aggregate=False, mask_dtype=md,
                vote_impl=vote, leaf_idx=shared[0] if shared else None)
            return per_tree[None]                            # (1, T_loc, N)

        shared = () if lt is None else (lt.leaf_idx,)
        in_specs = (tree_specs, P("parties")) + (P("trees"),) * len(shared)
        inner = compat.shard_map(predict_local, mesh=self.mesh,
                                 in_specs=in_specs,
                                 out_specs=P("parties", "trees"),
                                 check_vma=False)

        def fn(trees, xbt, *shared):
            per_tree = inner(trees, xbt, *shared)            # (m, T, N)
            if p.task == "classification":
                votes = (per_tree[0][..., None] ==
                         jnp.arange(p.n_classes)[None, None]).sum(0)
                return jnp.argmax(votes, -1)
            return per_tree[0].mean(0)
        return fn, shared

    def _executable(self, bucket: int):
        if bucket in self._exec:
            return self._exec[bucket]
        xbt = jnp.zeros((self.n_parties, bucket, self._fp()), jnp.uint8)
        if self.mesh is not None:
            fn, shared = self._build_sharded()
            args = (self.trees, xbt) + shared
            with compat.set_mesh(self.mesh):
                compiled = jax.jit(fn).lower(*args).compile()
        else:
            fn, shared = self._predict_fn()

            def wave(trees, xbt, *shared):
                return protocol.run_simulated(fn, (trees, xbt), shared)
            args = (self.trees, xbt) + shared
            compiled = jax.jit(wave).lower(*args).compile()
        self.compile_count += 1
        self._exec[bucket] = compiled
        return compiled

    def _fp(self) -> int:
        """Per-party (padded) feature width of request rows."""
        if self.partition is not None:
            return int(self.partition.feat_gid.shape[1])
        if self._request_fp is None:
            raise ValueError(
                "feature width unknown: pass n_features_per_party / a "
                "partition, or serve a binned batch before warmup()")
        return int(self._request_fp)

    def warmup(self) -> "ForestServer":
        """Pre-lower + compile every bucket (the compile-once contract)."""
        for b in self.buckets:
            self._executable(b)
        return self

    # ---------------------------------------------------------- serve layer
    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def serve_binned(self, xb_parts: np.ndarray) -> np.ndarray:
        """Serve pre-binned, pre-partitioned rows: (M, n, Fp) uint8 -> (n,).

        Chops into waves of at most the largest bucket, pads each wave to
        its bucket, strips padding from the outputs."""
        xb_parts = np.asarray(xb_parts)
        m, n, fp = xb_parts.shape
        if m != self.n_parties:
            raise ValueError(f"expected {self.n_parties} parties, got {m}")
        self._request_fp = fp
        if n == 0:                                    # empty batch: no wave
            dt = (np.int32 if self.params.task == "classification"
                  else np.float32)
            return np.empty((0,), dt)
        outs = []
        lo = 0
        while lo < n:
            hi = min(lo + self.buckets[-1], n)
            outs.append(self._serve_wave(xb_parts[:, lo:hi]))
            lo = hi
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    def serve(self, x_test: np.ndarray) -> np.ndarray:
        """Serve raw feature rows (n, F) — requires a partition for binning."""
        if self.partition is None:
            raise ValueError("raw-row serving needs a VerticalPartition")
        out = self.serve_binned(self.partition.bin_test(np.asarray(x_test)))
        return self.decode(out) if self.decode is not None else out

    def _serve_wave(self, xb_parts: np.ndarray) -> np.ndarray:
        m, n, fp = xb_parts.shape
        bucket = self._bucket_for(n)
        compiled = self._executable(bucket)
        if n < bucket:
            xb_parts = np.pad(xb_parts, ((0, 0), (0, bucket - n), (0, 0)))
        shared = (() if self.leaf_table is None
                  else (self.leaf_table.leaf_idx,))
        t0 = time.perf_counter()
        out = compiled(self.trees, jnp.asarray(xb_parts), *shared)
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        n_cols = (self.params.n_nodes if self.leaf_table is None
                  else self.leaf_table.capacity)
        n_trees = int(self.trees.is_leaf.shape[1])    # actual stack, not
        self.wave_stats.append({                      # params (fit_resumable
            "bucket": bucket, "n_rows": n,            # chunks can be partial)
            "latency_s": dt,
            "rows_per_s": n / max(dt, 1e-12),
            "comm_bytes": prediction.mask_comm_bytes(
                n_trees, bucket, n_cols, self.mask_dtype),
        })
        out = np.asarray(out)
        return out[0][:n] if out.ndim > 1 else out[:n]

    # ------------------------------------------------------------ reporting
    def stats_summary(self) -> dict:
        """p50/p95 latency + aggregate throughput over recorded waves.

        ``comm_bytes_total`` sums every recorded wave's psum payload, so it
        stays honest under mixed-bucket traffic (per-wave values live in
        ``wave_stats``)."""
        if not self.wave_stats:
            return {}
        lat = np.array([w["latency_s"] for w in self.wave_stats])
        rows = sum(w["n_rows"] for w in self.wave_stats)
        return {"waves": len(lat), "rows": rows,
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p95_ms": float(np.percentile(lat, 95) * 1e3),
                "rows_per_s": rows / max(float(lat.sum()), 1e-12),
                "comm_bytes_total": sum(w["comm_bytes"]
                                        for w in self.wave_stats),
                "compile_count": self.compile_count}
