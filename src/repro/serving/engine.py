"""Serving engines — compile-once, bucketed, async-wave federated inference.

Serving traffic arrives in arbitrary batch sizes; jit'd XLA executables want
static shapes.  The engine bridges the two the same way launch/serve.py does
for the transformer path:

  * requests are padded up to a small set of BUCKET row counts (default
    32/256/2048) and each bucket's prediction program is lowered and compiled
    exactly once (AOT ``jit(...).lower(...).compile()``), so steady-state
    traffic never recompiles — ``compile_count`` is the proof, asserted in
    tests/test_serving.py;
  * oversized requests are chopped into waves of the largest bucket
    (micro-batching); per-wave latency / rows-per-second / psum payload bytes
    are recorded in ``wave_stats``;
  * waves execute **asynchronously**: ``dispatch_wave`` launches an
    executable and returns an :class:`InFlightWave` handle without blocking
    (JAX async dispatch), ``collect`` blocks on the oldest handle, records
    its stats and strips padding.  ``serve_binned`` keeps a bounded ring of
    at most ``max_inflight`` waves in flight (backpressure: the ring must
    drain before more dispatch), so host-side padding/coalescing of wave
    ``i+1`` overlaps device execution of wave ``i`` — bit-identical to the
    sync path (``max_inflight=1``), same executables in the same order;
  * label decode (crypto.py) is applied in exactly one layer — ``collect`` —
    so ``serve``, ``serve_binned`` and the RequestQueue all return decoded
    outputs with one consistent dtype, including zero-row requests
    (``empty_result``).

``ForestServer`` is the paper's one-round protocol (§4.2); with
``compact=True`` (default) a ``LeafTable`` (plan.py) switches the kernel to
the leaf-compacted membership mask.  ``BoostingServer`` and ``LinearServer``
put federated gradient boosting and the F-LR baseline behind the *same*
bucketed async engine — ``Federation.serve`` dispatches on the model family.

Prefer building servers through ``Federation.serve`` — the session pre-binds
its mesh, keeps plans fresh across model updates, and can autotune the
bucket set from observed traffic (serving/autotune.py).
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core import prediction
from repro.core.tree import PartyTree
from repro.core.types import ForestParams
from repro.federation import programs
from repro.federation.substrate import ShardedSubstrate, SimulatedSubstrate
from repro.federation.transport import PartyUnavailableError
from repro.observability import registry as telemetry
from repro.observability import trace as tracing
from repro.observability.export import jax_profile
from repro.serving import plan
from repro.serving.config import ServeConfig

DEFAULT_BUCKETS = (32, 256, 2048)


def load_forest_trees(ckpt_dir: str, step: int | None = None) -> PartyTree:
    """Restore a fitted PartyTree stack (leading (M, T, ...) axes) from a
    ckpt/checkpoint.py snapshot — the exact artifact fit_resumable saves.

    PartyTree is a NamedTuple, so its checkpoint keys are the field names
    (".is_leaf", ".leaf_stats", ...) — enough to reconstruct it without a
    caller-provided ``like`` pytree."""
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    flat = ckpt.peek_checkpoint(ckpt_dir, step)
    keys = [f".{name}" for name in PartyTree._fields]
    if sorted(flat) != sorted(keys):
        raise ValueError(
            f"checkpoint at {ckpt_dir} step {step} is not a bare PartyTree "
            f"(keys {sorted(flat)})")
    return PartyTree(*(jnp.asarray(flat[k]) for k in keys))


@dataclasses.dataclass
class InFlightWave:
    """Handle for a dispatched, not-yet-collected wave.

    ``out`` is the executable's raw output — still a device future under JAX
    async dispatch; nothing has blocked on it yet.  ``collect`` resolves it.
    """

    out: Any
    bucket: int
    n_rows: int
    t0: float
    inflight_at_dispatch: int = 1
    # extra per-wave facts recorded by the dispatch path (e.g. the degraded
    # serving flag + dead-party list) — merged into the wave_stats entry
    info: dict | None = None
    # open trace span (tracing.TRACER.begin), finished at collect; None
    # when tracing is disabled
    span: Any = None


class ModelServer:
    """Bucket / pad / compile-once / async-wave machinery, model-agnostic.

    Subclasses bind a model family by implementing:
      * ``_program()``     — the substrate-specialized predict closure;
      * ``_wave_args(xbt)``— the full ordered argument tuple for one wave
                             (model state + the padded request rows + any
                             shared args, in the program's order);
      * ``_prep(x_raw)``   — raw request rows -> (M, n, Fp) party rows;
      * ``_raw_out_dtype()``, ``_request_dtype()``, ``_wave_comm_bytes(b)``.

    The generic layer owns bucketing, AOT compilation, the in-flight ring,
    decode, padding strip, stats, and bucket retuning.
    """

    def _init_engine(self, *, buckets, mesh=None, substrate=None,
                     partition=None, decode: Callable | None = None,
                     max_inflight: int = 1, allow_degraded: bool = False,
                     n_features_per_party: int | None = None) -> None:
        self.buckets = self._check_buckets(buckets)
        if substrate is not None:
            self.substrate = substrate
        else:
            self.substrate = (ShardedSubstrate(mesh) if mesh is not None
                              else SimulatedSubstrate())
        self.mesh = self.substrate.mesh
        self.allow_degraded = bool(allow_degraded)
        self.partition = partition
        self.decode = decode
        if int(max_inflight) < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.max_inflight = int(max_inflight)
        self.compile_count = 0
        # bounded: a long-running server must not leak one dict per wave
        self.wave_stats: collections.deque = collections.deque(maxlen=4096)
        self._exec: dict[int, Callable] = {}
        self._request_fp = n_features_per_party
        self._n_inflight = 0
        self._wave_info = None
        # opt-in jax.profiler hook: set a directory (or export
        # REPRO_JAX_PROFILE=<dir>) and serve_binned wraps its wave pump in
        # a profiler trace
        self.profile_dir = os.environ.get("REPRO_JAX_PROFILE") or None
        # telemetry handles bound once — the per-wave path must not pay a
        # registry name lookup per wave
        self._m_waves = telemetry.REGISTRY.counter("serving.waves")
        self._m_rows = telemetry.REGISTRY.counter("serving.rows")
        self._m_latency = telemetry.REGISTRY.histogram(
            "serving.wave_latency_s")

    @staticmethod
    def _check_buckets(buckets) -> tuple[int, ...]:
        buckets = tuple(int(b) for b in buckets) if buckets else ()
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be ascending/unique: {buckets}")
        return buckets

    # ------------------------------------------------------- family hooks
    def _program(self):
        raise NotImplementedError

    def _wave_args(self, xbt) -> tuple:
        raise NotImplementedError

    def _prep(self, x_raw: np.ndarray) -> np.ndarray:
        """Raw request rows -> (M, n, Fp) party rows.  The binned-tree
        default: bin + partition through the fit-time VerticalPartition."""
        if self.partition is None:
            raise ValueError("raw-row serving needs a VerticalPartition")
        return self.partition.bin_test(x_raw)

    def _raw_out_dtype(self):
        raise NotImplementedError

    def _request_dtype(self):
        return jnp.uint8

    def _wave_comm_bytes(self, bucket: int) -> int:
        return 0

    # ------------------------------------------------------- compile layer
    def _executable(self, bucket: int):
        if bucket in self._exec:
            return self._exec[bucket]
        xbt = jnp.zeros((self.n_parties, bucket, self._fp()),
                        self._request_dtype())
        fn = self._program()
        with self.substrate.context():
            # the substrate owns what "compiled" means: AOT lower+compile for
            # in-process substrates, bind (model state shipped once to the
            # party processes) for the message-passing one
            compiled = self.substrate.aot_compile(fn, *self._wave_args(xbt))
        self.compile_count += 1
        self._exec[bucket] = compiled
        return compiled

    def warmup(self) -> "ModelServer":
        """Pre-lower + compile every bucket (the compile-once contract)."""
        for b in self.buckets:
            self._executable(b)
        return self

    def set_buckets(self, buckets) -> "ModelServer":
        """Retune the bucket set (serving/autotune.py drives this).

        Executables for buckets that survive the retune are kept — the
        compile-once contract holds *per autotune epoch*: after a retune +
        ``warmup()``, ``compile_count`` grows only by the genuinely new
        buckets and then stops again."""
        buckets = self._check_buckets(buckets)
        self._exec = {b: e for b, e in self._exec.items() if b in buckets}
        self.buckets = buckets
        telemetry.REGISTRY.counter("serving.autotune_epochs").inc()
        return self

    def _fp(self) -> int:
        """Per-party (padded) feature width of request rows."""
        bound = self._bound_fp()
        if bound is None:
            raise ValueError(
                "feature width unknown: pass n_features_per_party / a "
                "partition, or serve a binned batch before warmup()")
        return bound

    def _bound_fp(self) -> int | None:
        if self.partition is not None:
            return int(self.partition.feat_gid.shape[1])
        return None if self._request_fp is None else int(self._request_fp)

    def _check_fp(self, fp: int) -> None:
        """Reject rows whose per-party width disagrees with the width the
        compiled executables were (or will be) specialized for — an opaque
        XLA shape error mid-wave otherwise."""
        bound = self._bound_fp()
        if bound is None:
            self._request_fp = int(fp)
        elif int(fp) != bound:
            raise ValueError(
                f"request rows have per-party feature width {fp} but this "
                f"server is bound to width {bound} (bucket executables are "
                f"shape-specialized; re-bin through the server's partition "
                f"or stand up a server for the new width)")

    # ---------------------------------------------------------- wave layer
    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def dispatch_wave(self, xb_parts: np.ndarray) -> InFlightWave:
        """Launch one wave without blocking on its result.

        ``xb_parts`` is (M, n, Fp) with ``0 < n <= buckets[-1]``; the rows
        are padded to the wave's bucket and handed to the AOT executable.
        JAX dispatch is asynchronous, so this returns as soon as the launch
        is enqueued — host work for the next wave (binning, coalescing,
        padding) overlaps device execution of this one."""
        xb_parts = np.asarray(xb_parts)
        m, n, fp = xb_parts.shape
        if m != self.n_parties:
            raise ValueError(f"expected {self.n_parties} parties, got {m}")
        if not 0 < n <= self.buckets[-1]:
            raise ValueError(
                f"wave of {n} rows: must be in (0, {self.buckets[-1]}] — "
                f"chop oversized requests into waves (serve_binned does)")
        self._check_fp(fp)
        bucket = self._bucket_for(n)
        compiled = self._executable(bucket)
        if n < bucket:
            xb_parts = np.pad(xb_parts, ((0, 0), (0, bucket - n), (0, 0)))
        span = tracing.TRACER.begin("serve.wave", category="compute",
                                    bucket=bucket, rows=n)
        t0 = time.perf_counter()
        self._wave_info = None
        out = self._execute(compiled, jnp.asarray(xb_parts))
        self._n_inflight += 1
        return InFlightWave(out=out, bucket=bucket, n_rows=n, t0=t0,
                            inflight_at_dispatch=self._n_inflight,
                            info=self._wave_info, span=span)

    def _execute(self, compiled, xbt):
        """Launch one compiled wave — the failure seam.  ForestServer
        overrides this to fall back to degraded serving when a distributed
        party is unavailable mid-round."""
        return compiled(*self._wave_args(xbt))

    def collect(self, wave: InFlightWave) -> np.ndarray:
        """Block on a dispatched wave; record stats, strip padding, decode.

        Under async dispatch ``latency_s`` spans launch -> ready, so for
        waves that queued behind earlier in-flight work it includes queueing
        time (``inflight_at_dispatch`` records the ring depth at launch)."""
        out = jax.block_until_ready(wave.out)
        dt = time.perf_counter() - wave.t0
        tracing.TRACER.finish(wave.span)
        self._n_inflight -= 1
        self._m_waves.inc()
        self._m_rows.inc(wave.n_rows)
        self._m_latency.observe(dt)
        entry = {
            "bucket": wave.bucket, "n_rows": wave.n_rows,
            "t0": wave.t0, "latency_s": dt,
            "rows_per_s": wave.n_rows / max(dt, 1e-12),
            "inflight": wave.inflight_at_dispatch,
            "comm_bytes": self._wave_comm_bytes(wave.bucket),
        }
        if wave.info:
            entry.update(wave.info)
        self.wave_stats.append(entry)
        return self._finalize(self._strip(out, wave.n_rows))

    def abandon(self, waves) -> None:
        """Collect-and-discard in-flight handles whose results are no longer
        wanted (a failed pump discarding its ring).  Keeps the in-flight
        counter honest — the waves did run — while suppressing their own
        errors (the caller is already propagating the original one)."""
        for wave in waves:
            try:
                self.collect(wave)
            except Exception:                        # noqa: BLE001
                pass

    def _strip(self, out, n: int) -> np.ndarray:
        """Master-side rows of a program output, padding stripped.

        The aggregated serving programs produce exactly two shapes: ``(rows,)``
        (sharded substrate — the cross-shard reduction already ran) or
        ``(M, rows)`` (simulated substrate — a per-party stack whose row 0 is
        the shared result).  Anything else (per-tree ``aggregate=False``
        stacks, future multi-output programs) must not be sliced silently."""
        out = np.asarray(out)
        if out.ndim == 1:
            return out[:n]
        if out.ndim == 2 and out.shape[0] == self.n_parties:
            return out[0, :n]
        raise ValueError(
            f"program output has unexpected shape {out.shape}: the serving "
            f"path expects (rows,) (sharded, reduced) or "
            f"({self.n_parties}, rows) (simulated party stack); per-tree / "
            f"multi-output programs need their own collect handling")

    def _finalize(self, out: np.ndarray) -> np.ndarray:
        """Decode lives here, and only here (one layer for every caller)."""
        return self.decode(out) if self.decode is not None else np.asarray(out)

    def empty_result(self) -> np.ndarray:
        """The zero-row result, produced by the same decode path as real
        waves — so its dtype matches non-empty outputs for every task and
        crypto setting (e.g. regression_unmasker promotes to float64)."""
        return self._finalize(np.empty((0,), self._raw_out_dtype()))

    # ---------------------------------------------------------- serve layer
    def _serve_wave(self, xb_parts: np.ndarray) -> np.ndarray:
        return self.collect(self.dispatch_wave(xb_parts))

    def serve_binned(self, xb_parts: np.ndarray, *,
                     max_inflight: int | None = None) -> np.ndarray:
        """Serve pre-binned, pre-partitioned rows: (M, n, Fp) -> (n,).

        Chops into waves of at most the largest bucket and pumps them
        through the in-flight ring: up to ``max_inflight`` waves run on
        device while the host pads the next ones; collection is FIFO, so
        outputs are bit-identical to the sync path."""
        xb_parts = np.asarray(xb_parts)
        m, n, fp = xb_parts.shape
        if m != self.n_parties:
            raise ValueError(f"expected {self.n_parties} parties, got {m}")
        if n == 0:                                    # empty batch: no wave
            return self.empty_result()
        k = self.max_inflight if max_inflight is None else max(1, max_inflight)
        ring: collections.deque[InFlightWave] = collections.deque()
        outs, lo = [], 0
        try:
            with jax_profile(self.profile_dir):
                while lo < n or ring:
                    while lo < n and len(ring) < k:   # fill the ring
                        hi = min(lo + self.buckets[-1], n)
                        ring.append(self.dispatch_wave(xb_parts[:, lo:hi]))
                        lo = hi
                    outs.append(self.collect(ring.popleft()))  # backpressure
        except BaseException:
            self.abandon(ring)                        # keep inflight honest
            raise
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    def serve(self, x_test: np.ndarray) -> np.ndarray:
        """Serve raw feature rows (n, F) — the family's _prep does the
        partition/bin/standardize step; decode is applied per wave."""
        return self.serve_binned(self._prep(np.asarray(x_test)))

    def serve_parties(self, blocks, *, salt=None):
        """Serve per-party request blocks keyed by (hashed) sample IDs.

        ``blocks`` are PartyBlocks/DataSources — one per fit-time party,
        matched by name, rows in any order and possibly superset (each
        region ships whatever extract it has).  The engine re-aligns them on
        hashed IDs, drops non-common rows, bins party-locally with the
        fit-time boundaries and dispatches as usual.  Returns
        ``(ids, predictions)`` in the canonical aligned order.
        """
        from repro.core import crypto
        if self.partition is None:
            raise ValueError("party-block serving needs the fit-time "
                             "VerticalPartition bound to the server")
        ids, xb = self.partition.bin_party_blocks(
            blocks, salt=salt if salt is not None else crypto.DEFAULT_SALT)
        return ids, self.serve_binned(xb)

    # ------------------------------------------------------------ reporting
    def stats_summary(self) -> dict:
        """p50/p95/p99 latency + aggregate throughput over recorded waves.

        ``comm_bytes_total`` sums every recorded wave's psum payload, so it
        stays honest under mixed-bucket traffic (per-wave values live in
        ``wave_stats``).  With no recorded waves the record is well-formed
        zeros (same keys, zero counts/latencies) — a just-spawned or fully
        drained cell aggregates into fleet metrics without special casing."""
        if not self.wave_stats:
            return {"waves": 0, "rows": 0, "p50_ms": 0.0, "p95_ms": 0.0,
                    "p99_ms": 0.0, "rows_per_s": 0.0, "comm_bytes_total": 0,
                    "compile_count": self.compile_count}
        lat = np.array([w["latency_s"] for w in self.wave_stats])
        rows = sum(w["n_rows"] for w in self.wave_stats)
        # busy time = union of the [t0, t0+latency] wave intervals: async
        # waves overlap by design, so summing latencies would double-count
        # and understate throughput by ~max_inflight; idle gaps between
        # traffic bursts don't count as busy either way
        spans = sorted((w["t0"], w["t0"] + w["latency_s"])
                       for w in self.wave_stats)
        busy, end = 0.0, float("-inf")
        for s, e in spans:
            if e > end:
                busy += e - max(s, end)
                end = e
        return {"waves": len(lat), "rows": rows,
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p95_ms": float(np.percentile(lat, 95) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3),
                "rows_per_s": rows / max(busy, 1e-12),
                "comm_bytes_total": sum(w["comm_bytes"]
                                        for w in self.wave_stats),
                "compile_count": self.compile_count}

    #: canonical name; ``stats_summary`` predates it and is kept as an alias.
    stats = stats_summary


class ForestServer(ModelServer):
    """Batched one-round prediction server over a fitted federated forest.

    Args:
      trees: PartyTree stack with leading (M, T, ...) axes (all parties'
        partial trees — what fit() produces and checkpoints store).
      params: the forest's ForestParams (static compile keys).
      buckets: ascending batch-row buckets; requests pad to the smallest
        fitting bucket, larger ones run in waves of the biggest.
      compact: serve through the leaf-compacted kernel (LeafTable).
      mesh: None -> run_simulated (vmap); a Mesh with ("trees", "parties")
        axes -> run_sharded party-SPMD x tree-sharded execution.
      partition: optional VerticalPartition for binning raw feature rows.
      decode: optional label decode applied to served outputs (crypto.py).
      max_inflight: in-flight wave ring depth (1 = synchronous waves).
    """

    def __init__(self, trees: PartyTree, params: ForestParams, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 compact: bool = True, mask_dtype=jnp.uint8,
                 vote_impl: str = "einsum", mesh=None, substrate=None,
                 partition=None, decode: Callable | None = None,
                 leaf_pad_multiple: int = 8, max_inflight: int = 1,
                 allow_degraded: bool = False,
                 n_features_per_party: int | None = None):
        self.params = params
        self.compact = compact
        self.mask_dtype = mask_dtype
        self.vote_impl = vote_impl
        self._leaf_pad = leaf_pad_multiple
        self._init_engine(
            buckets=buckets, mesh=mesh, substrate=substrate,
            partition=partition, decode=decode, max_inflight=max_inflight,
            allow_degraded=allow_degraded,
            n_features_per_party=n_features_per_party)
        self.refresh(trees)

    # ------------------------------------------------------------ factories
    @classmethod
    def from_forest(cls, forest, **kw) -> "ForestServer":
        """Wrap a fitted core.forest.FederatedForest (binning + decode ride
        along, so the server accepts raw feature rows)."""
        if forest.trees_ is None:
            raise ValueError("forest is not fitted: call fit() first")
        kw.setdefault("partition", forest.partition_)
        kw.setdefault("decode", forest._decode)
        return cls(forest.trees_, forest.params, **kw)

    from_model = from_forest

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, params: ForestParams,
                        step: int | None = None, **kw) -> "ForestServer":
        """Checkpoint -> serving, through a Federation session: the session
        rehydrates the fitted forest handle (reconstructing the label decode
        where possible) and binds the server to the right substrate.  The
        party count comes from the checkpointed stack itself."""
        from repro.federation import Federation
        mesh = kw.pop("mesh", None)
        trees = load_forest_trees(ckpt_dir, step)
        fed = Federation(parties=int(trees.is_leaf.shape[0]),
                         substrate="sharded" if mesh is not None
                         else "simulated", mesh=mesh)
        # fit-time privacy flags steer load's decode reconstruction; the
        # rest of kw configures the server itself
        model_kw = {k: kw.pop(k) for k in ("encrypt_labels",
                                           "mask_regression") if k in kw}
        model = fed.load(ckpt_dir, params, step=step, trees=trees,
                         partition=kw.pop("partition", None),
                         decode=kw.pop("decode", None), **model_kw)
        config = kw.pop("config", None)
        if config is None:
            config = ServeConfig(
                buckets=kw.pop("buckets", None),
                compact=kw.pop("compact", True),
                max_inflight=kw.pop("max_inflight", 1),
                allow_degraded=kw.pop("allow_degraded", False))
        return fed.serve(model, config, server_cls=cls, **kw)

    # -------------------------------------------------------- model binding
    @staticmethod
    def model_token(model) -> tuple:
        """Token of the model state a server was built from — object
        entries compare by identity, value entries by equality
        (session._token_matches); ``Federation.serve`` refreshes the cached
        server when the token changes.  The partition rides in the token
        because the server bins raw request rows with the fit-time
        boundaries: after an ``ingest_append`` + refit the boundaries moved,
        and serving with the stale grid would silently mis-bin every
        request."""
        return (model.trees_, model.partition_)

    def refresh_from(self, model) -> "ForestServer":
        """Rebind to a refreshed model: trees AND the request-path state
        (partition for binning, label decode) — a refit on appended rows
        changes all three."""
        if model.partition_ is not None:
            self.partition = model.partition_
        if model._decode is not None:
            self.decode = model._decode
        return self.refresh(model.trees_)

    def refresh(self, trees: PartyTree) -> "ForestServer":
        """(Re)bind the server to a PartyTree stack.

        Called at construction, and again by ``Federation.serve`` whenever a
        model's ``trees_`` changed underneath a cached server (e.g. a
        ``fit_resumable`` continuation extended the forest): the LeafTable
        plan is rebuilt and compiled executables are dropped — their shapes
        baked in the old stack.  ``compile_count`` keeps counting up, so the
        compile-once contract stays observable across refreshes."""
        self.trees = jax.tree.map(jnp.asarray, trees)
        self.n_parties = int(self.trees.is_leaf.shape[0])
        self.leaf_table = (plan.build_leaf_table(
            self.trees, self.params, pad_multiple=self._leaf_pad)
            if self.compact else None)
        self._exec = {}
        # alive-party tuple -> (bound runner, sliced trees, sliced leaf_idx,
        # surviving tree count): the degraded-serving fast path
        self._degraded: dict[tuple, tuple] = {}
        return self

    # ------------------------------------------------- degraded serving
    def _execute(self, compiled, xbt):
        try:
            return super()._execute(compiled, xbt)
        except PartyUnavailableError as err:
            if not self.allow_degraded or not err.parties:
                raise
            return self._execute_degraded(err, xbt)

    def _execute_degraded(self, err: PartyUnavailableError, xbt):
        """Answer a wave from the trees whose split paths avoid every dead
        party's features (their membership masks over the surviving parties
        intersect to exactly the full-federation leaf assignment, so the
        served predictions are exact — just from a smaller forest).  The
        wave is flagged ``degraded`` with the dead-party list in
        wave_stats."""
        from repro.federation import distributed
        sub = self.substrate
        known = getattr(sub, "unavailable_parties", lambda: ())()
        dead = tuple(sorted(set(err.parties) | set(known)))
        alive = tuple(p for p in range(self.n_parties) if p not in dead)
        if not alive:
            raise err
        cached = self._degraded.get(alive)
        if cached is None:
            sel = distributed.surviving_trees(self.trees, dead)
            if sel.size == 0:
                raise PartyUnavailableError(
                    f"cannot serve degraded: every tree splits on a dead "
                    f"party's features (dead={list(dead)})", parties=dead)
            trees = jax.tree.map(lambda a: a[:, sel], self.trees)
            lt = (None if self.leaf_table is None
                  else self.leaf_table.leaf_idx[np.asarray(sel)])
            prog = programs.forest_predict_program(
                sub, self.params, compact=lt is not None,
                mask_dtype=self.mask_dtype, vote_impl=self.vote_impl,
                parties=alive)
            args = (trees,) if lt is None else (trees, None, lt)
            runner = sub.aot_compile(prog, *args)
            cached = (runner, trees, lt, int(sel.size))
            self._degraded[alive] = cached
        runner, trees, lt, n_trees = cached
        out = runner(*((trees, xbt) if lt is None else (trees, xbt, lt)))
        self._wave_info = {"degraded": True, "dead_parties": list(dead),
                           "n_trees": n_trees}
        return np.asarray(out)[0]     # 1-D: _strip's reduced-output shape

    # ------------------------------------------------------------ hooks
    def _program(self):
        return programs.forest_predict_program(
            self.substrate, self.params, compact=self.leaf_table is not None,
            mask_dtype=self.mask_dtype, vote_impl=self.vote_impl)

    def _wave_args(self, xbt) -> tuple:
        shared = (() if self.leaf_table is None
                  else (self.leaf_table.leaf_idx,))
        return (self.trees, xbt) + shared

    def _raw_out_dtype(self):
        return (np.int32 if self.params.task == "classification"
                else np.float32)

    def _wave_comm_bytes(self, bucket: int) -> int:
        n_cols = (self.params.n_nodes if self.leaf_table is None
                  else self.leaf_table.capacity)
        n_trees = int(self.trees.is_leaf.shape[1])   # actual stack, not
        return prediction.mask_comm_bytes(           # params (fit_resumable
            n_trees, bucket, n_cols, self.mask_dtype)  # chunks can be partial)


class BoostingServer(ModelServer):
    """Bucketed async serving for federated gradient boosting.

    The per-round trees (each a T=1 PartyTree) are stacked along the tree
    axis and served through ONE substrate-specialized program: the paper's
    one-round membership protocol with ``aggregate=False`` per-round outputs
    and the boosting reduction (base + lr * Σ rounds, thresholded for the
    binary task) fused in-program — so one wave = one collective for the
    whole ensemble, exactly like the forest path.  Leaf compaction applies
    unchanged (per-round trees are ordinary PartyTrees)."""

    def __init__(self, trees: list, base: float, params, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 compact: bool = True, mask_dtype=jnp.uint8, mesh=None,
                 substrate=None, partition=None, leaf_pad_multiple: int = 8,
                 max_inflight: int = 1,
                 n_features_per_party: int | None = None):
        self.params = params                     # BoostParams
        self.compact = compact
        self.mask_dtype = mask_dtype
        self._leaf_pad = leaf_pad_multiple
        self._init_engine(
            buckets=buckets, mesh=mesh, substrate=substrate,
            partition=partition, decode=None, max_inflight=max_inflight,
            n_features_per_party=n_features_per_party)
        self._rebind(trees, base)

    @classmethod
    def from_model(cls, model, **kw) -> "BoostingServer":
        """Wrap a fitted core.boosting.FederatedBoosting."""
        if not model.trees_:
            raise ValueError("fit the boosting model first")
        kw.pop("decode", None)                   # boosting has no crypto decode
        kw.setdefault("partition", getattr(model, "_partition", None))
        return cls(model.trees_, model.base_, model.params, **kw)

    @staticmethod
    def model_token(model) -> tuple:
        t = model.trees_
        return (t, len(t), t[-1] if t else None, float(model.base_))

    def refresh_from(self, model) -> "BoostingServer":
        return self._rebind(model.trees_, model.base_)

    def _rebind(self, trees: list, base: float) -> "BoostingServer":
        from repro.core.boosting import stack_rounds
        self.trees = stack_rounds(trees)         # (M, R, ...) PartyTree
        self.base = jnp.asarray(base, jnp.float32)
        self.n_parties = int(self.trees.is_leaf.shape[0])
        self.leaf_table = (plan.build_leaf_table(
            self.trees, self.params.tree_params(),
            pad_multiple=self._leaf_pad) if self.compact else None)
        self._exec = {}
        return self

    def _program(self):
        return programs.boosting_predict_program(
            self.substrate, self.params,
            compact=self.leaf_table is not None, mask_dtype=self.mask_dtype)

    def _wave_args(self, xbt) -> tuple:
        shared = (() if self.leaf_table is None
                  else (self.leaf_table.leaf_idx,))
        return (self.trees, xbt, self.base) + shared

    def _raw_out_dtype(self):
        return np.int32 if self.params.task == "binary" else np.float32

    def _wave_comm_bytes(self, bucket: int) -> int:
        n_cols = (self.params.tree_params().n_nodes if self.leaf_table is None
                  else self.leaf_table.capacity)
        n_rounds = int(self.trees.is_leaf.shape[1])
        return prediction.mask_comm_bytes(n_rounds, bucket, n_cols,
                                          self.mask_dtype)


class LinearServer(ModelServer):
    """Bucketed async serving for the F-LR baseline.

    Request rows are split into per-party raw blocks, standardized with the
    fit-time moments and served through the single-psum joint-logit program
    — float32 party rows instead of binned uint8, everything else (buckets,
    AOT compile-once, the in-flight ring) identical to the tree engines."""

    def __init__(self, model, *, buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 mesh=None, substrate=None, max_inflight: int = 1):
        self.model = model                       # fitted FederatedLinear
        self.task = model.task
        self._init_engine(
            buckets=buckets, mesh=mesh, substrate=substrate,
            partition=getattr(model, "_partition", None), decode=None,
            max_inflight=max_inflight)
        self._rebind(model)

    @classmethod
    def from_model(cls, model, **kw) -> "LinearServer":
        if getattr(model, "_w", None) is None:
            raise ValueError("fit the F-LR model first")
        kw.pop("decode", None)
        kw.pop("compact", None)                  # no heap to compact
        kw.pop("partition", None)                # the model owns its split
        return cls(model, **kw)

    @staticmethod
    def model_token(model) -> tuple:
        return (model._w,)

    def refresh_from(self, model) -> "LinearServer":
        return self._rebind(model)

    def _rebind(self, model) -> "LinearServer":
        self.model = model
        self.w = jnp.asarray(model._w)           # (M, Fmax) party blocks
        b = jnp.asarray(model._b)
        self.b = b[0] if b.ndim else b           # psum'd: identical per party
        self.n_parties = int(self.w.shape[0])
        self._exec = {}
        return self

    def _program(self):
        return programs.linear_predict_program(self.substrate, self.task)

    def _wave_args(self, xbt) -> tuple:
        return (xbt, self.w, self.b)

    def _prep(self, x_raw: np.ndarray) -> np.ndarray:
        return self.model._standardized(self.model._blocks(x_raw))

    def serve_parties(self, blocks, *, salt=None):
        """Serve per-party raw request blocks keyed by (hashed) sample IDs.

        Same re-alignment path as the tree engines (name matching, hashed-ID
        intersection, fit-time column order) — but the aligned rows stay raw
        and are standardized with the fit-time moments instead of binned.
        Returns ``(ids, predictions)`` in the canonical aligned order."""
        from repro.core import crypto
        if self.partition is None:
            raise ValueError("party-block serving needs the fit-time "
                             "VerticalPartition bound to the server (fit "
                             "the F-LR model on a VerticalPartition)")
        ids, raw_parts = self.partition.raw_party_rows(
            blocks, salt=salt if salt is not None else crypto.DEFAULT_SALT)
        return ids, self.serve_binned(self.model._standardized(raw_parts))

    def _bound_fp(self) -> int | None:
        return int(self.w.shape[-1])             # fit-time padded width

    def _request_dtype(self):
        return jnp.float32

    def _raw_out_dtype(self):
        return np.int32 if self.task == "classification" else np.float32


def server_for(model) -> type[ModelServer]:
    """The engine class serving a fitted model's family — the dispatch
    behind ``Federation.serve`` (a thin ModelServer dispatch over the
    Estimator protocol)."""
    from repro.core.boosting import FederatedBoosting
    from repro.core.fedlinear import FederatedLinear
    from repro.core.forest import FederatedForest
    if isinstance(model, FederatedForest):
        return ForestServer
    if isinstance(model, FederatedBoosting):
        return BoostingServer
    if isinstance(model, FederatedLinear):
        return LinearServer
    if hasattr(model, "trees_") and hasattr(getattr(model, "trees_", None),
                                            "is_leaf"):
        return ForestServer                      # duck-typed forest handle
    raise TypeError(f"no serving engine for model family "
                    f"{type(model).__name__}")
