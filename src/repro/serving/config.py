"""ServeConfig — the one value object describing how a model is served.

``Federation.serve`` historically grew one keyword per serving knob
(buckets, compact, max_inflight, autotune_buckets, ...) and the server
cache keyed on an ad-hoc tuple of them.  This dataclass is the single
consolidated description: it is frozen and hashable, so the *same object*
is both the call's configuration and the session's server-cache key — a
knob that matters for caching cannot be forgotten in the key, and a knob
that doesn't (``traffic`` is an input, not a configuration) stays out.

Legacy keyword calls keep working through :func:`adapt_legacy_kwargs`,
which emits one DeprecationWarning and builds the equivalent ServeConfig.
"""
from __future__ import annotations

import dataclasses
import warnings

#: serve() keywords that moved onto ServeConfig; the adapter lifts them.
LEGACY_SERVE_KEYS = ("buckets", "compact", "max_inflight",
                     "autotune_buckets", "allow_degraded")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """How a serving engine is set up (not *what* it serves).

    Attributes:
      buckets: ascending batch-row buckets, or None for the engine default
        (requests pad to the smallest fitting bucket; oversized requests
        run in waves of the largest).
      compact: serve through the leaf-compacted kernel (LeafTable).
      max_inflight: async wave-ring depth (1 = synchronous waves).
      autotune_buckets: derive the bucket set from observed traffic
        (serving/autotune.py) instead of the warm-start guess.
      allow_degraded: on a distributed substrate, answer from the trees
        whose split paths avoid a dead party's features instead of failing
        the wave (flagged ``degraded`` in wave_stats).  In-process
        substrates have no partial-failure mode; the flag is inert there.
    """

    buckets: tuple[int, ...] | None = None
    compact: bool = True
    max_inflight: int = 1
    autotune_buckets: bool = False
    allow_degraded: bool = False

    def __post_init__(self) -> None:
        if self.buckets is not None:
            object.__setattr__(self, "buckets",
                               tuple(int(b) for b in self.buckets))
        if int(self.max_inflight) < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {self.max_inflight}")
        object.__setattr__(self, "max_inflight", int(self.max_inflight))

    def resolved_buckets(self, default: tuple[int, ...]) -> tuple[int, ...]:
        return self.buckets if self.buckets is not None else tuple(default)


def adapt_legacy_kwargs(config: ServeConfig | None, kw: dict) -> ServeConfig:
    """Lift pre-ServeConfig ``serve(...)`` keywords out of ``kw`` (mutating
    it) into a ServeConfig.  Mixing both spellings is rejected — silently
    preferring one would drop the other's knobs."""
    legacy = {k: kw.pop(k) for k in LEGACY_SERVE_KEYS if k in kw}
    if not legacy:
        return config if config is not None else ServeConfig()
    if config is not None:
        raise ValueError(
            f"pass serving knobs through ServeConfig OR the legacy "
            f"keywords, not both (got config= and {sorted(legacy)})")
    warnings.warn(
        f"Federation.serve({', '.join(sorted(legacy))}=...) keywords are "
        f"deprecated: pass serve(model, ServeConfig(...)) instead",
        DeprecationWarning, stacklevel=3)
    return ServeConfig(**legacy)
