"""Traffic-driven bucket autotuning for the serving engines.

The bucket set is the engine's central padding/compile trade-off: few, large
buckets waste device work on padding rows; many buckets multiply AOT
executables (compile time, code cache).  The default set (32/256/2048) is a
hardcoded guess; this module derives one from *observed* traffic instead —
the row-count distribution recorded in ``ModelServer.wave_stats`` (per-wave
``n_rows``) and/or ``RequestQueue.request_stats`` (per-request ``rows``).

The scheme is quantile-based: bucket boundaries sit at the row-count
quantiles of the traffic, rounded up to a pad multiple, capped at
``max_buckets`` executables and always covering the observed maximum (so
steady-state traffic of the sampled shape never recompiles — the
compile-once contract holds per autotune epoch, asserted in
tests/test_serving.py and the CI bench smoke).  With too little traffic the
warm-start set is returned unchanged.

Entry point: ``Federation.serve(model, autotune_buckets=True[, traffic=...])``
refreshes the session's cached server through ``ModelServer.set_buckets``
the same way ``trees_`` changes refresh plans.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np

DEFAULT_QUANTILES = (0.0, 0.5, 0.75, 0.9, 1.0)
DEFAULT_MAX_BUCKETS = 4
MIN_OBSERVATIONS = 8


def observed_row_counts(*stat_streams) -> np.ndarray:
    """Extract row counts from stats records (wave_stats dicts with
    ``n_rows``, request_stats dicts with ``rows``) or plain integers."""
    rows: list[int] = []
    for stream in stat_streams:
        if stream is None:
            continue
        for rec in stream:
            n = (rec.get("n_rows", rec.get("rows"))
                 if isinstance(rec, dict) else rec)
            if n is not None and int(n) > 0:
                rows.append(int(n))
    return np.asarray(rows, np.int64)


def _round_up(n: float, multiple: int) -> int:
    return max(multiple, -(-int(np.ceil(n)) // multiple) * multiple)


def autotune_buckets(traffic: Iterable, *, warm: tuple[int, ...],
                     max_buckets: int = DEFAULT_MAX_BUCKETS,
                     quantiles: tuple[float, ...] = DEFAULT_QUANTILES,
                     pad_multiple: int = 8,
                     min_observations: int = MIN_OBSERVATIONS
                     ) -> tuple[int, ...]:
    """Derive an ascending bucket set from observed traffic.

    ``traffic`` is anything :func:`observed_row_counts` accepts;
    ``warm`` is returned unchanged (normalized) when fewer than
    ``min_observations`` positive row counts were seen — the engine's
    warm-start (DEFAULT_BUCKETS on a fresh server, the current set on a
    retune)."""
    counts = observed_row_counts(traffic)
    if counts.size < min_observations:
        return tuple(sorted(set(int(b) for b in warm)))
    qs = np.quantile(counts, np.clip(quantiles, 0.0, 1.0))
    cand = sorted({_round_up(q, pad_multiple) for q in qs})
    # the largest bucket must cover the observed max (waves above it would
    # micro-batch fine, but the quantile already IS the max at q=1.0)
    top = _round_up(int(counts.max()), pad_multiple)
    if cand[-1] < top:
        cand.append(top)
    if len(cand) > max_buckets:
        # thin evenly but always keep the largest (it bounds wave size)
        keep_idx = np.linspace(0, len(cand) - 1, max_buckets)
        cand = sorted({cand[int(round(i))] for i in keep_idx})
    return tuple(cand)
