"""Cell-based serving fleet: sharded replicas behind one admission front door.

One ``ModelServer`` is compile-once but single-replica: one wave ring, one
queue, one failure domain.  ``ServingFleet`` owns N replicated server
**cells** — each with its own AOT-compiled executables, its own bounded
:class:`RequestQueue` acting as a bulkhead, and its own in-flight ring — and
puts a real front door ahead of them:

  * **Routing** — consistent hashing on the request key (default: the fleet
    request id; pass stable sample/request IDs for sticky routing).  Each
    cell projects ``vnodes`` points onto a hash ring; a key routes to the
    next point clockwise.  Adding or removing a cell re-routes only the
    keyspace adjacent to that cell's points — a fleet resize does NOT
    reshuffle the whole keyspace (asserted in tests/test_fleet.py).
  * **Admission control** — a token-bucket rate limiter (rows per second,
    burst capacity) at the front door, and per-cell queue-depth shedding:
    a request that would overflow its cell's bulkhead is rejected with a
    typed :class:`FleetOverloadError` naming the reason and cell, never
    silently dropped or allowed to wedge a neighbour cell.
  * **Poison quarantine** — a request that fails inside a cell's pump
    (binning, dispatch, or collect — e.g. the engine's width/rank guards)
    is quarantined and retried SOLO, so attribution is exact; after
    ``max_poison_retries`` solo failures it lands in the **dead-letter
    sink** with its payload and the error, and the cell keeps serving
    everyone else.
  * **Cell failure** — ``kill_cell`` (or a failed health check via
    ``check_health``, reusing the distributed substrate's ``health()``
    machinery) drains a cell: it leaves the ring, and every accepted,
    unresolved request it held is re-routed to the surviving keyspace.
    Accepted requests are never lost: each one resolves, re-routes, or
    dead-letters — asserted end-to-end in tests and launch/fleet_demo.py.

Observability is serving/metrics.py: ``metrics()`` pools every cell's raw
wave latencies into fleet percentiles and busy-interval throughput, and the
snapshot hook (``snapshot_hook=``, ``snapshot_every_s=``) pushes periodic
:class:`FleetMetrics` to the deployment's sink.

Build fleets through ``Federation.serve_fleet(model, config, n_cells=...)``
— it replicates the session's serving engine per cell with the same
cache/refresh semantics as ``Federation.serve``.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import numpy as np

from repro.federation.transport import PartyUnavailableError
from repro.observability import registry as telemetry
from repro.observability import trace as tracing
from repro.serving import metrics as fleet_metrics
from repro.serving.engine import ModelServer
from repro.serving.queue import PoisonedWaveError, RequestQueue


class FleetOverloadError(RuntimeError):
    """Typed admission rejection — the caller should back off and retry.

    ``reason`` is ``"rate_limit"`` (the front-door token bucket is empty) or
    ``"queue_depth"`` (the routed cell's bulkhead is full; ``cell`` names
    it).  Shed requests are counted in the fleet metrics, never enqueued."""

    def __init__(self, msg: str, *, reason: str, cell: str | None = None):
        super().__init__(msg)
        self.reason = reason
        self.cell = cell


class TokenBucket:
    """Token-bucket rate limiter (tokens = rows; refill = rate per second).

    ``clock`` is injectable so tests drive time deterministically.

    Lock discipline (checked by repro.analysis rules/locks):
        _lock: _tokens, _t
    """

    def __init__(self, rate: float, capacity: float | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.capacity = float(capacity if capacity is not None else rate)
        self._tokens = self.capacity
        self._clock = clock
        self._t = clock()
        self._lock = threading.Lock()

    def try_acquire(self, n: float = 1.0) -> bool:
        with self._lock:
            now = self._clock()
            self._tokens = min(self.capacity,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


def _hash64(key: str) -> int:
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")


class HashRing:
    """Consistent-hash ring over named cells (``vnodes`` points per cell).

    Stability contract: removing a cell re-routes ONLY keys that routed to
    that cell; adding one steals only the keyspace adjacent to its points."""

    def __init__(self, vnodes: int = 64):
        self.vnodes = int(vnodes)
        self._points: list[int] = []        # sorted hash points
        self._owner: dict[int, str] = {}    # point -> cell name

    def add(self, name: str) -> None:
        for v in range(self.vnodes):
            h = _hash64(f"{name}#{v}")
            while h in self._owner:         # vanishing-probability collision
                h = (h + 1) & (2**64 - 1)
            self._owner[h] = name
            bisect.insort(self._points, h)

    def remove(self, name: str) -> None:
        dead = [p for p, n in self._owner.items() if n == name]
        for p in dead:
            del self._owner[p]
        self._points = sorted(self._owner)

    def route(self, key: str) -> str:
        if not self._points:
            raise RuntimeError("hash ring is empty: no cells up")
        i = bisect.bisect(self._points, _hash64(key)) % len(self._points)
        return self._owner[self._points[i]]

    def __contains__(self, name: str) -> bool:
        return any(n == name for n in self._owner.values())

    def __len__(self) -> int:
        return len(set(self._owner.values()))


@dataclasses.dataclass
class _FleetRequest:
    """Front-door record of one accepted request (until resolved)."""

    rid: int
    key: str
    x: Any                       # payload as admitted (raw rows or binned)
    binned: bool
    cell: str
    cell_rid: int
    poisons: int = 0


@dataclasses.dataclass
class DeadLetter:
    """A request that repeatedly poisoned waves — parked, not dropped."""

    rid: int
    key: str
    x: Any
    error: Exception
    poisons: int


class _Cell:
    """One replica: engine + bounded queue (the bulkhead) + routing state."""

    def __init__(self, name: str, server: ModelServer, max_queue_rows: int):
        self.name = name
        self.server = server
        self.queue = RequestQueue(server)
        self.max_queue_rows = int(max_queue_rows)
        self.state = "up"                    # up | down


class ServingFleet:
    """N server cells behind consistent-hash routing and admission control.

    Args:
      servers: the cell engines (one compiled replica per cell), or a
        ``{name: server}`` mapping; a sequence gets ``cell0..cellN-1``.
      max_queue_rows: per-cell bulkhead — accepted-but-unserved rows beyond
        this shed with ``FleetOverloadError(reason="queue_depth")``.
      rate_limit_rows_per_s / rate_burst: front-door token bucket (None
        disables rate limiting).
      max_poison_retries: solo retries before a poisoning request is
        dead-lettered.
      vnodes: hash-ring points per cell (routing granularity).
      snapshot_hook / snapshot_every_s: periodic observability push — after
        a drain, if ``snapshot_every_s`` elapsed since the last push, the
        hook is called with a fresh :class:`FleetMetrics`.
      clock: injectable time source for the rate limiter and snapshots.

    Concurrency: ``submit``/``submit_parties`` are thread-safe (the cell
    queues are multi-producer).  ``drain``, ``kill_cell`` and
    ``check_health`` are coordinator operations — call them from one
    thread (drain itself fans out over the cells internally).

    Lock discipline (checked by repro.analysis rules/locks):
        _lock: _requests, _by_cell_rid, _next_rid, accepted_count, shed_counts
        unsynchronized (coordinator thread only, per the contract above): dead_letters, rerouted_count
        unsynchronized (coordinator thread only): ring, _last_snapshot
    """

    def __init__(self, servers, *, max_queue_rows: int = 8192,
                 rate_limit_rows_per_s: float | None = None,
                 rate_burst: float | None = None,
                 max_poison_retries: int = 2, vnodes: int = 64,
                 snapshot_hook: Callable | None = None,
                 snapshot_every_s: float | None = None,
                 clock: Callable[[], float] = time.monotonic):
        named = (dict(servers) if isinstance(servers, dict) else
                 {f"cell{i}": s for i, s in enumerate(servers)})
        if not named:
            raise ValueError("a fleet needs at least one cell")
        self.cells: dict[str, _Cell] = {
            name: _Cell(name, server, max_queue_rows)
            for name, server in named.items()}
        self.ring = HashRing(vnodes=vnodes)
        for name in self.cells:
            self.ring.add(name)
        self.limiter = (TokenBucket(rate_limit_rows_per_s, rate_burst,
                                    clock=clock)
                        if rate_limit_rows_per_s is not None else None)
        self.max_poison_retries = int(max_poison_retries)
        self.dead_letters: list[DeadLetter] = []
        self.accepted_count = 0
        self.shed_counts: dict[str, int] = {"rate_limit": 0, "queue_depth": 0}
        self.rerouted_count = 0
        self._requests: dict[int, _FleetRequest] = {}   # unresolved
        self._by_cell_rid: dict[tuple[str, int], int] = {}
        self._next_rid = 0
        self._lock = threading.Lock()
        self._snapshot_hook = snapshot_hook
        self._snapshot_every_s = snapshot_every_s
        self._clock = clock
        self._last_snapshot = clock()

    # ------------------------------------------------------------ admission
    def _admit(self, key: str, n_rows: int) -> _Cell:
        """Front door: rate limit, route, bulkhead check.  Raises
        FleetOverloadError instead of enqueueing when overloaded."""
        if self.limiter is not None and n_rows > 0 \
                and not self.limiter.try_acquire(n_rows):
            with self._lock:        # submit is multi-producer
                self.shed_counts["rate_limit"] += 1
            raise FleetOverloadError(
                f"rate limit: {n_rows} rows rejected at the front door",
                reason="rate_limit")
        cell = self.cells[self.ring.route(key)]
        depth = cell.queue.pending_rows()
        if depth + n_rows > cell.max_queue_rows:
            with self._lock:
                self.shed_counts["queue_depth"] += 1
            raise FleetOverloadError(
                f"cell {cell.name} bulkhead full: {depth} pending rows "
                f"+ {n_rows} > {cell.max_queue_rows}",
                reason="queue_depth", cell=cell.name)
        return cell

    def _record(self, key: str, x, binned: bool, cell: _Cell,
                cell_rid: int) -> int:
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._requests[rid] = _FleetRequest(
                rid=rid, key=key, x=x, binned=binned, cell=cell.name,
                cell_rid=cell_rid)
            self._by_cell_rid[(cell.name, cell_rid)] = rid
            self.accepted_count += 1
        return rid

    def submit(self, x: np.ndarray, *, key: str | None = None,
               binned: bool = False) -> int:
        """Admit one request; returns the fleet request id (resolved by
        ``drain``).  ``key`` is the routing key — stable IDs give sticky
        routing; default is the fleet rid (uniform spread)."""
        x = np.asarray(x)
        n = int(x.shape[1] if binned else x.shape[0])
        with self._lock:
            key = key if key is not None else f"req-{self._next_rid}"
        cell = self._admit(key, n)
        cell_rid = cell.queue.submit(x, binned=binned)
        return self._record(key, x, binned, cell, cell_rid)

    def submit_parties(self, blocks, *, key: str | None = None, salt=None):
        """Per-party request blocks through the same front door: the routed
        cell's fit-time partition re-aligns them on hashed IDs, then the
        aligned rows are admitted (rate limit + bulkhead) as a binned
        request.  Returns ``(rid, ids)`` — ``drain()[rid]`` rows line up
        with ``ids``."""
        from repro.core import crypto
        any_cell = next(iter(self.cells.values()))
        if any_cell.server.partition is None:
            raise ValueError("party-block serving needs the fit-time "
                             "VerticalPartition bound to the cell servers")
        ids, xb = any_cell.server.partition.bin_party_blocks(
            blocks, salt=salt if salt is not None else crypto.DEFAULT_SALT)
        return self.submit(xb, key=key, binned=True), ids

    def serve(self, x: np.ndarray, *, key: str | None = None) -> np.ndarray:
        """Admit + drain one request (the synchronous convenience path)."""
        rid = self.submit(x, key=key)
        return self.drain()[rid]

    # ---------------------------------------------------------------- drain
    def drain(self) -> dict[int, np.ndarray]:
        """Serve every accepted pending request; returns {rid: predictions}.

        Cells drain concurrently (one thread per cell — each pumps its own
        bounded in-flight ring).  Poisoned waves quarantine and solo-retry
        the implicated requests; a cell that fails wholesale (its substrate
        reports parties unavailable beyond what degraded serving covers) is
        drained and its requests re-route.  Every accepted request ends in
        the results dict or the dead-letter sink — never silently lost."""
        results: dict[int, np.ndarray] = {}
        with tracing.TRACER.span("fleet.drain", category="host",
                                 cells=len(self.cells)):
            for _ in range(8 * max(1, len(self.cells))):  # progress-bounded
                active = [c for c in self.cells.values()
                          if c.state == "up" and c.queue.pending_requests()]
                if not active:
                    break
                if len(active) == 1:
                    outcomes = {active[0].name: self._drain_cell(active[0])}
                else:
                    with ThreadPoolExecutor(max_workers=len(active)) as pool:
                        futs = {c.name: pool.submit(self._drain_cell, c)
                                for c in active}
                        outcomes = {n: f.result() for n, f in futs.items()}
                for name, outcome in outcomes.items():
                    self._absorb(self.cells[name], outcome, results)
        self._publish_telemetry()
        self._maybe_snapshot()
        return results

    def _publish_telemetry(self) -> None:
        """Push fleet-level counters into the shared telemetry registry
        (coordinator thread, after a drain pass — reads under ``_lock``
        where the discipline map requires it)."""
        reg = telemetry.REGISTRY
        with self._lock:
            accepted = self.accepted_count
            shed = dict(self.shed_counts)
        reg.gauge("fleet.accepted").set(accepted)
        for reason, n in shed.items():
            reg.gauge(f"fleet.shed.{reason}").set(n)
        reg.gauge("fleet.dead_letters").set(len(self.dead_letters))
        reg.gauge("fleet.rerouted").set(self.rerouted_count)
        reg.gauge("fleet.cells_up").set(
            sum(1 for c in self.cells.values() if c.state == "up"))

    @staticmethod
    def _drain_cell(cell: _Cell):
        """One cell's pump pass; exceptions are data, not control flow."""
        try:
            return cell.queue.drain()
        except (PoisonedWaveError, PartyUnavailableError) as err:
            return err

    def _absorb(self, cell: _Cell, outcome, results: dict) -> None:
        """Fold one cell's drain outcome into fleet state."""
        if isinstance(outcome, dict):
            self._resolve(cell, outcome, results)
            return
        if isinstance(outcome, PoisonedWaveError):
            # requests that retired before the wave failed are done — their
            # answers ride on the error's partial dict
            self._resolve(cell, outcome.partial, results)
        # the queue wraps every pump failure in PoisonedWaveError; a party
        # lost under the cell (PartyUnavailableError on __cause__) is a CELL
        # failure — drain the cell, don't blame the request
        cause = getattr(outcome, "__cause__", None)
        if isinstance(outcome, PartyUnavailableError) \
                or isinstance(cause, PartyUnavailableError):
            self.kill_cell(cell.name)
        else:
            self._quarantine(cell, outcome, results)

    def _resolve(self, cell: _Cell, outs: dict, results: dict) -> None:
        with self._lock:
            for cell_rid, out in outs.items():
                rid = self._by_cell_rid.pop((cell.name, cell_rid), None)
                if rid is None:               # evicted/re-routed meanwhile
                    continue
                self._requests.pop(rid, None)
                results[rid] = out

    def _quarantine(self, cell: _Cell, err: PoisonedWaveError,
                    results: dict) -> None:
        """Evict the implicated requests, then retry each SOLO so the real
        poisoner is identified exactly; dead-letter past the retry budget."""
        suspects = []
        with self._lock:
            for cell_rid in err.rids:
                rid = self._by_cell_rid.pop((cell.name, cell_rid), None)
                if rid is not None:
                    suspects.append(self._requests[rid])
        for req in suspects:
            cell.queue.evict(req.cell_rid)
        for req in suspects:
            self._solo_retry(cell, req, results, err)

    def _solo_retry(self, cell: _Cell, req: _FleetRequest, results: dict,
                    last_err: Exception) -> None:
        while True:
            req.poisons += 1
            if req.poisons > self.max_poison_retries:
                with self._lock:
                    self._requests.pop(req.rid, None)
                self.dead_letters.append(DeadLetter(
                    rid=req.rid, key=req.key, x=req.x, error=last_err,
                    poisons=req.poisons))
                return
            solo = RequestQueue(cell.server)  # nothing else can coalesce in
            solo_rid = solo.submit(req.x, binned=req.binned)
            try:
                out = solo.drain()[solo_rid]
            except PoisonedWaveError as err2:
                last_err = err2
                continue
            with self._lock:
                self._requests.pop(req.rid, None)
            results[req.rid] = out
            return

    # -------------------------------------------------------- cell lifecycle
    def kill_cell(self, name: str) -> int:
        """Drain a cell out of the fleet: it leaves the ring, and every
        accepted, unresolved request it held re-routes onto the surviving
        keyspace (the consistent-hash property keeps everyone else's
        routing unchanged).  Returns the number of re-routed requests.
        Raises if this was the last cell up — a fleet of zero cells cannot
        honour its accepted requests."""
        cell = self.cells[name]
        if cell.state == "down":
            return 0
        survivors = [c for c in self.cells.values()
                     if c.state == "up" and c.name != name]
        if not survivors:
            raise RuntimeError(
                f"cannot drain {name}: it is the last cell up and accepted "
                f"requests would be lost")
        cell.state = "down"
        self.ring.remove(name)
        with self._lock:
            stranded = [r for r in self._requests.values()
                        if r.cell == name]
        moved = 0
        for req in stranded:
            cell.queue.evict(req.cell_rid)
            with self._lock:
                self._by_cell_rid.pop((name, req.cell_rid), None)
            target = self.cells[self.ring.route(req.key)]
            req.cell = target.name
            req.cell_rid = target.queue.submit(req.x, binned=req.binned)
            with self._lock:
                self._by_cell_rid[(target.name, req.cell_rid)] = req.rid
            moved += 1
        self.rerouted_count += moved
        return moved

    def check_health(self) -> dict[str, bool]:
        """Health-check every up cell through its substrate's ``health()``
        seam (PR 6's distributed machinery; in-process substrates have no
        seam and are trivially healthy).  A cell whose substrate reports
        dead parties it cannot serve around — every party down, or any
        party down without ``allow_degraded`` — is drained via
        :meth:`kill_cell`.  Returns {cell: healthy}."""
        out: dict[str, bool] = {}
        for name, cell in list(self.cells.items()):
            if cell.state != "up":
                out[name] = False
                continue
            healthy = True
            probe = getattr(cell.server.substrate, "health", None)
            if probe is not None:
                h = probe()
                dead = [p for p, v in h.items() if v is None]
                if dead:
                    healthy = (cell.server.allow_degraded
                               and len(dead) < len(h))
            out[name] = healthy
            if not healthy:
                self.kill_cell(name)
        return out

    def cells_up(self) -> list[str]:
        return [n for n, c in self.cells.items() if c.state == "up"]

    # ---------------------------------------------------------- observability
    def metrics(self) -> fleet_metrics.FleetMetrics:
        """A fresh FleetMetrics snapshot over every cell (up or down)."""
        pairs = [(fleet_metrics.cell_stats(n, c.state, c.server, c.queue),
                  list(c.server.wave_stats))
                 for n, c in self.cells.items()]
        return fleet_metrics.aggregate(
            pairs, accepted=self.accepted_count, shed=self.shed_counts,
            dead_letters=len(self.dead_letters),
            rerouted=self.rerouted_count)

    def _maybe_snapshot(self) -> None:
        if self._snapshot_hook is None:
            return
        now = self._clock()
        if self._snapshot_every_s is None \
                or now - self._last_snapshot >= self._snapshot_every_s:
            self._last_snapshot = now
            self._snapshot_hook(self.metrics())

    # ------------------------------------------------------------- engines
    def warmup(self) -> "ServingFleet":
        """AOT-compile every up cell's bucket executables."""
        for cell in self.cells.values():
            if cell.state == "up":
                cell.server.warmup()
        return self
