"""Request queue with continuous micro-batching over a serving engine.

Requests of arbitrary row counts are enqueued; ``drain()`` coalesces pending
rows into waves (many small requests share one executable launch; a huge
request spans several) and pumps them through the engine's bucketed,
compile-once path as a **two-phase async pipeline**: fill the bounded
in-flight ring (``dispatch_wave`` — non-blocking, JAX async dispatch), then
collect the oldest wave, scatter its outputs back to the requests it carried
and refill.  While a wave executes on device, the host is coalescing and
padding the next ones — the forest analogue of launch/serve.py's slot-based
continuous batching for the transformer decode loop.  With
``server.max_inflight == 1`` the pump degenerates to the synchronous
dispatch/collect sequence, bit-identically.

Decode is the engine's job (``collect``), so results arrive here already in
their final dtype — including zero-row requests, which retire with the
engine's ``empty_result()`` instead of a locally fabricated array.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from repro.serving.engine import ModelServer


@dataclasses.dataclass
class _Pending:
    rid: int
    x: np.ndarray               # raw (n, F) rows, or binned (M, n, Fp)
    binned: bool
    t_submit: float
    sent: int = 0               # rows dispatched into in-flight waves
    done: int = 0               # rows collected + scattered back
    out: np.ndarray | None = None

    @property
    def n_rows(self) -> int:
        return int(self.x.shape[1] if self.binned else self.x.shape[0])

    def party_rows(self, server: ModelServer, start: int,
                   take: int) -> np.ndarray:
        """(M, take, Fp) party rows for one span — raw requests bin HERE,
        inside the pump, so binning of wave i+1 overlaps device execution
        of wave i instead of serializing at submit time."""
        if self.binned:
            return self.x[:, start:start + take]
        return server._prep(self.x[start:start + take])


class RequestQueue:
    """FIFO queue of prediction requests over one serving engine."""

    def __init__(self, server: ModelServer, max_wave_rows: int | None = None):
        self.server = server
        self.max_wave_rows = max_wave_rows or server.buckets[-1]
        self._pending: list[_Pending] = []
        self._next_id = 0
        # bounded, like the server's wave_stats: no per-request leak
        self.request_stats: collections.deque = collections.deque(maxlen=4096)

    def submit(self, x: np.ndarray, *, binned: bool = False) -> int:
        """Enqueue one request; returns its id (resolved by drain()).

        Raw requests are NOT binned here — binning happens span-by-span in
        the drain pump, overlapped with in-flight device execution.  Binned
        requests are shape-validated up front, so one bad request can't
        poison the pump for everything queued behind it."""
        x = np.asarray(x)
        if binned:
            if x.ndim != 3 or x.shape[0] != self.server.n_parties:
                raise ValueError(
                    f"binned request must be ({self.server.n_parties}, "
                    f"rows, Fp), got {x.shape}")
            self.server._check_fp(x.shape[2])
        p = _Pending(self._next_id, x, bool(binned), time.perf_counter())
        self._pending.append(p)
        self._next_id += 1
        return p.rid

    def submit_parties(self, blocks, *, salt=None):
        """Enqueue one request arriving as per-party blocks keyed by sample
        IDs (PartyBlocks/DataSources, matched to fit-time parties by name;
        rows may be shuffled or superset — they are re-aligned on hashed IDs
        and non-common rows dropped before the rows enter the pump).

        Returns ``(request_id, ids)``: ``drain()[request_id]`` rows line up
        with ``ids`` (the canonical aligned ordering).  Alignment + binning
        happen at submit time — the request must be pinned to an ID ordering
        before its rows can coalesce into waves."""
        from repro.core import crypto
        if self.server.partition is None:
            raise ValueError("party-block requests need the fit-time "
                             "VerticalPartition bound to the server")
        ids, xb = self.server.partition.bin_party_blocks(
            blocks, salt=salt if salt is not None else crypto.DEFAULT_SALT)
        return self.submit(xb, binned=True), ids

    def _next_wave(self):
        """Coalesce the next wave across request boundaries (host phase).

        Returns ((M, rows, Fp) array, [(pending, start, take), ...]) or
        (None, None) when every pending row is already in flight."""
        cap = min(self.max_wave_rows, self.server.buckets[-1])
        wave, spans, rows = [], [], 0
        for p in self._pending:
            remaining = p.n_rows - p.sent
            if remaining == 0:          # fully dispatched (or zero-row)
                continue
            take = min(remaining, cap - rows)
            if take == 0:               # wave is full
                break
            wave.append(p.party_rows(self.server, p.sent, take))
            spans.append((p, p.sent, take))
            p.sent += take
            rows += take
        if not wave:
            return None, None
        return np.concatenate(wave, axis=1), spans

    def _scatter(self, out: np.ndarray, spans) -> None:
        """Write one collected wave's (decoded) rows back to its requests."""
        lo = 0
        for p, start, take in spans:
            seg = out[lo:lo + take]
            if p.out is None:
                p.out = np.empty(p.n_rows, seg.dtype)
            p.out[start:start + take] = seg
            p.done += take
            lo += take

    def _retire(self, results: dict[int, np.ndarray]) -> None:
        still = []
        for p in self._pending:
            if p.done == p.n_rows:
                if p.out is None:       # zero-row request: engine dtype
                    p.out = self.server.empty_result()
                results[p.rid] = p.out
                self.request_stats.append({
                    "rid": p.rid, "rows": int(p.done),
                    "latency_s": time.perf_counter() - p.t_submit})
            else:
                still.append(p)
        self._pending = still

    def drain(self) -> dict[int, np.ndarray]:
        """Serve everything pending; returns {request_id: predictions}.

        Two-phase pump: (1) fill the in-flight ring with coalesced waves —
        each ``dispatch_wave`` returns without blocking; (2) collect the
        oldest wave, scatter its rows, retire finished requests, refill.
        The ring bound (``server.max_inflight``) is the backpressure: at
        most K waves of host memory + device work are ever outstanding."""
        results: dict[int, np.ndarray] = {}
        ring: collections.deque = collections.deque()
        k = self.server.max_inflight
        try:
            while True:
                while len(ring) < k:                # phase 1: fill
                    wave, spans = self._next_wave()
                    if wave is None:
                        break
                    ring.append((self.server.dispatch_wave(wave), spans))
                if not ring:                        # nothing in flight:
                    self._retire(results)           # zero-row stragglers
                    break
                handle, spans = ring.popleft()      # phase 2: collect
                self._scatter(self.server.collect(handle), spans)
                self._retire(results)
        except BaseException:
            # a failed dispatch/collect discards the local ring: drain the
            # already-launched waves (keeps the server's in-flight counter
            # honest) and make dispatched-but-unserved rows eligible for
            # re-dispatch, or the next drain() silently strands them
            self.server.abandon(handle for handle, _ in ring)
            for p in self._pending:
                p.sent = p.done
            raise
        return results
