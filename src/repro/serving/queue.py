"""Request queue with continuous micro-batching over a serving engine.

Requests of arbitrary row counts are enqueued; ``drain()`` coalesces pending
rows into waves (many small requests share one executable launch; a huge
request spans several) and pumps them through the engine's bucketed,
compile-once path as a **two-phase async pipeline**: fill the bounded
in-flight ring (``dispatch_wave`` — non-blocking, JAX async dispatch), then
collect the oldest wave, scatter its outputs back to the requests it carried
and refill.  While a wave executes on device, the host is coalescing and
padding the next ones — the forest analogue of launch/serve.py's slot-based
continuous batching for the transformer decode loop.  With
``server.max_inflight == 1`` the pump degenerates to the synchronous
dispatch/collect sequence, bit-identically.

Decode is the engine's job (``collect``), so results arrive here already in
their final dtype — including zero-row requests, which retire with the
engine's ``empty_result()`` instead of a locally fabricated array.

Concurrency contract: ``submit``/``submit_parties`` are safe from any number
of producer threads (a fleet cell's normal case — serving/fleet.py fans
requests in from the router while the cell drains); ``drain`` is single-
consumer — one drainer per queue at a time.  A failure inside the pump is
surfaced as :class:`PoisonedWaveError` carrying the ids of the requests
whose rows were in the failing wave, so a front door can quarantine the
poisoner instead of wedging the whole cell on a retry loop.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

import numpy as np

from repro.observability import registry as telemetry
from repro.observability import trace as tracing
from repro.serving.engine import ModelServer


class PoisonedWaveError(RuntimeError):
    """A wave failed inside the pump — binning, dispatch, or collect.

    ``rids`` are the ids of the requests whose rows were implicated:
    exactly one for a binning (``_prep``) failure, every request coalesced
    into the wave for a dispatch/collect failure (``stage`` says which).
    The original exception rides on ``__cause__``.  Rows already rolled
    back to re-dispatchable when this propagates — a retry drain serves
    everything that is still pending.  ``partial`` holds the results of
    requests that RETIRED before the failure (they are no longer pending,
    so a caller that drops ``partial`` drops their answers)."""

    def __init__(self, msg: str, *, rids, stage: str):
        super().__init__(msg)
        self.rids = tuple(rids)
        self.stage = stage
        self.partial: dict = {}


@dataclasses.dataclass
class _Pending:
    rid: int
    x: np.ndarray               # raw (n, F) rows, or binned (M, n, Fp)
    binned: bool
    t_submit: float
    sent: int = 0               # rows dispatched into in-flight waves
    done: int = 0               # rows collected + scattered back
    out: np.ndarray | None = None

    @property
    def n_rows(self) -> int:
        return int(self.x.shape[1] if self.binned else self.x.shape[0])

    def party_rows(self, server: ModelServer, start: int,
                   take: int) -> np.ndarray:
        """(M, take, Fp) party rows for one span — raw requests bin HERE,
        inside the pump, so binning of wave i+1 overlaps device execution
        of wave i instead of serializing at submit time."""
        if self.binned:
            return self.x[:, start:start + take]
        return server._prep(self.x[start:start + take])


class RequestQueue:
    """FIFO queue of prediction requests over one serving engine.

    ``submit`` is multi-producer thread-safe; ``drain`` is the single
    pump thread by contract (the `_Pending` objects it mutates in place —
    sent/done/out row spans — are only ever touched by that one drainer).

    Lock discipline (checked by repro.analysis rules/locks):
        _lock: _pending, _next_id, request_stats
    """

    def __init__(self, server: ModelServer, max_wave_rows: int | None = None):
        self.server = server
        self.max_wave_rows = max_wave_rows or server.buckets[-1]
        self._pending: list[_Pending] = []
        self._next_id = 0
        # multi-producer seam: submit() from concurrent threads must not
        # interleave partially (rid allocation, the width-binding check in
        # _check_fp, and the enqueue are one atomic step); drain's structural
        # mutations of _pending take the same lock
        self._lock = threading.Lock()
        # bounded, like the server's wave_stats: no per-request leak
        self.request_stats: collections.deque = collections.deque(maxlen=4096)
        # bound once; _retire runs per drained request
        self._m_requests = telemetry.REGISTRY.counter("serving.requests")
        self._m_req_latency = telemetry.REGISTRY.histogram(
            "serving.request_latency_s")
        self._m_depth = telemetry.REGISTRY.gauge("serving.queue_depth_rows")

    def submit(self, x: np.ndarray, *, binned: bool = False) -> int:
        """Enqueue one request; returns its id (resolved by drain()).

        Raw requests are NOT binned here — binning happens span-by-span in
        the drain pump, overlapped with in-flight device execution.  Binned
        requests are shape-validated up front, so one bad request can't
        poison the pump for everything queued behind it.  Thread-safe."""
        x = np.asarray(x)
        with self._lock:
            if binned:
                if x.ndim != 3 or x.shape[0] != self.server.n_parties:
                    raise ValueError(
                        f"binned request must be ({self.server.n_parties}, "
                        f"rows, Fp), got {x.shape}")
                self.server._check_fp(x.shape[2])
            p = _Pending(self._next_id, x, bool(binned), time.perf_counter())
            self._pending.append(p)
            self._next_id += 1
            return p.rid

    def submit_parties(self, blocks, *, salt=None):
        """Enqueue one request arriving as per-party blocks keyed by sample
        IDs (PartyBlocks/DataSources, matched to fit-time parties by name;
        rows may be shuffled or superset — they are re-aligned on hashed IDs
        and non-common rows dropped before the rows enter the pump).

        Returns ``(request_id, ids)``: ``drain()[request_id]`` rows line up
        with ``ids`` (the canonical aligned ordering).  Alignment + binning
        happen at submit time — the request must be pinned to an ID ordering
        before its rows can coalesce into waves."""
        from repro.core import crypto
        if self.server.partition is None:
            raise ValueError("party-block requests need the fit-time "
                             "VerticalPartition bound to the server")
        ids, xb = self.server.partition.bin_party_blocks(
            blocks, salt=salt if salt is not None else crypto.DEFAULT_SALT)
        return self.submit(xb, binned=True), ids

    # -------------------------------------------------------- bulkhead seams
    def pending_rows(self) -> int:
        """Rows accepted but not yet fully served — the queue-depth a
        bulkhead sheds on (serving/fleet.py's admission check)."""
        with self._lock:
            return sum(p.n_rows - p.done for p in self._pending)

    def pending_requests(self) -> int:
        with self._lock:
            return len(self._pending)

    def evict(self, rid: int) -> np.ndarray | None:
        """Remove a pending request from the queue (dead-lettering a
        poisoner, or re-routing off a drained cell).  Returns the request
        payload (raw or binned, as submitted) or None if the rid is not
        pending.  Must not be called while a drain is mid-pump."""
        with self._lock:
            for i, p in enumerate(self._pending):
                if p.rid == rid:
                    del self._pending[i]
                    return p.x
        return None

    # ------------------------------------------------------------- the pump
    def _next_wave(self):
        """Coalesce the next wave across request boundaries (host phase).

        Returns ((M, rows, Fp) array, [(pending, start, take), ...]) or
        (None, None) when every pending row is already in flight.  A
        binning failure is attributed to the exact request being binned."""
        cap = min(self.max_wave_rows, self.server.buckets[-1])
        wave, spans, rows = [], [], 0
        with self._lock:
            pending = list(self._pending)
        for p in pending:
            remaining = p.n_rows - p.sent
            if remaining == 0:          # fully dispatched (or zero-row)
                continue
            take = min(remaining, cap - rows)
            if take == 0:               # wave is full
                break
            try:
                wave.append(p.party_rows(self.server, p.sent, take))
            except Exception as err:
                raise PoisonedWaveError(
                    f"request {p.rid} failed to bin: {err}",
                    rids=(p.rid,), stage="bin") from err
            spans.append((p, p.sent, take))
            p.sent += take
            rows += take
        if not wave:
            return None, None
        return np.concatenate(wave, axis=1), spans

    def _scatter(self, out: np.ndarray, spans) -> None:
        """Write one collected wave's (decoded) rows back to its requests."""
        lo = 0
        for p, start, take in spans:
            seg = out[lo:lo + take]
            if p.out is None:
                p.out = np.empty(p.n_rows, seg.dtype)
            p.out[start:start + take] = seg
            p.done += take
            lo += take

    def _retire(self, results: dict[int, np.ndarray]) -> None:
        with self._lock:
            still = []
            for p in self._pending:
                if p.done == p.n_rows:
                    if p.out is None:   # zero-row request: engine dtype
                        p.out = self.server.empty_result()
                    results[p.rid] = p.out
                    latency = time.perf_counter() - p.t_submit
                    self.request_stats.append({
                        "rid": p.rid, "rows": int(p.done),
                        "latency_s": latency})
                    self._m_requests.inc()
                    self._m_req_latency.observe(latency)
                else:
                    still.append(p)
            self._pending = still

    def drain(self) -> dict[int, np.ndarray]:
        """Serve everything pending; returns {request_id: predictions}.

        Two-phase pump: (1) fill the in-flight ring with coalesced waves —
        each ``dispatch_wave`` returns without blocking; (2) collect the
        oldest wave, scatter its rows, retire finished requests, refill.
        The ring bound (``server.max_inflight``) is the backpressure: at
        most K waves of host memory + device work are ever outstanding.

        A failure anywhere in the pump propagates as
        :class:`PoisonedWaveError` naming the implicated request ids, with
        every dispatched-but-unserved row rolled back to re-dispatchable —
        nothing is stranded, nothing is silently dropped."""
        results: dict[int, np.ndarray] = {}
        ring: collections.deque = collections.deque()
        k = self.server.max_inflight
        drain_span = tracing.TRACER.begin("queue.drain", category="host")
        try:
            while True:
                while len(ring) < k:                # phase 1: fill
                    wave, spans = self._next_wave()
                    if wave is None:
                        break
                    try:
                        handle = self.server.dispatch_wave(wave)
                    except Exception as err:
                        raise PoisonedWaveError(
                            f"wave of requests "
                            f"{[p.rid for p, _, _ in spans]} failed to "
                            f"dispatch: {err}",
                            rids=[p.rid for p, _, _ in spans],
                            stage="dispatch") from err
                    ring.append((handle, spans))
                if not ring:                        # nothing in flight:
                    self._retire(results)           # zero-row stragglers
                    break
                handle, spans = ring.popleft()      # phase 2: collect
                try:
                    out = self.server.collect(handle)
                except Exception as err:
                    raise PoisonedWaveError(
                        f"wave of requests "
                        f"{[p.rid for p, _, _ in spans]} failed to collect: "
                        f"{err}",
                        rids=[p.rid for p, _, _ in spans],
                        stage="collect") from err
                self._scatter(out, spans)
                self._retire(results)
        except BaseException as err:
            # a failed dispatch/collect discards the local ring: drain the
            # already-launched waves (keeps the server's in-flight counter
            # honest) and make dispatched-but-unserved rows eligible for
            # re-dispatch, or the next drain() silently strands them
            self.server.abandon(handle for handle, _ in ring)
            with self._lock:
                for p in self._pending:
                    p.sent = p.done
            if isinstance(err, PoisonedWaveError):
                # requests retired before the failure are no longer pending;
                # their answers ride out on the error
                err.partial = dict(results)
            raise
        finally:
            if drain_span is not None:
                drain_span.set(requests=len(results))
                tracing.TRACER.finish(drain_span)
            self._m_depth.set(self.pending_rows())
        return results
