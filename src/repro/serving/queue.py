"""Request queue with continuous micro-batching over a ForestServer.

Requests of arbitrary row counts are enqueued; ``drain()`` coalesces pending
rows into waves (many small requests share one executable launch; a huge
request spans several), serves them through the engine's bucketed,
compile-once path, and scatters each wave's outputs back to the requests it
carried — the forest analogue of launch/serve.py's slot-based continuous
batching for the transformer decode loop.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from repro.serving.engine import ForestServer


@dataclasses.dataclass
class _Pending:
    rid: int
    xb_parts: np.ndarray        # (M, n, Fp) binned party rows
    t_submit: float
    done: int = 0               # rows already served
    out: np.ndarray | None = None


class RequestQueue:
    """FIFO queue of prediction requests over one ForestServer."""

    def __init__(self, server: ForestServer, max_wave_rows: int | None = None):
        self.server = server
        self.max_wave_rows = max_wave_rows or server.buckets[-1]
        self._pending: list[_Pending] = []
        self._next_id = 0
        # bounded, like the server's wave_stats: no per-request leak
        self.request_stats: collections.deque = collections.deque(maxlen=4096)

    def submit(self, x: np.ndarray, *, binned: bool = False) -> int:
        """Enqueue one request; returns its id (resolved by drain())."""
        if binned:
            xb = np.asarray(x)
        else:
            if self.server.partition is None:
                raise ValueError("raw submit needs a server partition")
            xb = self.server.partition.bin_test(np.asarray(x))
        p = _Pending(self._next_id, xb, time.perf_counter())
        self._pending.append(p)
        self._next_id += 1
        return p.rid

    def drain(self) -> dict[int, np.ndarray]:
        """Serve everything pending; returns {request_id: predictions}."""
        results: dict[int, np.ndarray] = {}
        while self._pending:
            # ---- coalesce the next wave across request boundaries --------
            wave, spans, rows = [], [], 0
            for p in self._pending:
                remaining = p.xb_parts.shape[1] - p.done
                if remaining == 0:          # zero-row request: retire below
                    continue
                take = min(remaining, self.max_wave_rows - rows)
                if take == 0:               # wave is full
                    break
                wave.append(p.xb_parts[:, p.done:p.done + take])
                spans.append((p, p.done, take))
                rows += take
            if wave:
                out = self.server.serve_binned(np.concatenate(wave, axis=1))
                lo = 0
                for p, start, take in spans:
                    seg = out[lo:lo + take]
                    if p.out is None:
                        p.out = np.empty(p.xb_parts.shape[1], seg.dtype)
                    p.out[start:start + take] = seg
                    p.done += take
                    lo += take
            # ---- retire completed requests -------------------------------
            still = []
            for p in self._pending:
                if p.done == p.xb_parts.shape[1]:
                    if p.out is None:       # zero-row request
                        dt = (np.int32 if self.server.params.task
                              == "classification" else np.float32)
                        p.out = np.empty((0,), dt)
                    out_p = p.out
                    if self.server.decode is not None:
                        out_p = self.server.decode(out_p)
                    results[p.rid] = out_p
                    self.request_stats.append({
                        "rid": p.rid, "rows": int(p.done),
                        "latency_s": time.perf_counter() - p.t_submit})
                else:
                    still.append(p)
            self._pending = still
        return results
