"""Federated forest inference serving engine.

Turns the paper's one-round prediction protocol (§4.2, Prop. 1) into a
servable system, in three pieces:

  * ``plan``   — LeafTable: per-tree live-leaf index tables.  A deep heap is
    mostly dead slots, so the membership mask, its single psum, and the vote
    contraction are gathered over live leaves (bit-identical outputs — the
    intersection semantics do not change, only which columns are carried).
  * ``engine`` — bucket / pad / compile-once / async waves.  Traffic arrives
    in arbitrary batch sizes; the server pads each request up to a small set
    of row buckets (default 32/256/2048) and AOT-compiles one executable per
    bucket, so steady-state serving never recompiles (``compile_count`` is
    the proof).  Waves dispatch asynchronously through a bounded in-flight
    ring (``max_inflight``): host binning/coalescing/padding of wave i+1
    overlaps device execution of wave i, bit-identically to the sync path.
    One ``ModelServer`` core serves every family — ``ForestServer`` (the
    paper's one-round protocol), ``BoostingServer``, ``LinearServer`` —
    behind ``Federation.serve``'s dispatch.  Execution is the same SPMD
    protocol as training: ``run_simulated`` (vmap) on one host, or shard_map
    over a (trees, parties) mesh with the ``aggregate=False`` per-tree hook
    and the forest vote as the caller-side cross-shard reduction.
  * ``autotune`` — bucket sets learned from observed traffic (wave /
    request row-count quantiles) instead of hardcoded guesses; the
    compile-once contract holds per autotune epoch.
  * ``queue``  — RequestQueue: continuous micro-batching.  Pending requests
    coalesce into waves across request boundaries (many small requests share
    one launch; a huge one spans several), pumped two-phase through the
    async ring, like launch/serve.py's slot-based batching for the
    transformer decode path.
  * ``fleet``  — ServingFleet: N replicated server cells behind one front
    door — consistent-hash routing, token-bucket admission, per-cell
    bulkheads with typed shedding, poison quarantine + dead-letter sink,
    and cell kill/health-fail → keyspace redistribution with zero lost
    accepted requests.
  * ``metrics``— per-cell wave stats rolled up into FleetMetrics (pooled
    percentiles, busy-interval throughput, shed/dead-letter/degraded
    counters) with alert thresholds and a periodic snapshot hook.

Entry points: ``Federation.serve`` / ``Federation.serve_fleet`` (the session
API — pre-binds the mesh and keeps the LeafTable plan fresh across model
updates), ``launch/serve_forest.py`` + ``launch/fleet_demo.py`` (CLI traffic
drivers) and ``benchmarks/serving_bench.py`` (dense vs leaf-compacted and
fleet-vs-single-cell rows/s, p50/p95/p99).
"""
from repro.serving.autotune import autotune_buckets, observed_row_counts  # noqa: F401
from repro.serving.config import ServeConfig  # noqa: F401
from repro.serving.engine import (BoostingServer, ForestServer,  # noqa: F401
                                  InFlightWave, LinearServer, ModelServer,
                                  load_forest_trees, server_for)
from repro.serving.fleet import (DeadLetter, FleetOverloadError,  # noqa: F401
                                 HashRing, ServingFleet, TokenBucket)
from repro.serving.metrics import (AlertThresholds, CellStats,  # noqa: F401
                                   FleetMetrics, alerts)
from repro.serving.plan import LeafTable, build_leaf_table  # noqa: F401
from repro.serving.queue import PoisonedWaveError, RequestQueue  # noqa: F401
