"""Federated forest inference serving engine.

Turns the paper's one-round prediction protocol (§4.2, Prop. 1) into a
servable system, in three pieces:

  * ``plan``   — LeafTable: per-tree live-leaf index tables.  A deep heap is
    mostly dead slots, so the membership mask, its single psum, and the vote
    contraction are gathered over live leaves (bit-identical outputs — the
    intersection semantics do not change, only which columns are carried).
  * ``engine`` — ForestServer: bucket / pad / compile-once.  Traffic arrives
    in arbitrary batch sizes; the server pads each request up to a small set
    of row buckets (default 32/256/2048) and AOT-compiles one executable per
    bucket, so steady-state serving never recompiles (``compile_count`` is
    the proof).  Oversized requests run as micro-batched waves of the
    largest bucket; per-wave latency/throughput/psum-bytes land in
    ``wave_stats``.  Execution is the same SPMD protocol as training:
    ``run_simulated`` (vmap) on one host, or shard_map over a
    (trees, parties) mesh with the ``aggregate=False`` per-tree hook and the
    forest vote as the caller-side cross-shard reduction.
  * ``queue``  — RequestQueue: continuous micro-batching.  Pending requests
    coalesce into waves across request boundaries (many small requests share
    one launch; a huge one spans several), like launch/serve.py's slot-based
    batching for the transformer decode path.

Entry points: ``Federation.serve`` (the session API — pre-binds the mesh and
keeps the LeafTable plan fresh across model updates),
``launch/serve_forest.py`` (CLI traffic driver) and
``benchmarks/serving_bench.py`` (dense vs leaf-compacted rows/s, p50/p95).
"""
from repro.serving.engine import ForestServer, load_forest_trees  # noqa: F401
from repro.serving.plan import LeafTable, build_leaf_table  # noqa: F401
from repro.serving.queue import RequestQueue  # noqa: F401
