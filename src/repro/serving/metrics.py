"""Fleet observability: per-cell wave stats rolled up into FleetMetrics.

A serving fleet (serving/fleet.py) is only operable if one surface answers
"is the fleet healthy and how close to the edge is it?"  This module is that
surface:

  * :class:`CellStats`   — one cell's snapshot: wave counts, latency
    percentiles, queue depth (the bulkhead's fill level), degraded waves,
    psum payload bytes, compile count, and the cell's routing state.
  * :class:`FleetMetrics`— the fleet rollup: latency percentiles pooled over
    every cell's raw per-wave latencies (not an average of averages),
    throughput over the union of busy intervals across cells (concurrent
    cells overlap by design — summing per-cell rows/s would double-count
    idle time), plus the front-door counters: accepted / shed (by reason) /
    dead-lettered / re-routed.
  * :class:`AlertThresholds` + :func:`alerts` — configurable trip wires
    (p99 latency, queue depth, shed and dead-letter counts, cells down)
    evaluated against a snapshot; returns human-readable alert lines.

Cells that have served nothing yet aggregate cleanly: ``ModelServer.stats``
returns a well-formed zero record, and pooled percentiles simply skip empty
cells.  ``ServingFleet.metrics()`` builds these; a periodic snapshot hook
(``snapshot_hook=``/``snapshot_every_s=``) pushes them to whatever sink the
deployment uses (a print, a log shipper, a TSDB writer).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def busy_seconds(spans) -> float:
    """Union length of [t0, t1) wave intervals — the honest denominator for
    throughput when waves overlap (async rings, concurrent cells)."""
    busy, end = 0.0, float("-inf")
    for s, e in sorted(spans):
        if e > end:
            busy += e - max(s, end)
            end = e
    return busy


def _percentiles(latencies_s) -> tuple[float, float, float]:
    if len(latencies_s) == 0:
        return 0.0, 0.0, 0.0
    lat = np.asarray(latencies_s, float)
    p50, p95, p99 = np.percentile(lat, (50, 95, 99))
    return float(p50 * 1e3), float(p95 * 1e3), float(p99 * 1e3)


@dataclasses.dataclass(frozen=True)
class CellStats:
    """One cell's observability snapshot (derived, not live state)."""

    name: str
    state: str                   # "up" | "draining" | "down"
    waves: int
    rows: int
    p50_ms: float
    p95_ms: float
    p99_ms: float
    rows_per_s: float
    queue_depth_rows: int        # bulkhead fill: accepted, not yet served
    queue_depth_requests: int
    degraded_waves: int          # waves answered from surviving trees (PR 6)
    comm_bytes: int              # psum payload over recorded waves
    compile_count: int


@dataclasses.dataclass(frozen=True)
class FleetMetrics:
    """Fleet-level rollup of every cell plus the front-door counters."""

    cells: tuple[CellStats, ...]
    waves: int
    rows: int
    rows_per_s: float            # pooled busy-interval throughput
    p50_ms: float                # percentiles over POOLED wave latencies
    p95_ms: float
    p99_ms: float
    queue_depth_rows: int
    accepted: int
    shed: dict                   # reason -> count ("rate_limit", "queue_depth")
    dead_letters: int
    rerouted: int                # accepted requests moved off a drained cell
    degraded_waves: int
    comm_bytes: int
    cells_up: int
    cells_down: int

    @property
    def shed_total(self) -> int:
        return sum(self.shed.values())


def cell_stats(name: str, state: str, server, queue) -> CellStats:
    """Snapshot one cell from its engine + queue (zero-wave cells produce a
    well-formed zero record — ModelServer.stats guarantees the shape)."""
    s = server.stats()
    return CellStats(
        name=name, state=state, waves=s["waves"], rows=s["rows"],
        p50_ms=s["p50_ms"], p95_ms=s["p95_ms"], p99_ms=s["p99_ms"],
        rows_per_s=s["rows_per_s"],
        queue_depth_rows=queue.pending_rows(),
        queue_depth_requests=queue.pending_requests(),
        degraded_waves=sum(1 for w in server.wave_stats if w.get("degraded")),
        comm_bytes=s["comm_bytes_total"], compile_count=s["compile_count"])


def aggregate(cells, *, accepted: int, shed: dict, dead_letters: int,
              rerouted: int) -> FleetMetrics:
    """Roll per-cell (CellStats, wave_stats) pairs up into FleetMetrics.

    ``cells`` is a sequence of (CellStats, wave_stats-iterable) so the
    percentiles and the busy-interval union come from the raw per-wave
    records, not from already-reduced per-cell summaries."""
    stats = tuple(cs for cs, _ in cells)
    waves = [w for _, ws in cells for w in ws]
    p50, p95, p99 = _percentiles([w["latency_s"] for w in waves])
    busy = busy_seconds((w["t0"], w["t0"] + w["latency_s"]) for w in waves)
    rows = sum(w["n_rows"] for w in waves)
    return FleetMetrics(
        cells=stats,
        waves=len(waves), rows=rows,
        rows_per_s=rows / max(busy, 1e-12) if waves else 0.0,
        p50_ms=p50, p95_ms=p95, p99_ms=p99,
        queue_depth_rows=sum(c.queue_depth_rows for c in stats),
        accepted=accepted, shed=dict(shed), dead_letters=dead_letters,
        rerouted=rerouted,
        degraded_waves=sum(c.degraded_waves for c in stats),
        comm_bytes=sum(c.comm_bytes for c in stats),
        cells_up=sum(1 for c in stats if c.state == "up"),
        cells_down=sum(1 for c in stats if c.state == "down"))


@dataclasses.dataclass(frozen=True)
class AlertThresholds:
    """Trip wires for :func:`alerts`; None disables a check."""

    p99_ms: float | None = None
    queue_depth_rows: int | None = None
    shed_total: int | None = None
    dead_letters: int | None = None
    cells_down: int | None = 1      # any down cell alerts by default


def alerts(m: FleetMetrics,
           t: AlertThresholds = AlertThresholds()) -> list[str]:
    """Evaluate a snapshot against thresholds; one line per tripped wire."""
    out = []
    if t.p99_ms is not None and m.p99_ms > t.p99_ms:
        out.append(f"p99 latency {m.p99_ms:.1f}ms > {t.p99_ms:.1f}ms")
    if t.queue_depth_rows is not None \
            and m.queue_depth_rows > t.queue_depth_rows:
        out.append(f"queue depth {m.queue_depth_rows} rows > "
                   f"{t.queue_depth_rows}")
    if t.shed_total is not None and m.shed_total > t.shed_total:
        out.append(f"shed {m.shed_total} requests "
                   f"({', '.join(f'{k}={v}' for k, v in sorted(m.shed.items()))})")
    if t.dead_letters is not None and m.dead_letters > t.dead_letters:
        out.append(f"{m.dead_letters} dead-lettered requests")
    if t.cells_down is not None and m.cells_down >= t.cells_down:
        down = [c.name for c in m.cells if c.state == "down"]
        out.append(f"{m.cells_down} cells down ({', '.join(down)})")
    return out
