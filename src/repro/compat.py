"""Version-compatibility shims over drifting jax APIs.

The repo pins ``jax==0.4.37`` (requirements.txt), but the source is written
against the modern spellings (``jax.shard_map``, ``jax.set_mesh``,
positional ``AbstractMesh(sizes, names)``) so an upgrade is a no-op.  Every
call site goes through this module instead of feature-testing jax inline.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True) -> Callable:
    """``jax.shard_map`` (>=0.6) or ``jax.experimental.shard_map`` (0.4.x).

    ``check_vma`` maps onto the old ``check_rep`` flag — same meaning
    (verify collective/replication consistency of the body).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def abstract_mesh(axis_sizes: tuple, axis_names: tuple):
    """``AbstractMesh`` across the 0.4->0.7 constructor change.

    New jax takes ``(sizes, names)`` positionally; 0.4.x takes a single
    tuple of ``(name, size)`` pairs.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(tuple(axis_names), tuple(axis_sizes))))


def set_mesh(mesh) -> contextlib.AbstractContextManager:
    """Context manager installing ``mesh`` for sharding-constraint resolution.

    ``jax.set_mesh`` on new jax; on 0.4.x a concrete ``Mesh`` is itself the
    resource-env context manager that gives ``with_sharding_constraint``
    its axis names.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def axis_size(axis_name: str) -> Any:
    """Size of a mapped SPMD axis from inside the mapped function.

    ``lax.axis_size`` only exists on newer jax; ``psum`` of the literal 1 is
    the portable spelling and constant-folds at trace time (no collective in
    the lowered program).
    """
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)
