"""AdamW + LR schedule, implemented directly on pytrees (no optax on box).

Optimizer state shards exactly like the params (the dry-run relies on this:
mu/nu inherit the param PartitionSpecs).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def adamw_init(params: Params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, state: dict, *, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.01) -> tuple[Params, dict]:
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * gf
        nu = b2 * nu + (1 - b2) * gf * gf
        upd_ = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd_ + weight_decay * pf)
        return pf.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}


def cosine_lr(step: jnp.ndarray, *, peak: float, warmup: int, total: int,
              floor_frac: float = 0.1) -> jnp.ndarray:
    sf = step.astype(jnp.float32)
    warm = peak * sf / max(warmup, 1)
    prog = jnp.clip((sf - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(sf < warmup, warm, cos)
