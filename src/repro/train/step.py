"""Training step: microbatched grad accumulation + AdamW.

Global batches (256 × 4k tokens) can't materialize logits in one shot; the
step scans over microbatches accumulating f32 grads — the standard
production pattern, and what the dry-run lowers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.train import optim

Params = Any


def make_train_step(cfg: ArchConfig, *, micro_batch: int = 0, lr: float = 3e-4):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Remat is governed by cfg.remat (per-unit checkpoint inside the scan)."""

    def one_grad(params, mb):
        (loss, (ce, aux)), g = jax.value_and_grad(
            transformer.lm_loss, has_aux=True)(params, mb, cfg)
        del aux
        return loss, ce, g

    def train_step(params, opt_state, batch):
        b = batch["tokens"].shape[0]
        mb_size = micro_batch or b
        n_micro = max(b // mb_size, 1)
        if n_micro == 1:
            loss, ce, grads = one_grad(params, batch)
        else:
            mbs = jax.tree.map(
                lambda a: a.reshape(n_micro, mb_size, *a.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g_acc, l_acc, c_acc = carry
                loss, ce, g = one_grad(params, mb)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss, c_acc + ce), None

            (grads, loss, ce), _ = jax.lax.scan(
                body, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss, ce = loss / n_micro, ce / n_micro

        params, opt_state = optim.adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "ce": ce}

    return train_step
