from repro.data.tabular import (DATASETS, make_classification,  # noqa: F401
                                make_party_views, make_regression,
                                load_dataset)
