from repro.data.tabular import DATASETS, make_classification, make_regression, load_dataset  # noqa: F401
