"""Synthetic language-modeling data pipeline.

Markov-chain token streams with learnable structure (so cross-entropy has
signal to descend) + the modality stubs (frames/patches) the audio/VLM
architectures consume.  Deterministic per seed; an infinite generator, the
shape a real pipeline (pygrain etc.) would have.
"""
from __future__ import annotations

from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def _markov_tokens(rng: np.random.Generator, vocab: int, shape,
                   order_states: int = 64) -> np.ndarray:
    """Tokens from a sparse random Markov chain over `order_states` states."""
    trans = rng.integers(0, vocab, size=(order_states, 8))
    state = rng.integers(0, order_states, size=shape[0])
    out = np.empty(shape, np.int32)
    for t in range(shape[1]):
        choice = rng.integers(0, 8, size=shape[0])
        out[:, t] = trans[state, choice]
        state = (out[:, t] + choice) % order_states
    return out


def synthetic_lm_batches(cfg: ArchConfig, batch: int, seq: int, *,
                         seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        b = {"tokens": jnp.asarray(_markov_tokens(rng, cfg.vocab, (batch, seq)))}
        if cfg.enc_layers:
            b["frames"] = jnp.asarray(
                rng.normal(size=(batch, cfg.enc_frames, cfg.d_model)) * 0.1,
                jnp.dtype(cfg.dtype))
        if cfg.n_patches:
            b["patches"] = jnp.asarray(
                rng.normal(size=(batch, cfg.n_patches, cfg.d_model)) * 0.1,
                jnp.dtype(cfg.dtype))
        yield b
