"""Metrics + the paper's Z-test (no scipy/sklearn on the box)."""
from __future__ import annotations

import math

import numpy as np


def accuracy(y_true, y_pred) -> float:
    return float(np.mean(np.asarray(y_true) == np.asarray(y_pred)))


def f1_binary(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true), np.asarray(y_pred)
    tp = float(np.sum((y_pred == 1) & (y_true == 1)))
    fp = float(np.sum((y_pred == 1) & (y_true == 0)))
    fn = float(np.sum((y_pred == 0) & (y_true == 1)))
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom else 0.0


def rmse(y_true, y_pred) -> float:
    d = np.asarray(y_true, dtype=np.float64) - np.asarray(y_pred, dtype=np.float64)
    return float(np.sqrt(np.mean(d * d)))


def _phi(z: float) -> float:
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


def ztest_two_sample(a, b) -> tuple[float, float]:
    """Two-sample Z-test (paper §5.2): H0: means equal. Returns (z, p)."""
    a, b = np.asarray(a, dtype=np.float64), np.asarray(b, dtype=np.float64)
    se = math.sqrt(a.var(ddof=1) / len(a) + b.var(ddof=1) / len(b))
    if se == 0.0:
        return 0.0, 1.0
    z = (a.mean() - b.mean()) / se
    return float(z), float(2.0 * (1.0 - _phi(abs(z))))
