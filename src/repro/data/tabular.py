"""Tabular data pipeline: synthetic analogues of the paper's benchmark suite.

The container is offline, so the UCI files themselves are unavailable.  We
generate synthetic datasets with the same (n_samples, n_features, n_classes /
target-range) signatures as the paper's Table 2, using a blob+rotation
generative process (informative low-rank subspace, redundant mixtures, noise
features) so that feature importance is spread across the vertical partition —
the regime the paper's experiments probe.  Sizes of the two huge sets
(kdd cup 99: 4M, year prediction: 515k, target marketing: 156k) are scaled
down to CPU-tractable sizes; the *shape* of the conclusions (parity of FF vs
NonFF, scaling of prediction cost) does not depend on n.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def make_classification(n: int, f: int, n_classes: int = 2, *,
                        n_informative: int | None = None, class_sep: float = 1.2,
                        seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    ni = n_informative or max(2, f // 4)
    ni = min(ni, f)
    centers = rng.normal(scale=class_sep, size=(n_classes, ni))
    y = rng.integers(0, n_classes, size=n)
    xi = centers[y] + rng.normal(size=(n, ni))
    mix = rng.normal(size=(ni, f)) / np.sqrt(ni)  # spread info across columns
    x = xi @ mix + 0.5 * rng.normal(size=(n, f))
    return x.astype(np.float64), y.astype(np.int64)


def make_regression(n: int, f: int, *, n_informative: int | None = None,
                    noise: float = 0.5, nonlinear: bool = True, seed: int = 0
                    ) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    ni = n_informative or max(2, f // 4)
    ni = min(ni, f)
    x = rng.normal(size=(n, f))
    w = rng.normal(size=ni)
    y = x[:, :ni] @ w
    if nonlinear:
        y = y + np.sin(2.0 * x[:, 0]) * np.abs(w).sum() * 0.3 + 0.5 * x[:, 1] * x[:, 2 % f]
    y = y + noise * rng.normal(size=n)
    return x.astype(np.float64), y.astype(np.float64)


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    task: str
    n: int          # scaled-down where the paper's set is huge (see module doc)
    f: int
    n_classes: int = 2
    paper_n: int | None = None   # the paper's Table 2 size, for the record


# the paper's Table 2, with CPU-tractable sizes
DATASETS: dict[str, DatasetSpec] = {
    "target_marketing": DatasetSpec("target_marketing", "classification", 8000, 95, 2, 156198),
    "ionosphere":       DatasetSpec("ionosphere", "classification", 351, 34, 2),
    "spambase":         DatasetSpec("spambase", "classification", 4601, 57, 2),
    "parkinson":        DatasetSpec("parkinson", "classification", 756, 754, 2),
    "kdd_cup_99":       DatasetSpec("kdd_cup_99", "classification", 8000, 41, 2, 4_000_000),
    "waveform":         DatasetSpec("waveform", "classification", 5000, 21, 3),
    "gene":             DatasetSpec("gene", "classification", 801, 2000, 5, None),
    "year_prediction":  DatasetSpec("year_prediction", "regression", 8000, 90, 0, 515_345),
    "superconduct":     DatasetSpec("superconduct", "regression", 8000, 81, 0, 21_263),
}


def make_party_views(x, y=None, n_parties: int = 3, *, overlap: float = 0.75,
                     contiguous: bool = True, shuffle: bool = True,
                     label_party: int = 0, seed: int = 0,
                     salt: str | None = None):
    """Fabricate realistic per-party views of a dense dataset: shuffled,
    partially-overlapping regional extracts for party-first ingestion tests
    and benchmarks.

    Every party receives its own feature columns for (a) a common core of
    ``overlap * n`` samples shared by all parties and (b) a disjoint slice
    of the remaining samples only it holds — so the M-party ID intersection
    is exactly the core.  Each party's rows are independently shuffled and
    keyed by string sample IDs; ``label_party`` carries the labels.

    Returns ``(blocks, x_aligned, y_aligned)`` where the aligned pair is
    the **equivalent centrally pre-aligned dataset**: the core rows in
    canonical order (sorted by hashed ID — exactly the ordering
    party-block ingestion aligns to).  Fitting from ``blocks`` is
    bit-identical to fitting from ``Federation(seed=seed).ingest(x_aligned,
    y_aligned, contiguous=contiguous)`` (tests/test_partyblock.py asserts
    it): blocks carry ``feature_ids`` from the same ``assign_features``
    draw the raw-matrix adapter makes with this ``seed``.
    """
    from repro.core import crypto
    from repro.core.party import assign_features
    from repro.core.partyblock import PartyBlock
    x = np.asarray(x)
    n, f = x.shape
    if not 0.0 < overlap <= 1.0:
        raise ValueError(f"overlap must be in (0, 1], got {overlap}")
    groups = assign_features(f, n_parties, contiguous=contiguous,
                             rng=np.random.default_rng(seed))
    rng = np.random.default_rng([seed, 104729])  # own stream: never collides
    perm = rng.permutation(n)                    # with the features draw
    core = perm[: max(1, int(round(overlap * n)))]
    extras = np.array_split(perm[len(core):], n_parties)
    ids = np.array([f"u{i:07d}" for i in range(n)])
    blocks = []
    for i, g in enumerate(groups):
        rows = np.concatenate([core, extras[i]])
        if shuffle:
            rows = rows[np.random.default_rng([seed, i, 7])
                        .permutation(len(rows))]
        blocks.append(PartyBlock(
            name=f"party{i:03d}", x=x[rows][:, g], ids=ids[rows],
            y=None if y is None or i != label_party else np.asarray(y)[rows],
            feature_ids=g))
    salt = crypto.DEFAULT_SALT if salt is None else salt
    aligned = core[np.argsort(crypto.hash_ids(ids[core], salt=salt))]
    return blocks, x[aligned], (None if y is None
                                else np.asarray(y)[aligned])


def load_dataset(name: str, seed: int = 0):
    spec = DATASETS[name]
    if spec.task == "classification":
        x, y = make_classification(spec.n, spec.f, spec.n_classes, seed=seed)
    else:
        x, y = make_regression(spec.n, spec.f, seed=seed)
    return x, y, spec


def train_test_split(x, y, test_frac: float = 0.25, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(y)
    perm = rng.permutation(n)
    cut = int(n * (1 - test_frac))
    tr, te = perm[:cut], perm[cut:]
    return x[tr], y[tr], x[te], y[te]
