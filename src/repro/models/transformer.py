"""Model assembly: pattern-unit scan over heterogeneous block stacks.

A model is ``embed -> scan(pattern units) -> tail blocks -> norm -> lm_head``
where a *unit* is one repetition of ``cfg.pattern`` (e.g. zamba2's
5×mamba2 + 1×shared-attn).  Scanning stacked unit params keeps HLO size and
compile time O(1) in depth.  Weight-tied blocks (``attn_shared``) live
outside the scan and are closed over — one copy of the weights, per-unit KV
caches.

Three entry points, matching the assigned input shapes:
  * ``forward_train``  — full-sequence causal logits (train_4k);
  * ``prefill``        — logits for the last position + a populated cache
                         (prefill_32k);
  * ``decode_step``    — ONE token against a ring-buffer cache
                         (decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, ssm
from repro.models.layers import AttnMode, attention, mlp, moe, rmsnorm

Params = dict[str, Any]


# ------------------------------------------------------------------- init
def _init_block(key, kind: str, cfg: ArchConfig, *, bidir: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    if kind in ("attn", "attn_shared"):
        p: Params = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": layers.init_attention(ks[0], cfg),
        }
        if cfg.n_experts and not bidir:
            p["ffn"] = layers.init_moe(ks[1], cfg)
        else:
            p["ffn"] = layers.init_mlp(ks[1], cfg)
        if cfg.cross_attention and not bidir:
            p["ln_cross"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["cross"] = layers.init_attention(ks[2], cfg)
        return p
    if kind == "mamba2":
        return {"ln": jnp.ones((cfg.d_model,), jnp.float32),
                **{"core": ssm.init_mamba2(ks[0], cfg)}}
    if kind == "mlstm":
        return {"ln": jnp.ones((cfg.d_model,), jnp.float32),
                "core": ssm.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"ln": jnp.ones((cfg.d_model,), jnp.float32),
                "core": ssm.init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def init_params(key, cfg: ArchConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8 + len(cfg.pattern))
    d, v = cfg.d_model, cfg.vocab
    params: Params = {
        "embed": (jax.random.normal(keys[0], (v, d), jnp.float32) * 0.02).astype(dt),
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": (jax.random.normal(keys[1], (d, v), jnp.float32)
                    / math.sqrt(d)).astype(dt),
    }
    # scanned units: stack per pattern position
    units: Params = {}
    for j, kind in enumerate(cfg.pattern):
        if kind == "attn_shared":
            units[f"blk{j}"] = {}
            continue
        sub = jax.random.split(keys[2 + j], cfg.n_units)
        units[f"blk{j}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_block(k, kind, cfg) for k in sub])
    params["units"] = units
    if cfg.tail_blocks:
        tk = jax.random.split(keys[-1], len(cfg.tail_blocks))
        params["tail"] = [
            _init_block(tk[i], kind, cfg) if kind != "attn_shared" else {}
            for i, kind in enumerate(cfg.tail_blocks)]
    if "attn_shared" in cfg.pattern or "attn_shared" in cfg.tail_blocks:
        params["shared_attn"] = _init_block(keys[-2], "attn", cfg)
    if cfg.enc_layers:
        ek = jax.random.split(keys[-3], cfg.enc_layers)
        params["enc"] = {"units": {"blk0": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_block(k, "attn", cfg, bidir=True) for k in ek])}}
    if cfg.n_patches:
        params["vision_proj"] = (jax.random.normal(keys[-4], (d, d), jnp.float32)
                                 / math.sqrt(d)).astype(dt)
    return params


# ---------------------------------------------------------------- context
@dataclasses.dataclass
class Ctx:
    phase: str                      # "train" | "prefill" | "decode"
    positions: jnp.ndarray          # (B,S) or (3,B,S) rope positions
    pos: Optional[jnp.ndarray]      # decode: absolute position scalar
    shared_params: Optional[Params] = None
    enc_out: Optional[jnp.ndarray] = None
    bidir: bool = False
    cache_len: Optional[int] = None   # prefill: cache capacity headroom


# ------------------------------------------------------------ block apply
def apply_block(kind: str, bp: Params, x: jnp.ndarray, cfg: ArchConfig,
                ctx: Ctx, cache: Optional[Params]):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_shared"):
        p = ctx.shared_params if kind == "attn_shared" else bp
        mode = AttnMode("bidir" if ctx.bidir else "causal",
                        window=cfg.sliding_window)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        out, self_cache = attention(
            p["attn"], h, cfg, mode=mode, positions=ctx.positions,
            cache=None if cache is None else cache.get("self"), pos=ctx.pos,
            cache_len=ctx.cache_len, phase=ctx.phase)
        # named for the "attn_out" remat policy: saving this (B,S,H·dh)
        # tensor lets the backward pass skip recomputing the S×S chain
        from jax.ad_checkpoint import checkpoint_name
        out = checkpoint_name(out, "attn_out")
        x = x + out
        new_cache: Params = {"self": self_cache}
        if "cross" in p and not ctx.bidir:
            h = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
            out, cross_cache = attention(
                p["cross"], h, cfg, mode=AttnMode("cross"),
                positions=ctx.positions,
                cache=None if cache is None else cache.get("cross"),
                kv_src=ctx.enc_out, phase=ctx.phase)
            x = x + out
            new_cache["cross"] = cross_cache
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.n_experts and not ctx.bidir:
            out, aux = moe(p["ffn"], h, cfg)
        else:
            out = mlp(p["ffn"], h)
        return x + out, new_cache, aux

    h = rmsnorm(x, bp["ln"], cfg.norm_eps)
    fn = {"mamba2": ssm.mamba2_block, "mlstm": ssm.mlstm_block,
          "slstm": ssm.slstm_block}[kind]
    out, new_cache = fn(bp["core"], h, cfg, cache=cache)
    return x + out, new_cache, aux


def _run_stack(params: Params, x: jnp.ndarray, cfg: ArchConfig, ctx: Ctx,
               cache: Optional[Params], pattern: tuple[str, ...],
               units_key: str = "units", tail: bool = True):
    """Scan over stacked pattern units, then the unscanned tail."""
    units = params[units_key]
    aux_total = jnp.zeros((), jnp.float32)

    def unit_body(carry, xs):
        h, aux_acc = carry
        up, ucache = xs
        new_caches = {}
        for j, kind in enumerate(pattern):
            c_j = None if ucache is None else ucache.get(f"blk{j}")
            h, nc, aux = apply_block(kind, up[f"blk{j}"], h, cfg, ctx, c_j)
            new_caches[f"blk{j}"] = nc
            aux_acc = aux_acc + aux
        return (h, aux_acc), new_caches

    if ctx.phase == "train" and cfg.remat != "none":
        policy = {
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            "attn_out": jax.checkpoint_policies.save_only_these_names(
                "attn_out"),
        }.get(cfg.remat)
        unit_body = jax.checkpoint(unit_body, policy=policy)

    ucache = None if cache is None else cache[units_key]
    n_units = len(cfg.pattern) and (cfg.n_units if units_key == "units"
                                    else cfg.enc_layers)
    if n_units > 0:
        if ucache is None:
            (x, aux_total), new_ucache = jax.lax.scan(
                lambda c, p: unit_body((c[0], c[1]), (p, None)),
                (x, aux_total), units)
        else:
            (x, aux_total), new_ucache = jax.lax.scan(
                unit_body, (x, aux_total), (units, ucache))
    else:
        new_ucache = {}

    new_tail = []
    if tail and cfg.tail_blocks and units_key == "units":
        tcache = None if cache is None else cache.get("tail")
        for i, kind in enumerate(cfg.tail_blocks):
            c_i = None if tcache is None else tcache[i]
            x, nc, aux = apply_block(kind, params["tail"][i], x, cfg, ctx, c_i)
            new_tail.append(nc)
            aux_total = aux_total + aux
    return x, {units_key: new_ucache, "tail": new_tail}, aux_total


# ----------------------------------------------------------------- embeds
def _positions_for(cfg: ArchConfig, batch: int, seq: int,
                   offset) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections is None:
        return pos
    # M-RoPE: vision prefix uses an (h, w) grid with t=0; text advances t.
    p = cfg.n_patches
    g = max(1, int(math.sqrt(max(p, 1))))
    idx = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    is_text = idx >= p
    t = jnp.where(is_text, idx - p, 0)
    hpos = jnp.where(is_text, idx - p, jnp.clip(idx, 0, p - 1) // g)
    wpos = jnp.where(is_text, idx - p, jnp.clip(idx, 0, p - 1) % g)
    return jnp.broadcast_to(jnp.stack([t, hpos, wpos]), (3, batch, seq))


def _embed(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
           extras: Optional[Params], offset=0) -> jnp.ndarray:
    x = params["embed"][tokens]
    if cfg.n_patches and extras is not None and "patches" in extras:
        proj = jnp.einsum("bpd,de->bpe", extras["patches"].astype(x.dtype),
                          params["vision_proj"])
        x = jnp.concatenate([proj, x[:, cfg.n_patches:]], axis=1)
    if cfg.rope_theta == 0:  # whisper: sinusoidal absolute positions
        pos = jnp.arange(tokens.shape[1], dtype=jnp.int32) + offset
        x = x + layers.sinusoidal_positions(pos, cfg.d_model)[None].astype(x.dtype)
    return x


def _encode(params: Params, frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, enc_frames, D)."""
    x = frames + layers.sinusoidal_positions(
        jnp.arange(frames.shape[1]), cfg.d_model)[None].astype(frames.dtype)
    ctx = Ctx(phase="train", positions=jnp.zeros((1, 1), jnp.int32), pos=None,
              bidir=True)
    x, _, _ = _run_stack(params, x, cfg, ctx, None, ("attn",),
                         units_key="enc_units", tail=False)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


# ------------------------------------------------------------ entry points
def forward_train(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                  extras: Optional[Params] = None):
    """(B,S) tokens -> (B,S,V) logits, aux loss."""
    b, s = tokens.shape
    enc_out = None
    if cfg.enc_layers:
        enc_out = _encode({"enc_units": params["enc"]["units"],
                           "enc_norm": params["final_norm"]},
                          extras["frames"], cfg)
    x = _embed(params, tokens, cfg, extras)
    ctx = Ctx(phase="train", positions=_positions_for(cfg, b, s, 0), pos=None,
              shared_params=params.get("shared_attn"), enc_out=enc_out)
    x, _, aux = _run_stack(params, x, cfg, ctx, None, cfg.pattern)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, aux


def prefill(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
            extras: Optional[Params] = None,
            cache_len: Optional[int] = None):
    """Populate caches; return (last-position logits, cache)."""
    b, s = tokens.shape
    enc_out = None
    if cfg.enc_layers:
        enc_out = _encode({"enc_units": params["enc"]["units"],
                           "enc_norm": params["final_norm"]},
                          extras["frames"], cfg)
    x = _embed(params, tokens, cfg, extras)
    ctx = Ctx(phase="prefill", positions=_positions_for(cfg, b, s, 0), pos=None,
              shared_params=params.get("shared_attn"), enc_out=enc_out,
              cache_len=cache_len)
    x, cache, _ = _run_stack(params, x, cfg, ctx, _empty_cache_like(cfg), cfg.pattern)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits[:, 0], cache


def decode_step(params: Params, cache: Params, token: jnp.ndarray,
                pos: jnp.ndarray, cfg: ArchConfig):
    """One token (B,1) + ring-buffer cache -> (logits (B,V), new cache)."""
    b = token.shape[0]
    x = _embed(params, token, cfg, None, offset=pos)
    positions = _positions_for(cfg, b, 1, pos)
    ctx = Ctx(phase="decode", positions=positions, pos=pos,
              shared_params=params.get("shared_attn"))
    x, new_cache, _ = _run_stack(params, x, cfg, ctx, cache, cfg.pattern)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits[:, 0], new_cache


# ------------------------------------------------------------------ cache
def _empty_cache_like(cfg: ArchConfig):
    """Sentinel: prefill builds its cache from scratch (no cache inputs) —
    but apply_block still needs a mapping to .get() from."""
    return None


def _block_cache(kind: str, cfg: ArchConfig, batch: int, cap: int) -> Params:
    if kind in ("attn", "attn_shared"):
        c: Params = {"self": layers.init_attn_cache(cfg, batch, cap)}
        if cfg.cross_attention:
            dt = jnp.dtype(cfg.dtype)
            c["cross"] = {
                "k": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads,
                                cfg.head_dim), dt),
                "v": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads,
                                cfg.head_dim), dt)}
        return c
    if kind == "mamba2":
        return ssm.init_mamba2_cache(cfg, batch)
    if kind == "mlstm":
        return ssm.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return ssm.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def make_cache(cfg: ArchConfig, batch: int, seq_len: int) -> Params:
    """Decode cache for a context of ``seq_len`` (capacity = window if SWA)."""
    cap = seq_len if cfg.sliding_window is None else min(cfg.sliding_window,
                                                         seq_len)
    units = {}
    for j, kind in enumerate(cfg.pattern):
        one = _block_cache(kind, cfg, batch, cap)
        units[f"blk{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_units, *a.shape)), one)
    tail = [_block_cache(kind, cfg, batch, cap) for kind in cfg.tail_blocks]
    return {"units": units, "tail": tail}


# ------------------------------------------------------------------- loss
def lm_loss(params: Params, batch: Params, cfg: ArchConfig):
    logits, aux = forward_train(params, batch["tokens"], cfg,
                                extras={k: v for k, v in batch.items()
                                        if k not in ("tokens",)})
    labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], -1)[..., 0]
    mask = jnp.ones_like(gold).at[:, -1].set(0.0)
    ce = ((lse - gold) * mask).sum() / mask.sum()
    return ce + 0.01 * aux, (ce, aux)
