"""Dense building blocks: RMSNorm, RoPE/M-RoPE, GQA attention (causal /
sliding-window / bidirectional / cross), SwiGLU MLP, capacity-based MoE.

Conventions:
  * params are nested dicts of jnp arrays; inits take (key, cfg);
  * activations (B, S, D); attention is query-chunked (exact, lax.map over
    q blocks) so S×S score tensors are never fully materialized — the pure
    JAX analogue of the Pallas flash kernel, and what the dry-run lowers;
  * KV caches are ring buffers {k, v, kpos}: ``kpos`` records the absolute
    position held in each slot, which uniformly handles full-cache decode
    (capacity = seq_len) and sliding-window decode (capacity = window).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = dict[str, Any]
ATTN_Q_CHUNK = 1024


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _dense_init(key, shape, scale_axis=0):
    scale = 1.0 / math.sqrt(shape[scale_axis])
    return jax.random.normal(key, shape, jnp.float32) * scale


# ----------------------------------------------------------------- RMSNorm
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (n * scale.astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(dh: int, theta: float) -> jnp.ndarray:
    return theta ** (-jnp.arange(0, dh // 2, dtype=jnp.float32) / (dh // 2))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               sections: Optional[tuple[int, int, int]] = None) -> jnp.ndarray:
    """x: (B, S, H, dh). positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the dh/2 rotary frequencies are split into (t, h, w)
    sections, each rotated by its own position stream.
    """
    b, s, h, dh = x.shape
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    if sections is None:
        ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,dh/2)
    else:
        if positions.ndim != 3:
            raise ValueError(f"M-RoPE needs (3, B, S) positions, got ndim={positions.ndim}")
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            p = positions[i].astype(jnp.float32)[..., None]
            parts.append(p * freqs[start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, -1)                 # (B,S,dh/2)
    cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """(..., ) int positions -> (..., d) sinusoidal embedding (whisper)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * math.log(10000.0) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


# --------------------------------------------------------------- attention
@dataclasses.dataclass
class AttnMode:
    kind: str                      # "causal" | "bidir" | "cross"
    window: Optional[int] = None


def init_attention(key, cfg: ArchConfig) -> Params:
    d, dh, h, kh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * dh)),
        "wk": _dense_init(ks[1], (d, kh * dh)),
        "wv": _dense_init(ks[2], (d, kh * dh)),
        "wo": _dense_init(ks[3], (h * dh, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return jax.tree.map(lambda a: a.astype(_dtype(cfg)), p)


def _sdpa_chunked(q, k, v, mode: AttnMode, q_offset, kpos,
                  probs_bf16: bool = False, scores_bf16: bool = False,
                  pretranspose: bool = True):
    """q: (B,Sq,H,dh); k,v: (B,Sk,Kh,dh); kpos: (Sk,) absolute key positions
    (-1 = empty slot). Query-chunked exact attention; GQA via head grouping.

    §Perf: the S×S scores chain (scores matmul -> mask+softmax fusion ->
    probs matmul) dominates HBM traffic for long-sequence training; the
    bf16 knobs halve what is *materialized* between the two matmuls while
    the softmax itself stays in f32 registers (the Pallas flash kernel is
    the TPU deployment path that removes the chain entirely)."""
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = dh ** -0.5
    qg = q.reshape(b, sq, kh, g, dh)
    score_dt = jnp.bfloat16 if scores_bf16 else jnp.float32

    # k/v pre-transposed ONCE outside the chunk loop (k-sized, cheap) so the
    # scores einsums are layout-native — without this XLA inserts transposes
    # of the S×S scores tensor, ~15% of all HBM traffic (§Perf profile).
    # Training-only: at prefill/decode the transposed full-sequence copies
    # raise peak residency (§Perf found +22 GiB/dev on qwen3 prefill_32k),
    # and there the chain is traversed once so the transpose win is smaller.
    if pretranspose:
        kt = k.transpose(0, 2, 1, 3)                      # (B,Kh,Sk,dh)
        vt = v.transpose(0, 2, 1, 3)
    else:
        kt, vt = k, v

    def chunk(qc_and_pos):
        qc, qpos = qc_and_pos                             # (B,cq,Kh,G,dh), (cq,)
        if pretranspose:
            qt = qc.transpose(0, 2, 3, 1, 4)              # (B,Kh,G,cq,dh) small
            s = jnp.einsum("bkgqd,bksd->bkgqs", qt.astype(score_dt),
                           kt.astype(score_dt),
                           preferred_element_type=score_dt)
        else:  # v1 formulation: lowest peak residency (prefill/decode)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc.astype(score_dt),
                           kt.astype(score_dt),
                           preferred_element_type=score_dt)
        s = s * jnp.asarray(scale, score_dt)
        valid = kpos[None, :] >= 0
        if mode.kind in ("causal",):
            valid &= kpos[None, :] <= qpos[:, None]
        if mode.window is not None:
            valid &= kpos[None, :] > qpos[:, None] - mode.window
        s = jnp.where(valid[None, None, None], s, jnp.asarray(-1e30, score_dt))
        if scores_bf16:
            # manual softmax with bf16 STORAGE: the max/sum reductions and
            # the exp run in f32 transiently inside fusions, but every
            # materialized S×S tensor is bf16 (halves the chain's traffic)
            m = s.max(-1, keepdims=True).astype(jnp.float32)
            p = jnp.exp(s.astype(jnp.float32) - m).astype(jnp.bfloat16)
            denom = p.astype(jnp.float32).sum(-1, keepdims=True)
            p = (p.astype(jnp.float32) / jnp.maximum(denom, 1e-30)
                 ).astype(jnp.bfloat16)
        else:
            p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
            if probs_bf16:  # §Perf: halve the HBM-resident probs
                p = p.astype(jnp.bfloat16)
        if pretranspose:
            out = jnp.einsum("bkgqs,bksd->bkgqd", p, vt.astype(p.dtype),
                             preferred_element_type=jnp.float32)
            return out.transpose(0, 3, 1, 2, 4)           # (B,cq,Kh,G,dh)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, vt.astype(p.dtype),
                          preferred_element_type=jnp.float32)

    cq = min(ATTN_Q_CHUNK, sq)
    qpos_all = q_offset + jnp.arange(sq)
    if sq > cq:
        pad = -sq % cq                  # pad q so every seq length chunks
        qp = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        pp = jnp.pad(qpos_all, (0, pad), constant_values=-(10 ** 9))
        nc = qp.shape[1] // cq
        qs = qp.reshape(b, nc, cq, kh, g, dh).transpose(1, 0, 2, 3, 4, 5)
        out = jax.lax.map(chunk, (qs, pp.reshape(nc, cq)))  # (nc,B,cq,Kh,G,dh)
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nc * cq, h, dh)[:, :sq]
    else:
        out = chunk((qg, qpos_all)).reshape(b, sq, h, dh)
    return out.astype(q.dtype)


def attention(params: Params, x: jnp.ndarray, cfg: ArchConfig, *,
              mode: AttnMode, positions: jnp.ndarray,
              cache: Optional[Params] = None, pos: Optional[jnp.ndarray] = None,
              kv_src: Optional[jnp.ndarray] = None,
              cache_len: Optional[int] = None, phase: str = "train"):
    """Returns (out, new_cache). Modes:
       * train/prefill: cache=None in, cache built when ``build_cache``;
       * decode: cache given, x is (B,1,D), pos is the absolute position;
       * cross: kv_src supplies encoder states (cached k/v reused if given).
    """
    b, s, d = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", src, params["wk"]).reshape(b, src.shape[1], kh, dh)
    v = jnp.einsum("bsd,de->bse", src, params["wv"]).reshape(b, src.shape[1], kh, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)

    use_rope = cfg.rope_theta > 0 and mode.kind != "cross"
    if mode.kind == "cross":
        if cache is not None:  # decode: reuse projected encoder k/v
            k, v = cache["k"], cache["v"]
        kpos = jnp.arange(k.shape[1])
        out = _sdpa_chunked(q, k, v, AttnMode("bidir"), 0, kpos,
                            cfg.attn_probs_bf16, cfg.attn_scores_bf16,
                            pretranspose=(phase == "train"))
        new_cache = {"k": k, "v": v}
    elif cache is None:   # train / prefill (self-attention)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        kpos = jnp.arange(s)
        out = _sdpa_chunked(q, k, v, mode, 0, kpos, cfg.attn_probs_bf16,
                            cfg.attn_scores_bf16,
                            pretranspose=(phase == "train"))
        cap = s if cache_len is None else cache_len
        if mode.window is not None:
            cap = min(cap, mode.window)
        keep = min(cap, s)
        # ring invariant: position p lives in slot p % cap — align the kept
        # tail so subsequent decode steps evict the true oldest. When the
        # alignment is the identity (cap == s, the prefill_32k case) the
        # slice aliases k/v directly — the scatter variant cost +7 GiB/dev
        # peak residency (§Perf).
        shift = (s - keep) % cap
        tail_pos = jnp.arange(s - keep, s, dtype=jnp.int32)
        if keep == cap and shift == 0:
            kb, vb = k[:, s - keep:], v[:, s - keep:]
            kposb = tail_pos
        else:
            idx = jnp.arange(s - keep, s) % cap
            kb = jnp.zeros((b, cap) + k.shape[2:], k.dtype).at[:, idx].set(
                k[:, s - keep:])
            vb = jnp.zeros((b, cap) + v.shape[2:], v.dtype).at[:, idx].set(
                v[:, s - keep:])
            kposb = jnp.full((cap,), -1, jnp.int32).at[idx].set(tail_pos)
        new_cache = {"k": kb, "v": vb, "kpos": kposb}
    else:                 # decode (self-attention, ring-buffer cache)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        cap = cache["k"].shape[1]
        slot = pos % cap
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        ckpos = jax.lax.dynamic_update_slice(
            cache["kpos"], pos[None].astype(jnp.int32), (slot,))
        out = _sdpa_chunked(q, ck, cv, mode, pos, ckpos,
                            cfg.attn_probs_bf16, cfg.attn_scores_bf16,
                            pretranspose=False)
        new_cache = {"k": ck, "v": cv, "kpos": ckpos}
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * dh), params["wo"])
    return y, new_cache


def init_attn_cache(cfg: ArchConfig, batch: int, cap: int):
    dt = _dtype(cfg)
    return {
        "k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dt),
        "kpos": jnp.full((cap,), -1, jnp.int32),
    }


# --------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wg": _dense_init(ks[0], (d, f)), "wu": _dense_init(ks[1], (d, f)),
         "wd": _dense_init(ks[2], (f, d))}
    return jax.tree.map(lambda a: a.astype(_dtype(cfg)), p)


def mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["wg"]).astype(jnp.float32))
    up = jnp.einsum("bsd,df->bsf", x, params["wu"])
    return jnp.einsum("bsf,fd->bsd", (gate * up.astype(jnp.float32)).astype(x.dtype),
                      params["wd"])


def _constrain(x, spec):
    """with_sharding_constraint that degrades to identity when no mesh is
    set (single-device smoke tests)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def _padded_experts(cfg: ArchConfig) -> int:
    e = cfg.n_experts
    return (e + 15) // 16 * 16 if cfg.pad_experts else e


# --------------------------------------------------------------------- MoE
def init_moe(key, cfg: ArchConfig) -> Params:
    d, e = cfg.d_model, cfg.n_experts
    ep = _padded_experts(cfg)
    fe = cfg.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": _dense_init(ks[0], (d, e)),
        "we_gate": _dense_init(ks[1], (ep, d, fe), scale_axis=1),
        "we_up": _dense_init(ks[2], (ep, d, fe), scale_axis=1),
        "we_down": _dense_init(ks[3], (ep, fe, d), scale_axis=1),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, cfg.n_shared_experts * fe)
    return jax.tree.map(lambda a: a.astype(_dtype(cfg)), p)


def moe(params: Params, x: jnp.ndarray, cfg: ArchConfig):
    """Capacity-based top-k routing with sort-based grouping.

    FLOPs are honest (E × capacity × d × d_ff with capacity ≈ T·k/E·factor),
    unlike dense all-experts dispatch.  Returns (y, aux_loss).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, k)              # (t, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(t * k / e * cfg.moe_capacity))
    flat_e = idx.reshape(-1)                              # (t*k,)
    order = jnp.argsort(flat_e)                           # group tokens by expert
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    rank = jnp.arange(t * k) - seg_start[sorted_e]        # position within expert
    keep = rank < cap
    # slot table (e, cap) -> flattened (token,k) index; sentinel t*k = empty.
    # Dropped tokens scatter into a dump slot (e*cap) that is sliced away.
    # Padded (dead) experts get all-empty rows — the router never emits them.
    e_pad = params["we_gate"].shape[-3]
    slot_id = sorted_e * cap + jnp.clip(rank, 0, cap - 1)
    slots = jnp.full((e_pad * cap + 1,), t * k, jnp.int32)
    slots = slots.at[jnp.where(keep, slot_id, e_pad * cap)].set(
        jnp.where(keep, order, t * k).astype(jnp.int32))
    slots = slots[: e_pad * cap].reshape(e_pad, cap)
    tok_of_slot = jnp.clip(slots // k, 0, t - 1)
    slot_valid = slots < t * k

    xe = jnp.where(slot_valid[..., None], xf[tok_of_slot], 0)   # (e, cap, d)
    if cfg.moe_shard_acts:
        # §Perf: without constraints GSPMD replicates the dispatch tensors
        # (88 GiB/dev for qwen2-moe prefill). Expert dim -> 'model' when it
        # divides the 16-way axis, capacity -> 'data'.
        espec = "model" if cfg.n_experts % 16 == 0 else None
        cspec = "data" if espec == "model" else ("data", "model")
        xe = _constrain(xe, jax.sharding.PartitionSpec(espec, cspec, None))
    gate_ff = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["we_gate"])
                          .astype(jnp.float32))
    up = jnp.einsum("ecd,edf->ecf", xe, params["we_up"])
    ye = jnp.einsum("ecf,efd->ecd", (gate_ff * up.astype(jnp.float32)
                                     ).astype(x.dtype), params["we_down"])
    if cfg.moe_shard_acts:
        ye = _constrain(ye, jax.sharding.PartitionSpec(espec, cspec, None))
    # combine: scatter-add back with gate weights
    wslot = jnp.where(slot_valid, gate_vals.reshape(-1)[jnp.clip(slots, 0, t * k - 1)], 0)
    y = jnp.zeros((t + 1, d), ye.dtype).at[
        jnp.where(slot_valid, tok_of_slot, t)].add(
        (ye * wslot[..., None]).astype(ye.dtype))[:t]

    if "shared" in params:
        y = y + mlp(params["shared"], xf[None])[0]
    # load-balance aux loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((e,)).at[flat_e].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d).astype(x.dtype), aux
