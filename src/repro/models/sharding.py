"""Sharding rules: parameter / optimizer / activation / cache PartitionSpecs.

Strategy (DESIGN.md §6): 2-D "FSDP × tensor" sharding for every large matrix —
one dim on ``model`` (tensor/expert parallel), the other on ``data`` (FSDP),
so that optimizer state (f32 mu/nu = 6 bytes/param extra) fits HBM for the
40B-scale configs.  Anything small or non-divisible is replicated — the
roofline pass tells us which of those choices matter.

Rules are *path-based* (leaf names are stable API), with divisibility checks
against the actual mesh axis sizes; non-divisible dims fall back to
replication rather than relying on GSPMD padding.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any

# leaf name -> (spec builder) ; d = data axis name, m = model axis name
_MATRIX_RULES = {
    # (in, out) 2D projections: FSDP on in-dim, tensor on out-dim
    "wq": ("d", "m"), "wk": ("d", "m"), "wv": ("d", "m"),
    "wg": ("d", "m"), "wu": ("d", "m"), "w_in": ("d", "m"),
    "in_proj": ("d", "m"), "wi": ("d", "m"), "wf": ("d", "m"),
    # row-parallel outputs
    "wo": ("m", "d"), "wd": ("m", "d"), "out_proj": ("m", "d"),
    # square-ish
    "vision_proj": ("d", "m"),
    "lm_head": ("d", "m"),          # vocab on model => sharded logits/softmax
    "embed": ("m", "d"),            # vocab on model
}


def _axis_ok(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _spec_for_matrix(shape, rule, axes: dict[str, Any], sizes: dict[str, int]):
    """Apply a 2-trailing-dim rule with divisibility fallback; leading dims
    (scan stacking, expert dim) get None."""
    lead = [None] * (len(shape) - 2)
    din, dout = shape[-2], shape[-1]
    a_in = (axes[rule[0]] if axes[rule[0]] is not None
            and _axis_ok(din, sizes[rule[0]]) else None)
    a_out = (axes[rule[1]] if axes[rule[1]] is not None
             and _axis_ok(dout, sizes[rule[1]]) else None)
    if a_in is not None and a_in == a_out:
        a_in = None
    return P(*lead, a_in, a_out)


def param_specs(params: Params, mesh: Mesh, mode: str = "train",
                expert_data: bool = False) -> Params:
    """PartitionSpec pytree for a params/grads pytree (path-name based).

    mode="train": 2-D FSDP×tensor (optimizer state must shard over data).
    mode="serve": tensor-parallel only — FSDP in-dim sharding makes every
    matmul produce partial sums and all-reduce full activations (§Perf found
    295 GB/dev of all-reduce on qwen2-moe prefill); at serving time there is
    no optimizer state, so weights replicate over the data axes instead.
    """
    axes = {"d": _data_axis(mesh) if mode == "train" else None, "m": "model"}
    sizes = {"d": _axis_size(mesh, axes["d"]) if mode == "train" else 0,
             "m": _axis_size(mesh, "model")}
    m_sz = sizes["m"]

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        name = str(names[-1])
        shape = leaf.shape
        if name in ("we_gate", "we_up", "we_down"):
            # expert-parallel when E | model; else tensor-parallel inside expert
            e_axis = len(shape) - 3
            lead = [None] * e_axis
            if expert_data:
                # §Perf experiment: experts over the data axis (GSPMD pads
                # 60 -> 64); contraction dims unsharded => no partial-sum
                # all-reduce per expert matmul
                if name == "we_down":
                    return P(*lead, "data",
                             "model" if _axis_ok(shape[-2], m_sz) else None,
                             None)
                return P(*lead, "data", None,
                         "model" if _axis_ok(shape[-1], m_sz) else None)
            if _axis_ok(shape[e_axis], m_sz):
                fs = axes["d"] if _axis_ok(shape[-2], sizes["d"]) else None
                return P(*lead, "model", fs, None)
            if name == "we_down":
                return P(*lead, None, "model" if _axis_ok(shape[-2], m_sz) else None, None)
            return P(*lead, None, None, "model" if _axis_ok(shape[-1], m_sz) else None)
        if name == "r":  # slstm per-head recurrence (4, H, dh, dh)
            return _spec_for_matrix(shape, ("d", "m"), axes, sizes)
        if name == "conv":  # (K, d_inner) depthwise
            return (P(*[None] * (len(shape) - 1),
                      "model" if _axis_ok(shape[-1], m_sz) else None))
        if name in _MATRIX_RULES and len(shape) >= 2:
            return _spec_for_matrix(shape, _MATRIX_RULES[name], axes, sizes)
        return P()  # norms, gates, router, biases: replicated

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_specs(opt_state: Params, pspecs: Params) -> Params:
    """mu/nu shard like params; step replicated."""
    return {"mu": pspecs, "nu": pspecs, "step": P()}


def _data_axis(mesh: Mesh):
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data")
    return "data"


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def batch_spec(batch_size: int, mesh: Mesh, extra_dims: int = 1) -> P:
    """Shard the batch dim over as much of the data(+pod) axes as divides."""
    d = _data_axis(mesh)
    if _axis_ok(batch_size, _axis_size(mesh, d)):
        return P(d, *[None] * extra_dims)
    if isinstance(d, tuple) and _axis_ok(batch_size, mesh.shape["data"]):
        return P("data", *[None] * extra_dims)
    return P(*[None] * (extra_dims + 1))


def cache_specs(cache: Params, batch: int, mesh: Mesh) -> Params:
    """KV/SSM cache specs: batch on data axes when divisible; then the first
    remaining dim divisible by the model axis gets 'model'."""
    d = _data_axis(mesh)
    d_ok = _axis_ok(batch, _axis_size(mesh, d))
    m_sz = _axis_size(mesh, "model")

    def rule(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        shape = leaf.shape
        scanned = "units" in names
        # layout: [units?] batch rest...  (kpos has no batch dim)
        b_idx = 1 if scanned else 0
        spec = [None] * len(shape)
        if names[-1] == "kpos":
            return P(*spec)
        if len(shape) > b_idx and shape[b_idx] == batch and d_ok:
            spec[b_idx] = d
        for i in range(b_idx + 1, len(shape)):
            if shape[i] % m_sz == 0 and shape[i] >= m_sz:
                spec[i] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache)


def named(mesh: Mesh, spec_tree: Params):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
