"""Recurrent blocks: Mamba2 (SSD), mLSTM and sLSTM (xLSTM).

TPU adaptation: Mamba2 and mLSTM share one *chunked matmul-form scan*
(`chunked_ssd`) — the SSD duality: within a chunk the recurrence is evaluated
as a decay-masked (L×L) attention-like matmul (MXU work), across chunks a
small (H, P, N) state is carried by ``lax.scan``.  This avoids materializing
(B, S, H, P, N) state trajectories (impossible at 32k/500k) and keeps HLO
size O(1) in sequence length.

mLSTM's normalizer state n_t is folded into the same machinery by augmenting
the value vectors with a constant-1 channel: the last row of the carried
state IS the normalizer (models/DESIGN trick, tested in test_models.py).

sLSTM has true (non-associative) hidden-to-gate recurrence and is evaluated
with a plain ``lax.scan`` over time — the paper's own position: sLSTM trades
parallelism for memory mixing.

Simplifications vs. the reference CUDA implementations (noted per DESIGN.md):
n_groups=1 for Mamba2 B/C projections; conv1d over x only; exponential gates
clipped to ±8 instead of carrying the max-stabilizer state.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale_axis=0):
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(shape[scale_axis])


# ------------------------------------------------------------- chunked SSD
def chunked_ssd(a: jnp.ndarray, xin: jnp.ndarray, bk: jnp.ndarray,
                cq: jnp.ndarray, h0: jnp.ndarray, chunk: int):
    """Linear recurrence  h_t = a_t·h_{t-1} + xin_t ⊗ bk_t,  y_t = h_t·cq_t.

    a: (B,S,H) per-head decay in (0,1]; xin: (B,S,H,P); bk,cq: (B,S,H,N);
    h0: (B,H,P,N).  Returns (y (B,S,H,P), h_final).
    """
    b, s, h, p = xin.shape
    n = bk.shape[-1]
    lc = min(chunk, s)
    if s % lc:  # pad to a chunk multiple with identity steps
        pad = lc - s % lc
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bk = jnp.pad(bk, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cq = jnp.pad(cq, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = a.shape[1] // lc

    def resh(z):
        return z.reshape(b, nc, lc, *z.shape[2:]).swapaxes(0, 1)

    ac, xc, bc, cc = resh(a), resh(xin), resh(bk), resh(cq)

    def step(h, inp):
        av, xv, bv, cv = inp                              # (B,lc,H,...)
        la = jnp.log(jnp.clip(av.astype(jnp.float32), 1e-20, 1.0))
        cs = jnp.cumsum(la, axis=1)                       # (B,lc,H) inclusive
        # intra-chunk: decay-masked attention matmul (the SSD duality)
        scores = jnp.einsum("blhn,bmhn->bhlm", cv.astype(jnp.float32),
                            bv.astype(jnp.float32))
        decay = jnp.exp(cs[:, :, None] - cs[:, None, :]).transpose(0, 3, 1, 2)
        mask = jnp.tril(jnp.ones((lc, lc), bool))
        w = jnp.where(mask[None, None], scores * decay, 0.0)
        y = jnp.einsum("bhlm,bmhp->blhp", w, xv.astype(jnp.float32))
        # inbound state
        y += jnp.einsum("blhn,bhpn,blh->blhp", cv.astype(jnp.float32), h,
                        jnp.exp(cs))
        # outbound state
        tot = cs[:, -1]                                   # (B,H)
        carry_w = jnp.exp(tot[:, None] - cs)              # (B,lc,H)
        h_new = h * jnp.exp(tot)[..., None, None] + jnp.einsum(
            "blhp,blhn,blh->bhpn", xv.astype(jnp.float32),
            bv.astype(jnp.float32), carry_w)
        return h_new, y

    h_fin, ys = jax.lax.scan(step, h0.astype(jnp.float32), (ac, xc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(b, nc * lc, h, p)[:, :s]
    return y.astype(xin.dtype), h_fin


def ssd_decode_step(a, xin, bk, cq, h):
    """Single-token recurrence update. Shapes as chunked_ssd with S=1."""
    af = a.astype(jnp.float32)[:, 0]                      # (B,H)
    h_new = h * af[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xin.astype(jnp.float32)[:, 0], bk.astype(jnp.float32)[:, 0])
    y = jnp.einsum("bhpn,bhn->bhp", h_new, cq.astype(jnp.float32)[:, 0])
    return y[:, None].astype(xin.dtype), h_new


# ----------------------------------------------------------------- Mamba2
def init_mamba2(key, cfg: ArchConfig) -> Params:
    d, di, n, hh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * n + hh)),
        "conv": _init(ks[1], (cfg.ssm_conv, di)) * 0.5,
        "a_log": jnp.zeros((hh,), jnp.float32),          # A = exp(a_log) = 1
        "dt_bias": jnp.full((hh,), -2.0, jnp.float32),   # softplus ≈ 0.13
        "d_skip": jnp.ones((hh,), jnp.float32),
        "out_norm": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[5], (di, d)),
    }
    return jax.tree.map(lambda a_: a_.astype(_dtype(cfg)), p)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray]):
    """Depthwise causal conv. x: (B,S,di); w: (K,di); state: (B,K-1,di)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), xp[:, -(k - 1):]


def mamba2_block(params: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                 cache: Optional[Params] = None):
    """Returns (y, new_cache). cache = {"h": (B,H,P,N), "conv": (B,K-1,di)}."""
    b, s, _ = x.shape
    di, n, hh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    p_dim = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xs, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    xs, conv_state = _causal_conv(xs, params["conv"],
                                  None if cache is None else cache["conv"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(params["a_log"].astype(jnp.float32)))
    xh = xs.reshape(b, s, hh, p_dim)
    xin = xh * dt[..., None].astype(xh.dtype)
    bk = jnp.broadcast_to(bmat[:, :, None, :], (b, s, hh, n))
    cq = jnp.broadcast_to(cmat[:, :, None, :], (b, s, hh, n))

    if cache is None or s > 1:
        h0 = (jnp.zeros((b, hh, p_dim, n), jnp.float32) if cache is None
              else cache["h"])
        y, h_fin = chunked_ssd(a, xin, bk, cq, h0, cfg.ssm_chunk)
    else:
        y, h_fin = ssd_decode_step(a, xin, bk, cq, cache["h"])

    y = y + xh * params["d_skip"].astype(jnp.float32).reshape(1, 1, hh, 1).astype(xh.dtype)
    y = y.reshape(b, s, di)
    from repro.models.layers import rmsnorm  # local import avoids cycle
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"h": h_fin, "conv": conv_state}


def init_mamba2_cache(cfg: ArchConfig, batch: int) -> Params:
    return {"h": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                              _dtype(cfg))}


# ------------------------------------------------------------------ mLSTM
def init_mlstm(key, cfg: ArchConfig) -> Params:
    d, di, n, hh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": _init(ks[0], (d, 2 * di)),
        "wq": _init(ks[1], (di, hh * n)),
        "wk": _init(ks[2], (di, hh * n)),
        "wi": _init(ks[3], (di, hh)),
        "wf": _init(ks[4], (di, hh)),
        "out_norm": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[5], (di, d)),
    }
    return jax.tree.map(lambda a_: a_.astype(_dtype(cfg)), p)


def mlstm_block(params: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                cache: Optional[Params] = None):
    """Matrix-memory LSTM as augmented SSD (normalizer = extra value channel).
    cache = {"h": (B,H,P+1,N)}."""
    b, s, _ = x.shape
    di, n, hh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    p_dim = di // hh
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xi, params["wq"]).reshape(b, s, hh, n)
    k = jnp.einsum("bse,ef->bsf", xi, params["wk"]).reshape(b, s, hh, n) / math.sqrt(n)
    igate = jnp.exp(jnp.clip(jnp.einsum("bse,eh->bsh", xi, params["wi"])
                             .astype(jnp.float32), -8.0, 8.0))
    fgate = jax.nn.sigmoid(jnp.einsum("bse,eh->bsh", xi, params["wf"])
                           .astype(jnp.float32))
    v = xi.reshape(b, s, hh, p_dim)
    # normalizer state as a separate 1-channel recurrence (same decay/keys)
    # instead of a concatenated ones-channel: the concat's fwd pad + bwd
    # slice/pad chain inside the unit scan was 45% of xlstm's HBM bytes
    # (§Perf profile); two scans share everything but the value width.
    ig = igate[..., None].astype(v.dtype)
    vin = v * ig
    nin = ig[..., :1] * jnp.ones((b, s, hh, 1), v.dtype)

    if cache is None or s > 1:
        hv0, hn0 = ((jnp.zeros((b, hh, p_dim, n), jnp.float32),
                     jnp.zeros((b, hh, 1, n), jnp.float32))
                    if cache is None else
                    (cache["h"][:, :, :p_dim], cache["h"][:, :, p_dim:]))
        f = fgate.astype(x.dtype)
        yv, hv = chunked_ssd(f, vin, k, q, hv0, cfg.ssm_chunk)
        yn, hn = chunked_ssd(f, nin, k, q, hn0, cfg.ssm_chunk)
    else:
        hv0 = cache["h"][:, :, :p_dim]
        hn0 = cache["h"][:, :, p_dim:]
        f = fgate.astype(x.dtype)
        yv, hv = ssd_decode_step(f, vin, k, q, hv0)
        yn, hn = ssd_decode_step(f, nin, k, q, hn0)
    h_fin = jnp.concatenate([hv, hn], axis=2)     # keep cache layout (P+1, N)
    denom = yn[..., 0]
    yv = yv / jnp.maximum(jnp.abs(denom), 1.0)[..., None]
    yv = yv.reshape(b, s, di)
    from repro.models.layers import rmsnorm
    yv = rmsnorm(yv, params["out_norm"], cfg.norm_eps)
    yv = yv * jax.nn.silu(z.astype(jnp.float32)).astype(yv.dtype)
    out = jnp.einsum("bse,ed->bsd", yv, params["out_proj"])
    return out, {"h": h_fin}


def init_mlstm_cache(cfg: ArchConfig, batch: int) -> Params:
    hh = cfg.n_ssm_heads
    return {"h": jnp.zeros((batch, hh, cfg.d_inner // hh + 1, cfg.ssm_state),
                           jnp.float32)}


# ------------------------------------------------------------------ sLSTM
def init_slstm(key, cfg: ArchConfig) -> Params:
    d, di, hh = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads
    dh = di // hh
    ks = jax.random.split(key, 6)
    p = {
        "w_in": _init(ks[0], (d, 4 * di)),               # i, f, z, o pre-acts
        "r": _init(ks[1], (4, hh, dh, dh), scale_axis=2),  # per-head recurrence
        "in_norm": jnp.ones((d,), jnp.float32),
        "out_norm": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[2], (di, d)),
    }
    return jax.tree.map(lambda a_: a_.astype(_dtype(cfg)), p)


def _slstm_cell(params, xt, state, cfg):
    """One sLSTM step. xt: (B,4*di) pre-activations; state: (c,n,h) (B,H,dh)."""
    c, nrm, h = state
    hh = cfg.n_ssm_heads
    dh = cfg.d_inner // hh
    rec = jnp.einsum("bhp,ghpq->gbhq", h, params["r"].astype(jnp.float32))
    pre = xt.astype(jnp.float32).reshape(xt.shape[0], 4, hh, dh).swapaxes(0, 1) + rec
    i = jnp.exp(jnp.clip(pre[0], -8.0, 8.0))
    f = jax.nn.sigmoid(pre[1])
    z = jnp.tanh(pre[2])
    o = jax.nn.sigmoid(pre[3])
    c_new = f * c + i * z
    n_new = f * nrm + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new)


def slstm_block(params: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                cache: Optional[Params] = None):
    """True recurrence: lax.scan over time. cache = {"c","n","h"} (B,H,dh)."""
    b, s, _ = x.shape
    hh = cfg.n_ssm_heads
    dh = cfg.d_inner // hh
    from repro.models.layers import rmsnorm
    xn = rmsnorm(x, params["in_norm"], cfg.norm_eps)
    pre = jnp.einsum("bsd,de->bse", xn, params["w_in"])   # (B,S,4di)

    if cache is None:
        st = tuple(jnp.zeros((b, hh, dh), jnp.float32) for _ in range(3))
    else:
        st = (cache["c"], cache["n"], cache["h"])

    if s == 1 and cache is not None:
        st = _slstm_cell(params, pre[:, 0], st, cfg)
        ys = st[2][:, None]
    else:
        def step(carry, xt):
            new = _slstm_cell(params, xt, carry, cfg)
            return new, new[2]
        st, ys = jax.lax.scan(step, st, pre.swapaxes(0, 1))
        ys = ys.swapaxes(0, 1)                            # (B,S,H,dh)

    y = ys.reshape(b, s, hh * dh).astype(x.dtype)
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"c": st[0], "n": st[1], "h": st[2]}


def init_slstm_cache(cfg: ArchConfig, batch: int) -> Params:
    hh = cfg.n_ssm_heads
    dh = cfg.d_inner // hh
    z = jnp.zeros((batch, hh, dh), jnp.float32)
    return {"c": z, "n": z, "h": z}
