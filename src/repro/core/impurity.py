"""Impurity / gain computation from histogram statistics.

Gains are *absolute weighted impurity decreases* (parent - left - right of the
un-normalized impurity sums), matching CART's split ordering.  All quantities
are pure functions of histograms, so every party evaluates them identically —
a prerequisite for the exact-losslessness guarantee.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def stat_channels(y: jnp.ndarray, task: str, n_classes: int) -> jnp.ndarray:
    """Per-sample label statistics (N, C) accumulated by histograms."""
    if task == "classification":
        return (y[:, None] == jnp.arange(n_classes)[None, :]).astype(jnp.float32)
    y = y.astype(jnp.float32)
    return jnp.stack([jnp.ones_like(y), y, y * y], axis=-1)


def count_of(stats: jnp.ndarray, task: str) -> jnp.ndarray:
    """Weighted sample count from a stats vector (..., C)."""
    return stats.sum(-1) if task == "classification" else stats[..., 0]


def impurity_sum(stats: jnp.ndarray, task: str) -> jnp.ndarray:
    """Un-normalized impurity: n*gini (classification) or SSE (regression)."""
    if task == "classification":
        n = stats.sum(-1)
        return n - (stats * stats).sum(-1) / jnp.maximum(n, _EPS)
    n, s1, s2 = stats[..., 0], stats[..., 1], stats[..., 2]
    return s2 - s1 * s1 / jnp.maximum(n, _EPS)


def leaf_value(stats: jnp.ndarray, task: str) -> jnp.ndarray:
    """Leaf prediction from node stats: class distribution / mean target."""
    if task == "classification":
        n = jnp.maximum(stats.sum(-1, keepdims=True), _EPS)
        return stats / n
    return stats[..., 1] / jnp.maximum(stats[..., 0], _EPS)


def split_gains(hist: jnp.ndarray, task: str, min_samples_leaf: int
                ) -> jnp.ndarray:
    """Candidate gains for every (node, feature, split-bin).

    Args:
      hist: (L, F, B, C) histogram of label stats.
    Returns:
      (L, F, B-1) float32 gains; invalid splits are -inf.
    """
    left = jnp.cumsum(hist, axis=2)[:, :, :-1]          # (L, F, B-1, C)
    total = hist.sum(axis=2)                            # (L, F, C)
    right = total[:, :, None, :] - left
    parent = impurity_sum(total, task)[:, :, None]      # (L, F, 1)
    gain = parent - impurity_sum(left, task) - impurity_sum(right, task)
    nl, nr = count_of(left, task), count_of(right, task)
    ok = (nl >= min_samples_leaf) & (nr >= min_samples_leaf)
    return jnp.where(ok, gain, -jnp.inf)
