"""Core typed configuration for the Federated Forest.

All static hyper-parameters live here so that jitted builders can close over a
hashable, frozen params object (used as a static argument).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Task = Literal["classification", "regression"]

PARTY_AXIS = "parties"  # mesh/vmap axis name over which the federated protocol runs


@dataclasses.dataclass(frozen=True)
class ForestParams:
    """Hyper-parameters of a (federated) random forest.

    Mirrors the knobs of the paper's CART + bagging setup (Alg. 1/2/5/6).
    """

    task: Task = "classification"
    n_classes: int = 2              # ignored for regression
    n_estimators: int = 10
    max_depth: int = 6
    min_samples_split: int = 2
    min_samples_leaf: int = 1
    min_impurity_decrease: float = 0.0
    n_bins: int = 32                # quantile bins (<= 256, stored as uint8)
    max_features: float = 1.0       # per-tree feature subsampling fraction (master-side)
    bootstrap: bool = True
    seed: int = 0
    # Beyond-paper (§Perf): sibling histogram = parent - left-child
    # (LightGBM's subtraction trick) — halves split-finding compute below the
    # root. Exact for classification (integer counts in f32); for regression
    # it reorders float sums, so it is a statistically-equivalent variant.
    hist_subtraction: bool = False
    # Frontier compaction (§Perf, tentpole): at depths where the heap level
    # is wider than ``frontier_cap``, live nodes are remapped into a dense
    # segment index of capacity min(2^d, n_samples, frontier_cap) and the
    # histogram/gain stage runs over compact slots, in as many passes as the
    # LIVE node count requires (a while_loop — compute scales with actual
    # sparsity, not worst-case width).  Results are scattered back to heap
    # order, so the built PartyTree is bit-identical to the dense build.
    # 0 disables compaction (the dense seed behavior); "auto" derives the
    # cap from (N, depth, n_bins) at fit time — see ``resolved``.
    frontier_cap: int | str = 256
    # Histogram backend: a key of kernels.ops.BACKENDS, or "auto" (scatter on
    # CPU/GPU hosts, the compiled Pallas kernel on TPU).
    hist_impl: str = "auto"
    # Bagging batching: how many trees build together under one vmap (the
    # outer lax.map then runs over tree *chunks*).  1 reproduces the seed's
    # pure lax.map; larger values trade HLO size/peak memory for better
    # hardware utilization on wide hosts.  "auto" derives it at fit time.
    trees_per_batch: int | str = 1

    def __post_init__(self) -> None:
        if not (1 <= self.n_bins <= 256):
            raise ValueError("n_bins must be in [1, 256]")
        if self.max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        if self.task not in ("classification", "regression"):
            raise ValueError(f"unknown task {self.task!r}")
        if not (0.0 < self.max_features <= 1.0):
            raise ValueError("max_features must be in (0, 1]")
        if isinstance(self.frontier_cap, str):
            if self.frontier_cap != "auto":
                raise ValueError(f"frontier_cap must be an int >= 0 or "
                                 f"'auto', got {self.frontier_cap!r}")
        elif self.frontier_cap < 0:
            raise ValueError("frontier_cap must be >= 0 (0 = dense build)")
        if isinstance(self.trees_per_batch, str):
            if self.trees_per_batch != "auto":
                raise ValueError(f"trees_per_batch must be an int >= 1 or "
                                 f"'auto', got {self.trees_per_batch!r}")
        elif self.trees_per_batch < 1:
            raise ValueError("trees_per_batch must be >= 1")

    # ---- "auto" build-knob resolution ----------------------------------------
    @property
    def needs_resolution(self) -> bool:
        """True while a build knob is still the "auto" placeholder — the
        params cannot parameterize a fit program until ``resolved``."""
        return (isinstance(self.frontier_cap, str)
                or isinstance(self.trees_per_batch, str))

    def resolved(self, n_samples: int) -> "ForestParams":
        """Replace "auto" build knobs with concrete values derived from the
        training-set size and the static shape knobs (N, depth, n_bins).

        Both knobs are perf-only: frontier compaction scatters results back
        to heap order and tree batching only regroups the bagging vmap, so
        ANY resolution builds a forest bit-identical to any explicit
        setting (asserted in tests).  Explicit integer settings pass
        through untouched — the override escape hatch."""
        if not self.needs_resolution:
            return self
        changes: dict = {}
        if isinstance(self.frontier_cap, str):
            changes["frontier_cap"] = auto_frontier_cap(
                n_samples, self.max_depth, self.n_bins, self.n_stat_channels)
        if isinstance(self.trees_per_batch, str):
            changes["trees_per_batch"] = auto_trees_per_batch(
                n_samples, self.n_estimators, self.n_bins)
        return dataclasses.replace(self, **changes)

    # ---- derived static sizes -------------------------------------------------
    @property
    def n_nodes(self) -> int:
        """Total nodes of the complete binary tree (heap layout)."""
        return 2 ** (self.max_depth + 1) - 1

    @property
    def max_leaves(self) -> int:
        """Upper bound on live leaves of one tree.

        Leaves are disjoint, so a depth-``max_depth`` tree has at most
        ``2^max_depth`` of them — the static clamp for the serving layer's
        leaf-compacted prediction tables (serving/plan.py).
        """
        return 2 ** self.max_depth

    @property
    def n_stat_channels(self) -> int:
        """Label-statistic channels accumulated in histograms.

        classification: per-class (weighted) counts.
        regression:     (w, w*y, w*y^2) — enough for variance/SSE splits.
        """
        return self.n_classes if self.task == "classification" else 3

    def level_slice(self, depth: int) -> tuple[int, int]:
        """(offset, width) of the nodes at ``depth`` in heap layout."""
        return 2**depth - 1, 2**depth


def auto_frontier_cap(n_samples: int, max_depth: int, n_bins: int,
                      n_stat_channels: int) -> int:
    """Heuristic frontier cap: the widest compact level whose per-feature
    histogram slab (cap * n_bins * channels f32) stays within a ~4 MiB
    working set, clamped to what the tree can actually populate
    (min(2^depth, N) live nodes) and floored at 64 slots so shallow/fat
    configurations don't thrash the multi-pass while_loop.  Rounded to a
    multiple of 64 for tidy lane alignment.  Perf-only: any cap builds the
    same forest bit-for-bit."""
    budget = (1 << 22) // max(1, n_bins * n_stat_channels * 4)
    budget = max(64, (budget // 64) * 64)
    return int(min(2 ** max_depth, max(64, n_samples), budget))


def auto_trees_per_batch(n_samples: int, n_estimators: int,
                         n_bins: int) -> int:
    """Heuristic bagging batch: stack trees under one vmap while the
    per-batch row working set (~N * n_bins lanes per tree) stays within a
    ~4 MiB budget, capped at 8 (HLO size grows with the batch) and at the
    forest size.  Perf-only: batching regroups the lax.map without touching
    per-tree randomness, so outputs are bit-identical at any setting."""
    per_tree = max(1, n_samples * n_bins)
    return int(max(1, min(n_estimators, 8, (1 << 22) // per_tree)))
