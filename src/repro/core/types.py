"""Core typed configuration for the Federated Forest.

All static hyper-parameters live here so that jitted builders can close over a
hashable, frozen params object (used as a static argument).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Task = Literal["classification", "regression"]

PARTY_AXIS = "parties"  # mesh/vmap axis name over which the federated protocol runs


@dataclasses.dataclass(frozen=True)
class ForestParams:
    """Hyper-parameters of a (federated) random forest.

    Mirrors the knobs of the paper's CART + bagging setup (Alg. 1/2/5/6).
    """

    task: Task = "classification"
    n_classes: int = 2              # ignored for regression
    n_estimators: int = 10
    max_depth: int = 6
    min_samples_split: int = 2
    min_samples_leaf: int = 1
    min_impurity_decrease: float = 0.0
    n_bins: int = 32                # quantile bins (<= 256, stored as uint8)
    max_features: float = 1.0       # per-tree feature subsampling fraction (master-side)
    bootstrap: bool = True
    seed: int = 0
    # Beyond-paper (§Perf): sibling histogram = parent - left-child
    # (LightGBM's subtraction trick) — halves split-finding compute below the
    # root. Exact for classification (integer counts in f32); for regression
    # it reorders float sums, so it is a statistically-equivalent variant.
    hist_subtraction: bool = False
    # Frontier compaction (§Perf, tentpole): at depths where the heap level
    # is wider than ``frontier_cap``, live nodes are remapped into a dense
    # segment index of capacity min(2^d, n_samples, frontier_cap) and the
    # histogram/gain stage runs over compact slots, in as many passes as the
    # LIVE node count requires (a while_loop — compute scales with actual
    # sparsity, not worst-case width).  Results are scattered back to heap
    # order, so the built PartyTree is bit-identical to the dense build.
    # 0 disables compaction (the dense seed behavior).
    frontier_cap: int = 256
    # Histogram backend: a key of kernels.ops.BACKENDS, or "auto" (scatter on
    # CPU/GPU hosts, the compiled Pallas kernel on TPU).
    hist_impl: str = "auto"
    # Bagging batching: how many trees build together under one vmap (the
    # outer lax.map then runs over tree *chunks*).  1 reproduces the seed's
    # pure lax.map; larger values trade HLO size/peak memory for better
    # hardware utilization on wide hosts.
    trees_per_batch: int = 1

    def __post_init__(self) -> None:
        if not (1 <= self.n_bins <= 256):
            raise ValueError("n_bins must be in [1, 256]")
        if self.max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        if self.task not in ("classification", "regression"):
            raise ValueError(f"unknown task {self.task!r}")
        if not (0.0 < self.max_features <= 1.0):
            raise ValueError("max_features must be in (0, 1]")
        if self.frontier_cap < 0:
            raise ValueError("frontier_cap must be >= 0 (0 = dense build)")
        if self.trees_per_batch < 1:
            raise ValueError("trees_per_batch must be >= 1")

    # ---- derived static sizes -------------------------------------------------
    @property
    def n_nodes(self) -> int:
        """Total nodes of the complete binary tree (heap layout)."""
        return 2 ** (self.max_depth + 1) - 1

    @property
    def max_leaves(self) -> int:
        """Upper bound on live leaves of one tree.

        Leaves are disjoint, so a depth-``max_depth`` tree has at most
        ``2^max_depth`` of them — the static clamp for the serving layer's
        leaf-compacted prediction tables (serving/plan.py).
        """
        return 2 ** self.max_depth

    @property
    def n_stat_channels(self) -> int:
        """Label-statistic channels accumulated in histograms.

        classification: per-class (weighted) counts.
        regression:     (w, w*y, w*y^2) — enough for variance/SSE splits.
        """
        return self.n_classes if self.task == "classification" else 3

    def level_slice(self, depth: int) -> tuple[int, int]:
        """(offset, width) of the nodes at ``depth`` in heap layout."""
        return 2**depth - 1, 2**depth
