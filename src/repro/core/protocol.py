"""Runners that bind the SPMD federated protocol to an execution substrate.

The builder/predictor in tree.py / prediction.py are written once against the
``parties`` axis name.  They execute under:

  * ``run_simulated``: vmap with axis_name — M parties on one host.  This is
    the CPU test/benchmark path and is semantically identical to the
    distributed run (collectives have the same meaning under vmap).
  * ``run_sharded``: shard_map over a mesh axis literally named "parties" —
    the production / dry-run path (mesh from launch/mesh.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import PARTY_AXIS


def run_simulated(fn: Callable[..., Any], party_args: tuple, shared_args: tuple = ()):
    """vmap over the leading party axis of ``party_args``; broadcast the rest."""
    in_axes = (0,) * len(party_args) + (None,) * len(shared_args)
    return jax.vmap(fn, in_axes=in_axes, axis_name=PARTY_AXIS)(
        *party_args, *shared_args)


def jit_simulated(fn: Callable[..., Any], n_party: int, n_shared: int,
                  **jit_kw):
    """jit(run_simulated(fn)) with the party/shared split baked in."""
    @functools.partial(jax.jit, **jit_kw)
    def wrapped(*args):
        return run_simulated(fn, args[:n_party], args[n_party:n_party + n_shared])
    return wrapped


def sharded_program(fn: Callable[..., Any], mesh: Mesh, n_party: int,
                    n_shared: int, shared_specs=None, out_specs=None):
    """shard_map ``fn`` over the mesh axis named ``PARTY_AXIS``.

    Party args shard their leading M axis one-party-per-shard (the axis size
    must equal M); inside the mapped body the local size-1 party dim is
    squeezed so ``fn`` sees exactly what it sees under ``run_simulated``, and
    re-expanded on the way out.  ``shared_specs`` places the shared args
    (default: replicated); ``out_specs`` defaults to party-stacked outputs.
    Returns the un-jitted program — callers jit/lower it (the AOT serving
    path) or wrap it in ``run_sharded`` for eager use.
    """
    from repro import compat  # local: compat imports nothing from core

    shared_specs = tuple(shared_specs) if shared_specs is not None else \
        (P(),) * n_shared
    if len(shared_specs) != n_shared:
        raise ValueError(f"{n_shared} shared args, {len(shared_specs)} specs")
    in_specs = (P(PARTY_AXIS),) * n_party + shared_specs
    out_specs = P(PARTY_AXIS) if out_specs is None else out_specs

    def local(*args):
        party = [jax.tree.map(lambda a: a[0], a) for a in args[:n_party]]
        out = fn(*party, *args[n_party:])
        return jax.tree.map(lambda a: a[None], out)

    return compat.shard_map(local, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)


def run_sharded(fn: Callable[..., Any], party_args: tuple,
                shared_args: tuple = (), *, mesh: Mesh,
                shared_specs=None, out_specs=None):
    """Run ``fn`` SPMD over the mesh's "parties" axis (see sharded_program)."""
    prog = sharded_program(fn, mesh, len(party_args), len(shared_args),
                           shared_specs=shared_specs, out_specs=out_specs)
    return prog(*party_args, *shared_args)


def replicate_to_mesh(x, mesh: Mesh):
    """Device-put a host array replicated over every mesh axis."""
    return jax.device_put(x, NamedSharding(mesh, P()))
