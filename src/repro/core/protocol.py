"""Runners that bind the SPMD federated protocol to an execution substrate.

The builder/predictor in tree.py / prediction.py are written once against the
``parties`` axis name.  They execute under:

  * ``run_simulated``: vmap with axis_name — M parties on one host.  This is
    the CPU test/benchmark path and is semantically identical to the
    distributed run (collectives have the same meaning under vmap).
  * ``run_sharded``: shard_map over a mesh axis literally named "parties" —
    the production / dry-run path (mesh from launch/mesh.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import PARTY_AXIS


def run_simulated(fn: Callable[..., Any], party_args: tuple, shared_args: tuple = ()):
    """vmap over the leading party axis of ``party_args``; broadcast the rest."""
    in_axes = (0,) * len(party_args) + (None,) * len(shared_args)
    return jax.vmap(fn, in_axes=in_axes, axis_name=PARTY_AXIS)(
        *party_args, *shared_args)


def jit_simulated(fn: Callable[..., Any], n_party: int, n_shared: int,
                  **jit_kw):
    """jit(run_simulated(fn)) with the party/shared split baked in."""
    @functools.partial(jax.jit, **jit_kw)
    def wrapped(*args):
        return run_simulated(fn, args[:n_party], args[n_party:n_party + n_shared])
    return wrapped


def replicate_to_mesh(x, mesh: Mesh):
    """Device-put a host array replicated over every mesh axis."""
    return jax.device_put(x, NamedSharding(mesh, P()))
