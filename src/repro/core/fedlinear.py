"""Federated logistic / linear regression (the paper's F-LR baseline).

Vertical-FL linear models: each party holds its feature block X_i and weight
block w_i; the joint logit is  z = Σ_i X_i w_i + b  — a single psum over the
party axis per step, gradients computed locally per block.  This is the
[Hardy et al. 2017]-style baseline the paper's Table 1 compares against
(without HE, matching the paper's trust model where intermediate sums are
masked rather than encrypted).

SPMD over PARTY_AXIS like the forest — runs under vmap (simulation) and
shard_map (mesh) unchanged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PARTY_AXIS


def _spmd_fit(x_i, y, *, task: str, lr: float, steps: int, l2: float):
    """x_i: (N, F_i) party-local standardized features; y: (N,) shared."""
    n, f = x_i.shape
    w = jnp.zeros((f,), jnp.float32)
    b = jnp.zeros((), jnp.float32)
    yf = y.astype(jnp.float32)

    def step(carry, _):
        w, b = carry
        z = jax.lax.psum(x_i @ w, PARTY_AXIS) + b        # one collective
        pred = jax.nn.sigmoid(z) if task == "classification" else z
        err = (pred - yf) / n
        gw = x_i.T @ err + l2 * w                        # local block grad
        gb = err.sum()
        return (w - lr * gw, b - lr * gb), None

    (w, b), _ = jax.lax.scan(step, (w, b), None, length=steps)
    return w, b


def _spmd_predict(x_i, w, b, *, task: str):
    z = jax.lax.psum(x_i @ w, PARTY_AXIS) + b
    if task == "classification":
        return (z > 0).astype(jnp.int32)
    return z


@dataclasses.dataclass
class FederatedLinear:
    """F-LR: logistic (classification) or linear (regression) regression."""
    task: str = "classification"
    lr: float = 0.5
    steps: int = 400
    l2: float = 1e-4

    def fit(self, x_parts: list[np.ndarray], y: np.ndarray):
        """x_parts: per-party raw feature blocks (same N, varying F_i)."""
        self._mu = [p.mean(0) for p in x_parts]
        self._sd = [p.std(0) + 1e-8 for p in x_parts]
        xs = self._stack([(p - m) / s for p, m, s
                          in zip(x_parts, self._mu, self._sd)])
        fn = lambda xi, yy: _spmd_fit(xi, yy, task=self.task, lr=self.lr,
                                      steps=self.steps, l2=self.l2)
        self._w, self._b = jax.jit(
            jax.vmap(fn, in_axes=(0, None), axis_name=PARTY_AXIS)
        )(jnp.asarray(xs), jnp.asarray(y))
        return self

    def predict(self, x_parts: list[np.ndarray]) -> np.ndarray:
        xs = self._stack([(p - m) / s for p, m, s
                          in zip(x_parts, self._mu, self._sd)])
        fn = lambda xi, w, b: _spmd_predict(xi, w, b, task=self.task)
        out = jax.vmap(fn, in_axes=(0, 0, None), axis_name=PARTY_AXIS)(
            jnp.asarray(xs), self._w, self._b[0] if self._b.ndim else self._b)
        return np.asarray(out[0])

    @staticmethod
    def _stack(parts: list[np.ndarray]) -> np.ndarray:
        fmax = max(p.shape[1] for p in parts)
        out = np.zeros((len(parts), parts[0].shape[0], fmax), np.float32)
        for i, p in enumerate(parts):
            out[i, :, : p.shape[1]] = p
        return out


def split_columns(x: np.ndarray, n_parties: int) -> list[np.ndarray]:
    return [np.asarray(b) for b in np.array_split(x, n_parties, axis=1)]
