"""Federated logistic / linear regression (the paper's F-LR baseline).

Vertical-FL linear models: each party holds its feature block X_i and weight
block w_i; the joint logit is  z = Σ_i X_i w_i + b  — a single psum over the
party axis per step, gradients computed locally per block.  This is the
[Hardy et al. 2017]-style baseline the paper's Table 1 compares against
(without HE, matching the paper's trust model where intermediate sums are
masked rather than encrypted).

SPMD over PARTY_AXIS like the forest — runs under vmap (simulation) and
shard_map (mesh) unchanged; execution goes through a federation Substrate
so the session API drives F-LR exactly like the tree models.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.party import VerticalPartition
from repro.core.types import PARTY_AXIS


@dataclasses.dataclass(frozen=True)
class LinearParams:
    """Spec for Federation.fit dispatch — mirrors FederatedLinear's knobs."""
    task: str = "classification"
    lr: float = 0.5
    steps: int = 400
    l2: float = 1e-4


def _spmd_fit(x_i, y, *, task: str, lr: float, steps: int, l2: float):
    """x_i: (N, F_i) party-local standardized features; y: (N,) shared."""
    n, f = x_i.shape
    w = jnp.zeros((f,), jnp.float32)
    b = jnp.zeros((), jnp.float32)
    yf = y.astype(jnp.float32)

    def step(carry, _):
        w, b = carry
        z = jax.lax.psum(x_i @ w, PARTY_AXIS) + b        # one collective
        pred = jax.nn.sigmoid(z) if task == "classification" else z
        err = (pred - yf) / n
        gw = x_i.T @ err + l2 * w                        # local block grad
        gb = err.sum()
        return (w - lr * gw, b - lr * gb), None

    (w, b), _ = jax.lax.scan(step, (w, b), None, length=steps)
    return w, b


def _spmd_predict(x_i, w, b, *, task: str):
    z = jax.lax.psum(x_i @ w, PARTY_AXIS) + b
    if task == "classification":
        return (z > 0).astype(jnp.int32)
    return z


@dataclasses.dataclass
class FederatedLinear:
    """F-LR: logistic (classification) or linear (regression) regression.

    Conforms to the federation Estimator protocol: ``fit``/``predict``
    accept either per-party raw feature blocks (the legacy surface) or a
    VerticalPartition carrying ``raw_parts`` — the session path.
    """
    task: str = "classification"
    lr: float = 0.5
    steps: int = 400
    l2: float = 1e-4
    # execution substrate (federation.substrate); None -> vmap simulation
    substrate: Any = None

    @classmethod
    def from_params(cls, params: LinearParams, substrate=None,
                    **kw) -> "FederatedLinear":
        return cls(task=params.task, lr=params.lr, steps=params.steps,
                   l2=params.l2, substrate=substrate, **kw)

    def _sub(self):
        from repro.federation.substrate import default_substrate
        return default_substrate(self.substrate)

    def _blocks(self, x) -> list[np.ndarray]:
        """Per-party raw feature blocks from any accepted input form."""
        if isinstance(x, VerticalPartition):
            if x.raw_parts is None:
                raise ValueError(
                    "this VerticalPartition carries no raw feature blocks "
                    "(built before make_vertical_partition kept them?)")
            self._partition = x
            return x.raw_parts
        if isinstance(x, np.ndarray) and x.ndim == 2:
            part = getattr(self, "_partition", None)
            if part is None:
                raise ValueError("raw-matrix input needs a partition: fit "
                                 "with a VerticalPartition first")
            return part.split_raw(x)
        return [np.asarray(b) for b in x]

    def _standardized(self, x_parts: list[np.ndarray]) -> np.ndarray:
        """(M, N, Fmax) stack of the blocks, standardized with the fit-time
        moments — the single owner of the normalize step shared by fit,
        predict, and the serving engine's LinearServer._prep."""
        return self._stack([(p - m) / s for p, m, s
                            in zip(x_parts, self._mu, self._sd)])

    def fit(self, x_parts, y: np.ndarray):
        """x_parts: per-party raw blocks (same N, varying F_i), or a
        VerticalPartition with raw_parts."""
        x_parts = self._blocks(x_parts)
        self._mu = [p.mean(0) for p in x_parts]
        self._sd = [p.std(0) + 1e-8 for p in x_parts]
        xs = self._standardized(x_parts)
        fn = lambda xi, yy: _spmd_fit(xi, yy, task=self.task, lr=self.lr,
                                      steps=self.steps, l2=self.l2)
        sub = self._sub()
        with sub.context():
            self._w, self._b = sub.jit(fn, 1, 1)(jnp.asarray(xs),
                                                 jnp.asarray(y))
        return self

    def predict(self, x_parts) -> np.ndarray:
        from repro.federation import programs
        xs = self._standardized(self._blocks(x_parts))
        sub = self._sub()
        run = sub.compile(programs.linear_predict_program(sub, self.task))
        with sub.context():
            out = run(jnp.asarray(xs), self._w,
                      self._b[0] if self._b.ndim else self._b)
        return programs.party0(out)

    @staticmethod
    def _stack(parts: list[np.ndarray]) -> np.ndarray:
        fmax = max(p.shape[1] for p in parts)
        out = np.zeros((len(parts), parts[0].shape[0], fmax), np.float32)
        for i, p in enumerate(parts):
            out[i, :, : p.shape[1]] = p
        return out


def split_columns(x: np.ndarray, n_parties: int) -> list[np.ndarray]:
    return [np.asarray(b) for b in np.array_split(x, n_parties, axis=1)]
