"""Federated Forest prediction (paper §4.2, Alg. 3/4/7/8).

Two algorithms:

  * ``forest_predict_oneround``  — the paper's contribution.  Each party routes
    every test sample through its PARTIAL tree; at foreign nodes the sample
    descends into BOTH subtrees.  The per-party result is a boolean
    leaf-membership mask (trees, samples, nodes).  Proposition 1 says the true
    leaf assignment is the per-leaf intersection across parties — here a
    single ``psum`` over the party axis FOR THE ENTIRE FOREST (the paper's
    "only one round of communication ... even for the overall forest").

  * ``forest_predict_classical`` — the multi-round baseline: samples are routed
    level by level, with the owning party broadcasting the branch decision at
    every level of every tree (one psum per (tree, level)).  This is the
    baseline of the paper's Figs. 4–6; its communication grows with depth and
    tree count while the one-round method does not.

Both are SPMD over PARTY_AXIS, like the builder.

Prediction-side sparsity (the serving tentpole): most heap slots of a deep
tree are dead, so ``forest_predict_oneround`` optionally takes a per-tree
``LeafTable`` (serving/plan.py) and emits the membership mask gathered over
live leaves — the psum payload and the vote contraction shrink from
``n_nodes`` columns to the live-leaf capacity, while the Prop. 1 intersection
semantics (and the bits of every output) are unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core import impurity
from repro.core.tree import PartyTree
from repro.core.types import PARTY_AXIS, ForestParams


def tree_leaf_membership(tree: PartyTree, xb_test: jnp.ndarray,
                         params: ForestParams) -> jnp.ndarray:
    """Paper Alg. 3: (N_t, n_nodes) bool leaf-candidate mask for one party.

    Built level-by-level and concatenated once (heap order IS level order) —
    a §Perf iteration replacing per-level dynamic_update_slice of the full
    (N, n_nodes) buffer, which copied the whole mask at every depth."""
    n = xb_test.shape[0]
    cur = jnp.ones((n, 1), bool)                             # root membership
    xb = xb_test.astype(jnp.int32)
    parts = []
    for d in range(params.max_depth):
        off, width = params.level_slice(d)
        leaf_lv = lax.dynamic_slice(tree.is_leaf, (off,), (width,))
        has = lax.dynamic_slice(tree.has_split, (off,), (width,))
        floc = jnp.clip(lax.dynamic_slice(tree.split_floc, (off,), (width,)), 0)
        bins = lax.dynamic_slice(tree.split_bin, (off,), (width,))
        vals = xb[:, floc]                                   # (N, width)
        left_ok = ~has[None] | (vals <= bins[None])          # foreign => both
        right_ok = ~has[None] | (vals > bins[None])
        parts.append(cur & leaf_lv[None])                    # leaves stop here
        alive = cur & ~leaf_lv[None]
        cur = jnp.stack([alive & left_ok, alive & right_ok],
                        -1).reshape(n, 2 * width)
    off, width = params.level_slice(params.max_depth)
    leaf_bottom = lax.dynamic_slice(tree.is_leaf, (off,), (width,))
    parts.append(cur & leaf_bottom[None])
    return jnp.concatenate(parts, axis=1)                    # (N, n_nodes)


def masked_leaf_stats(trees: PartyTree) -> jnp.ndarray:
    """(T, nn, C) leaf stats with non-leaf rows zeroed (the vote operand)."""
    return jnp.where(trees.is_leaf[..., None], trees.leaf_stats, 0.0)


def _combine_votes(inter: jnp.ndarray, leaf: jnp.ndarray, params: ForestParams,
                   aggregate: bool = True, vote_impl: str = "einsum"):
    """Forest vote from the (T, N, L) exact leaf-assignment mask.

    ``leaf`` is the matching (T, L, C) zero-masked leaf-stats tensor — the
    full heap (L = n_nodes, from :func:`masked_leaf_stats`) or the serving
    layer's leaf-compacted gather (L = live-leaf slots).  Either way each
    sample intersects exactly one true leaf column (Prop. 1) and every other
    column contributes an exact 0.0, so the vote is bit-identical across
    compactions.

    ``aggregate=False`` returns per-tree results (T, N) — used by the
    tree-parallel production mesh, where the final vote is a cross-shard
    reduction done by the caller.

    ``vote_impl='argmax'`` (§Perf, classification only): each sample hits
    exactly one leaf, so the per-tree label is a masked max over int8 leaf
    labels — no f32 blow-up of the (T, N, L) mask."""
    if params.task == "classification":
        if vote_impl == "argmax":
            label1 = (jnp.argmax(leaf, -1) + 1).astype(jnp.int8)   # (T, L)
            per_tree = (jnp.max(jnp.where(inter, label1[:, None, :], 0), -1)
                        .astype(jnp.int32) - 1)                    # (T, N)
        else:
            # per-tree label by leaf majority, then forest majority (Alg. 4)
            stats = jnp.einsum("tnl,tlc->tnc", inter.astype(jnp.float32), leaf)
            per_tree = jnp.argmax(stats, -1)                       # (T, N)
        if not aggregate:
            return per_tree
        votes = (per_tree[..., None] ==
                 jnp.arange(params.n_classes)[None, None, :]).sum(0)
        return jnp.argmax(votes, -1)
    vals = impurity.leaf_value(leaf, params.task)            # (T, L)
    per_tree = jnp.einsum("tnl,tl->tn", inter.astype(jnp.float32), vals)
    if not aggregate:
        return per_tree
    return per_tree.mean(0)                                  # Alg. 8: averaging


def tree_leaf_membership_compact(tree: PartyTree, xb_test: jnp.ndarray,
                                 params: ForestParams,
                                 leaf_idx: jnp.ndarray) -> jnp.ndarray:
    """Leaf-candidate mask gathered over live leaves: (N_t, L) bool.

    ``leaf_idx`` is one tree's row of a serving ``LeafTable`` — the heap ids
    of its live leaves in ascending (heap) order, -1 padded to the static
    capacity L.  Routing still walks every heap level (the per-level masks
    are what descend the tree), but the emitted mask — and with it the
    one-round psum payload and the vote contraction — shrinks from
    ``n_nodes`` columns to L.  Column j equals the dense mask's column
    ``leaf_idx[j]`` exactly; padded columns are identically False, so they
    can never survive the cross-party intersection."""
    mem = tree_leaf_membership(tree, xb_test, params)        # (N, nn)
    valid = leaf_idx >= 0
    return jnp.take(mem, jnp.clip(leaf_idx, 0), axis=1) & valid[None]


def gather_leaf_stats(trees: PartyTree, leaf_idx: jnp.ndarray) -> jnp.ndarray:
    """(T, L, C) leaf stats gathered over a LeafTable; padded rows zeroed.

    The compact counterpart of :func:`masked_leaf_stats` — gathered rows are
    leaves by construction, so only table padding needs masking."""
    idx = jnp.clip(leaf_idx, 0)[..., None]                   # (T, L, 1)
    stats = jnp.take_along_axis(trees.leaf_stats, idx, axis=1)
    return jnp.where((leaf_idx >= 0)[..., None], stats, 0.0)


def forest_predict_oneround(trees: PartyTree, xb_test: jnp.ndarray,
                            params: ForestParams, aggregate: bool = True,
                            mask_dtype=jnp.int32,
                            vote_impl: str = "einsum",
                            leaf_idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """The paper's one-round prediction. SPMD over PARTY_AXIS.

    ``mask_dtype``: the membership masks are 0/1 and M <= 255 parties, so
    a uint8 psum is exact and moves 4x fewer collective bytes than int32 —
    the §Perf-optimized setting (the baseline keeps int32, the naive
    lowering of a boolean sum).

    ``leaf_idx``: a serving ``LeafTable.leaf_idx`` array ((T, L) live-leaf
    heap ids, -1 padded — serving/plan.py) switches every tree to the
    leaf-compacted mask — same Prop. 1 intersection semantics, bit-identical
    outputs, with the single psum and the vote contraction shrunk from
    ``n_nodes`` to the table's live-leaf capacity."""
    if leaf_idx is None:
        def one(tree):
            return tree_leaf_membership(tree, xb_test, params)
        mem = lax.map(one, trees)                            # (T, N, nn) bool
        leaf = masked_leaf_stats(trees)
    else:
        def one(args):
            tree, idx = args
            return tree_leaf_membership_compact(tree, xb_test, params, idx)
        mem = lax.map(one, (trees, leaf_idx))                # (T, N, L) bool
        leaf = gather_leaf_stats(trees, leaf_idx)
    # === Proposition 1: ONE collective for the whole forest ===
    m = lax.psum(mem.astype(mask_dtype), PARTY_AXIS)
    n_parties = compat.axis_size(PARTY_AXIS)                 # static, no comm
    inter = m == jnp.asarray(n_parties, mask_dtype)          # S^l = ∩ S_i^l
    return _combine_votes(inter, leaf, params, aggregate, vote_impl)


def forest_predict_classical(trees: PartyTree, xb_test: jnp.ndarray,
                             params: ForestParams) -> jnp.ndarray:
    """Multi-round baseline: owner broadcasts the branch at every level."""
    n = xb_test.shape[0]
    xb = xb_test.astype(jnp.int32)

    def route_tree(tree: PartyTree):
        node = jnp.zeros((n,), jnp.int32)
        for _ in range(params.max_depth):
            has = tree.has_split[node]
            floc = jnp.clip(tree.split_floc[node], 0)
            bins = tree.split_bin[node]
            vals = jnp.take_along_axis(xb, floc[:, None], axis=1)[:, 0]
            go_r_loc = jnp.where(has, (vals > bins).astype(jnp.int32), 0)
            go_r = lax.psum(go_r_loc, PARTY_AXIS)  # one round per level (!)
            split_here = tree.owner[node] >= 0     # structure is shared
            node = jnp.where(split_here, 2 * node + 1 + go_r, node)
        inter = (jnp.arange(params.n_nodes)[None, :] == node[:, None])
        return inter & tree.is_leaf[None]

    inter = lax.map(route_tree, trees)                       # (T, N, nn)
    return _combine_votes(inter, masked_leaf_stats(trees), params)


def mask_comm_bytes(n_trees: int, n_rows: int, n_cols: int,
                    mask_dtype=jnp.int32) -> int:
    """Per-party payload of the one-round membership psum, in bytes.

    ``n_cols`` is ``params.n_nodes`` for the dense mask or the LeafTable
    capacity for the compacted one — the serving engine reports both."""
    return n_trees * n_rows * n_cols * jnp.dtype(mask_dtype).itemsize


def comm_rounds(params: ForestParams, method: str) -> int:
    """Analytic collective-round count per forest prediction (paper §Appendix)."""
    if method == "oneround":
        return 1
    if method == "classical":
        return params.n_estimators * params.max_depth
    raise ValueError(method)
