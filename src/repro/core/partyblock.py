"""Party-first data plane: per-party raw blocks keyed by sample IDs.

The paper's system (§3.1, §4.3) starts where each region actually stands:
every party holds its own feature block over its own customer base, keyed by
sample IDs, and training begins with encrypted-ID alignment.  A
:class:`PartyBlock` is that unit of ingestion — raw features + sample IDs +
(for exactly one party) the labels — and :class:`DataSource` is the hook for
loading one from a per-party file (``CSVSource``).

Alignment (:func:`align_party_blocks`) intersects the parties' *hashed* IDs
(crypto.align_ids, the PSI stand-in) and gathers every block onto one
canonical common ordering: the lexicographic sort of the common hashed IDs.
That ordering is invariant to each party's row order and to party order, so
shuffled, superset, out-of-order regional extracts all collapse to the same
aligned sample matrix — which is what makes federated fits from PartyBlocks
bit-identical to the centrally pre-aligned build (tests/test_partyblock.py).

Partition assembly (party-local quantile binning + the stacked
VerticalPartition) lives in core/party.py: ``partition_from_blocks``.
"""
from __future__ import annotations

import csv
import dataclasses
import os
from typing import Protocol, runtime_checkable

import numpy as np

from repro.analysis import runtime as egress_runtime
from repro.core import crypto


@dataclasses.dataclass
class PartyBlock:
    """One party's raw contribution to a federated dataset.

    Attributes:
      name: stable party identifier.  Ingestion orders parties by name
        (canonical party ordering), and serving matches request blocks to
        fit-time parties by it.
      x: (n_i, f_i) float raw feature block — never leaves the party; only
        binned values and masked statistics ever would.
      ids: (n_i,) sample IDs (ints or strings).  Alignment happens on their
        salted hashes; duplicates within a party are rejected.
      y: optional (n_i,) party-held labels, row-aligned with ``ids``.
        Exactly one party of a federation may hold labels.
      feature_ids: optional (f_i,) global column ids.  When set across all
        parties they must partition 0..F-1 (the raw-matrix compat adapter
        uses this to preserve the original column encoding); when omitted,
        ingestion assigns contiguous ids in canonical party order.
      feature_names: optional (f_i,) display names (CSV headers keep them).
    """

    name: str
    x: np.ndarray
    ids: np.ndarray
    y: np.ndarray | None = None
    feature_ids: np.ndarray | None = None
    feature_names: tuple[str, ...] | None = None

    def __post_init__(self) -> None:
        # keep float inputs at their own precision (binning casts to float64
        # internally either way, so losslessness is unaffected; coercing
        # float32 silos would double their memory), promote everything else
        self.x = np.asarray(self.x)
        if not np.issubdtype(self.x.dtype, np.floating):
            self.x = self.x.astype(np.float64)
        self.ids = np.asarray(self.ids).reshape(-1)
        if self.x.ndim != 2:
            raise ValueError(f"party {self.name!r}: x must be (n_samples, "
                             f"n_features), got shape {self.x.shape}")
        if len(self.ids) != self.x.shape[0]:
            raise ValueError(
                f"party {self.name!r}: {len(self.ids)} sample IDs for "
                f"{self.x.shape[0]} feature rows")
        if self.y is not None:
            self.y = np.asarray(self.y).reshape(-1)
            if len(self.y) != self.x.shape[0]:
                raise ValueError(
                    f"party {self.name!r}: {len(self.y)} labels for "
                    f"{self.x.shape[0]} rows")
        if self.feature_ids is not None:
            self.feature_ids = np.asarray(self.feature_ids,
                                          dtype=np.int64).reshape(-1)
            if len(self.feature_ids) != self.x.shape[1]:
                raise ValueError(
                    f"party {self.name!r}: {len(self.feature_ids)} "
                    f"feature_ids for {self.x.shape[1]} columns")
        # tag the final raw arrays for the runtime egress guard (no-op
        # unless REPRO_EGRESS_GUARD=1): these buffers and their views must
        # never reach Channel.send unsanitized
        egress_runtime.taint_block(self)

    @property
    def n_samples(self) -> int:
        return int(self.x.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.x.shape[1])

    def hashed_ids(self, salt: str = crypto.DEFAULT_SALT) -> np.ndarray:
        return crypto.hash_ids(self.ids, salt=salt)

    # ----------------------------------------------------------------- CSV
    @classmethod
    def from_csv(cls, path: str, *, name: str | None = None,
                 id_column: str = "id", label_column: str = "label",
                 delimiter: str = ",") -> "PartyBlock":
        """Load a per-party CSV extract: a header row names the columns,
        ``id_column`` keys the rows, ``label_column`` (if present in the
        header) becomes the party-held labels, every other column is a float
        feature.  ``name`` defaults to the file stem.  Feature headers of
        the form ``gf<N>`` (to_csv's encoding of global feature ids) are
        parsed back into ``feature_ids``, so the to_csv round trip preserves
        the global column encoding.

        Missing or NaN feature cells raise a loud ValueError naming the
        column and row — binning would otherwise silently sort NaNs into the
        last bin and corrupt every split on that feature."""
        with open(path, newline="") as fh:
            rows = list(csv.reader(fh, delimiter=delimiter))
        if not rows:
            raise ValueError(f"{path}: empty CSV")
        header, body = rows[0], rows[1:]
        id_idx, label_idx, feat_idx, names, feature_ids = csv_layout(
            header, path, id_column=id_column, label_column=label_column)
        ids = np.array([r[id_idx] for r in body])
        x = parse_feature_rows(body, feat_idx, header, path)
        y = parse_labels([r[label_idx] for r in body]) \
            if label_idx is not None else None
        return cls(name=name or os.path.splitext(os.path.basename(path))[0],
                   x=x, ids=ids, y=y, feature_ids=feature_ids,
                   feature_names=names)

    def to_csv(self, path: str, *, id_column: str = "id",
               label_column: str = "label") -> str:
        """Write the block as a per-party CSV (the from_csv inverse).

        Global feature ids, when present, are load-bearing for the column
        encoding, so they win over ``feature_names`` as headers: each
        column is written as ``gf<global id>`` and from_csv parses that
        back — a round trip cannot silently reassign the encoding."""
        if self.feature_ids is not None:
            names = tuple(f"gf{j}" for j in self.feature_ids)
        else:
            names = self.feature_names or tuple(
                f"f{j}" for j in range(self.n_features))
        with open(path, "w", newline="") as fh:
            w = csv.writer(fh)
            w.writerow([id_column, *names]
                       + ([label_column] if self.y is not None else []))
            for i in range(self.n_samples):
                row = [self.ids[i], *(repr(float(v)) for v in self.x[i])]
                if self.y is not None:
                    row.append(self.y[i])
                w.writerow(row)
        return path


# -------------------------------------------------------- CSV parse helpers
# Shared by PartyBlock.from_csv and the streaming ChunkedCSVSource: one owner
# of the header layout, the float parse (with the loud NaN/missing contract),
# and the label dtype rule, so a chunked read is bit-identical to from_csv.

def csv_layout(header: list[str], path: str, *, id_column: str = "id",
               label_column: str = "label"):
    """Resolve a CSV header into ``(id_idx, label_idx, feat_idx, names,
    feature_ids)``.  ``label_idx`` is None when no label column is present;
    ``feature_ids`` is parsed from all-``gf<N>`` headers (to_csv's global-id
    encoding) or None."""
    if id_column not in header:
        raise ValueError(f"{path}: no {id_column!r} column in header "
                         f"{header}")
    id_idx = header.index(id_column)
    label_idx = header.index(label_column) if label_column in header else None
    feat_idx = [j for j in range(len(header)) if j not in (id_idx, label_idx)]
    names = tuple(header[j] for j in feat_idx)
    feature_ids = None
    if names and all(n.startswith("gf") and n[2:].isdigit() for n in names):
        feature_ids = np.array([int(n[2:]) for n in names])
    return id_idx, label_idx, feat_idx, names, feature_ids


def parse_feature_rows(body, feat_idx, header, path: str, *,
                       row_offset: int = 0) -> np.ndarray:
    """Parse CSV body rows into a float64 feature matrix, raising a loud
    ValueError naming the column and (global) row index on missing or NaN
    cells instead of letting NaNs reach binning."""
    x = np.empty((len(body), len(feat_idx)), dtype=np.float64)
    for i, r in enumerate(body):
        for k, j in enumerate(feat_idx):
            cell = r[j].strip() if j < len(r) else ""
            v = float(cell) if cell else float("nan")
            if v != v:  # NaN — explicit "nan" cells and missing cells alike
                raise ValueError(
                    f"{path}: missing/NaN value in feature column "
                    f"{header[j]!r} at data row {row_offset + i} — clean or "
                    f"impute before ingest (binning would silently bucket "
                    f"NaNs and corrupt every split on that feature)")
            x[i, k] = v
    return x


def parse_labels(vals: list[str]) -> np.ndarray:
    """The label dtype rule: lexically-integer labels ("3") are class ids
    (int64); anything float-formatted ("3.0") stays float, so to_csv round
    trips regression targets that happen to be whole numbers without a dtype
    change."""
    if vals and all(v.removeprefix("-").removeprefix("+").isdigit()
                    for v in vals):
        return np.array([int(v) for v in vals], dtype=np.int64)
    return np.array([float(v) for v in vals])


def feature_groups(feature_ids_per_party, n_features_per_party):
    """Resolve per-party global feature-id groups — the single owner of the
    all-or-none feature_ids contract shared by every ingest path (in-memory
    ``partition_from_blocks``, distributed workers, streaming assembly).

    When every party declares ``feature_ids`` they must partition 0..F-1
    (ascending within each party); when none do, contiguous ids are assigned
    in the given (canonical) party order.  Returns ``(groups, n_features)``.
    """
    with_ids = [f for f in feature_ids_per_party if f is not None]
    if with_ids and len(with_ids) != len(feature_ids_per_party):
        raise ValueError("feature_ids must be set on every party or none")
    if with_ids:
        groups = [np.sort(np.asarray(f, dtype=np.int64).reshape(-1))
                  for f in feature_ids_per_party]
        all_ids = np.concatenate(groups) if groups else np.empty(0, np.int64)
        n_features = int(all_ids.size)
        if not np.array_equal(np.sort(all_ids), np.arange(n_features)):
            raise ValueError(
                f"feature_ids across parties must partition 0..F-1, got "
                f"{sorted(all_ids.tolist())}")
    else:
        offsets = np.cumsum([0] + list(n_features_per_party))
        groups = [np.arange(offsets[i], offsets[i + 1])
                  for i in range(len(n_features_per_party))]
        n_features = int(offsets[-1])
    return groups, n_features


@runtime_checkable
class DataSource(Protocol):
    """Anything that can produce a PartyBlock — the per-party loading hook
    ``Federation.ingest`` accepts in place of a materialized block."""

    def load(self) -> PartyBlock: ...


@dataclasses.dataclass
class CSVSource:
    """DataSource for a per-party CSV file (see PartyBlock.from_csv)."""

    path: str
    name: str | None = None
    id_column: str = "id"
    label_column: str = "label"
    delimiter: str = ","

    def load(self) -> PartyBlock:
        return PartyBlock.from_csv(self.path, name=self.name,
                                   id_column=self.id_column,
                                   label_column=self.label_column,
                                   delimiter=self.delimiter)


def resolve_blocks(blocks) -> list[PartyBlock]:
    """Materialize a mixed PartyBlock / DataSource sequence."""
    out = []
    for b in blocks:
        if isinstance(b, PartyBlock):
            out.append(b)
        elif isinstance(b, DataSource):
            loaded = b.load()
            if not isinstance(loaded, PartyBlock):
                raise TypeError(f"DataSource {b!r} loaded "
                                f"{type(loaded).__name__}, not a PartyBlock")
            out.append(loaded)
        else:
            raise TypeError(f"expected PartyBlock or DataSource, got "
                            f"{type(b).__name__}")
    names = [b.name for b in out]
    if len(set(names)) != len(names):
        raise ValueError(f"party names must be unique, got {names}")
    return out


def is_block_sequence(data) -> bool:
    """True when ``data`` is a non-empty sequence of PartyBlock/DataSource —
    the dispatch test behind Federation.ingest's two entry shapes."""
    return (isinstance(data, (list, tuple)) and len(data) > 0
            and all(isinstance(b, (PartyBlock, DataSource)) for b in data))


def align_party_blocks(blocks: list[PartyBlock], *,
                       salt: str = crypto.DEFAULT_SALT):
    """Align M party blocks on their hashed sample IDs.

    Returns ``(common_ids, positions)``: the common *raw* IDs in canonical
    order (sorted by hashed value), and one int64 position array per block
    such that ``blocks[i].x[positions[i]]`` rows line up across parties.

    Pre-aligned blocks (every party lists the identical IDs in the identical
    order — the raw-matrix compat adapter) skip the hashing pass: the
    identity alignment is returned directly, preserving the caller's row
    order bit-for-bit.
    """
    for b in blocks:
        if np.unique(b.ids).size != b.ids.size:
            raise ValueError(
                f"party {b.name!r} has duplicate sample IDs: alignment "
                f"would be ambiguous — deduplicate before ingest")
    first = blocks[0].ids
    if all(b.ids.shape == first.shape and np.array_equal(b.ids, first)
           for b in blocks[1:]):
        if first.size == 0:     # the fast path must keep the loud-error
            raise ValueError(   # contract, not fall through to binning
                f"empty hashed-ID intersection across parties "
                f"{[b.name for b in blocks]}: no shared samples to align")
        pos = np.arange(len(first), dtype=np.int64)
        return first.copy(), [pos.copy() for _ in blocks]
    try:
        # uniqueness already validated above with party names attached
        positions = crypto.align_ids(*(b.hashed_ids(salt) for b in blocks),
                                     check_unique=False)
    except ValueError as e:
        if "intersection" not in str(e):
            raise
        raise ValueError(
            f"empty hashed-ID intersection across parties "
            f"{[b.name for b in blocks]}: no shared samples to align "
            f"(same ID space and salt on every party?)") from e
    return blocks[0].ids[positions[0]], list(positions)
