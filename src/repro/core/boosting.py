"""Federated Gradient Boosting (beyond-paper, SecureBoost-style).

The paper's related work ([6] Cheng et al., SecureBoost) applies the same
vertical-federated split protocol to *boosted* trees.  Our level-synchronous
builder composes directly: boosting just changes the statistic channels from
class counts to (gradient, hessian) sums, and the leaf values to the Newton
step -G/(H+λ).  Everything else — the collectives, distributed storage, the
one-round predictor — is reused verbatim, which is the point: the paper's
protocol is a *substrate*, not a single model.

Supported: squared-error regression and binary logistic classification.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from typing import Any

from repro.core.party import VerticalPartition
from repro.core.types import ForestParams


@dataclasses.dataclass(frozen=True)
class BoostParams:
    task: str = "regression"            # "regression" | "binary"
    n_rounds: int = 20
    learning_rate: float = 0.2
    max_depth: int = 4
    min_samples_leaf: int = 1
    n_bins: int = 32
    reg_lambda: float = 1.0
    seed: int = 0
    # plumbed into the per-round tree build (see ForestParams)
    hist_impl: str = "auto"
    frontier_cap: int = 256

    def tree_params(self) -> ForestParams:
        # gradient trees: stats channels are (h, g, g²-ish) via the
        # regression channels (w, wy, wy²) with w=hessian, y=-g/h (see fit);
        # variance-reduction split gain == Newton gain up to constants.
        return ForestParams(task="regression", n_estimators=1,
                            max_depth=self.max_depth,
                            min_samples_leaf=self.min_samples_leaf,
                            n_bins=self.n_bins, bootstrap=False,
                            seed=self.seed, hist_impl=self.hist_impl,
                            frontier_cap=self.frontier_cap)


def stack_rounds(trees: list):
    """Stack per-round PartyTrees (each (M, 1, ...)) into one (M, R, ...)
    PartyTree along the tree axis — the layout the serving engine compiles
    against and ``Federation.save`` checkpoints."""
    if not trees:
        raise ValueError("no fitted rounds to stack")
    if len(trees) == 1:
        return trees[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1), *trees)


def split_rounds(stack) -> list:
    """Inverse of :func:`stack_rounds`: (M, R, ...) -> R (M, 1, ...) trees
    (``Federation.load`` rehydrates the per-round list from a checkpoint)."""
    r = int(stack.is_leaf.shape[1])
    return [jax.tree.map(lambda a: a[:, i:i + 1], stack) for i in range(r)]


@dataclasses.dataclass
class FederatedBoosting:
    params: BoostParams
    # execution substrate (federation.substrate); None -> vmap simulation
    substrate: Any = None
    trees_: list = dataclasses.field(default_factory=list)   # PartyTree per round
    base_: float = 0.0

    def _sub(self):
        from repro.federation.substrate import default_substrate
        return default_substrate(self.substrate)

    def _predict_runner(self):
        """The jitted per-round predict program — built in fit, or lazily
        for models rehydrated from a checkpoint (Federation.load)."""
        if getattr(self, "_pred_run", None) is None:
            from repro.federation import programs
            sub = self._sub()
            self._pred_run = sub.compile(programs.forest_predict_program(
                sub, self.params.tree_params(), tree_sharded=False))
        return self._pred_run

    def fit(self, partition: VerticalPartition, y: np.ndarray):
        from repro.federation import programs
        p = self.params
        tp = p.tree_params()
        y = np.asarray(y, np.float64)
        n = partition.n_samples
        m = partition.n_parties
        if p.task == "binary":
            pos = np.clip(y.mean(), 1e-6, 1 - 1e-6)
            self.base_ = float(np.log(pos / (1 - pos)))
        else:
            self.base_ = float(y.mean())
        f_cur = np.full(n, self.base_)

        xb = jnp.asarray(partition.xb)
        gid = jnp.asarray(partition.feat_gid)
        sel = jnp.ones((1, partition.n_features), bool)
        # one tree per round: never shard the T=1 args over a "trees" axis
        sub = self._sub()
        run = sub.compile(programs.forest_fit_program(sub, tp,
                                                      tree_sharded=False))
        self._pred_run = sub.compile(programs.forest_predict_program(
            sub, tp, tree_sharded=False))

        with sub.context():
            for _ in range(p.n_rounds):
                g, h = self._grad_hess(y, f_cur)
                # regression channels on the Newton pseudo-target: w = h,
                # y_pseudo = -g/h  =>  leaf mean = -G/H (ridge folded via +λ
                # pseudo-observations at 0 is approximated by reg_lambda in h)
                hh = h + p.reg_lambda / max(n, 1)
                pseudo = -g / hh
                stats = jnp.stack(
                    [jnp.asarray(hh, jnp.float32),
                     jnp.asarray(hh * pseudo, jnp.float32),
                     jnp.asarray(hh * pseudo * pseudo, jnp.float32)],
                    axis=-1)
                w = jnp.ones((1, n), jnp.float32)
                trees = run(xb, gid, sel, w, stats)
                self.trees_.append(trees)
                step = programs.party0(self._pred_run(trees, xb))
                f_cur = f_cur + p.learning_rate * step
        self._partition = partition
        return self

    def _grad_hess(self, y, f):
        if self.params.task == "binary":
            prob = 1.0 / (1.0 + np.exp(-f))
            return prob - y, np.maximum(prob * (1 - prob), 1e-6)
        return f - y, np.ones_like(y)

    def decision_function(self, x_test: np.ndarray) -> np.ndarray:
        from repro.federation import programs
        xb = jnp.asarray(self._partition.bin_test(np.asarray(x_test)))
        f = np.full(x_test.shape[0], self.base_)
        run = self._predict_runner()
        with self._sub().context():
            for trees in self.trees_:
                f = f + self.params.learning_rate * programs.party0(
                    run(trees, xb))
        return f

    def predict(self, x_test: np.ndarray) -> np.ndarray:
        f = self.decision_function(x_test)
        if self.params.task == "binary":
            return (f > 0).astype(np.int64)
        return f
