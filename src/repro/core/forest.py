"""FederatedForest — the user-facing estimator (fit/predict).

Orchestrates: master-side randomness (bootstrap weights + per-tree feature
subsets, paper Alg. 2 lines 3–4), label encoding (crypto.py), the SPMD
builder (tree.py) and the one-round predictor (prediction.py).  Execution
goes through a federation Substrate (vmap simulation by default; a session
can bind a sharded mesh instead) — the programs themselves live in
repro.federation.programs.

The centralized baseline ("NonFF") is *the same code* with M = 1 — that is the
strongest possible form of the paper's losslessness claim, and it's what the
tests assert bit-identically.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crypto, impurity, tree
from repro.core.party import VerticalPartition, make_vertical_partition
from repro.core.types import ForestParams


@dataclasses.dataclass
class FederatedForest:
    params: ForestParams
    encrypt_labels: bool = True
    # Regression-target masking is opt-in: the affine mask preserves split
    # gains exactly in real arithmetic but not in float32 (catastrophic
    # cancellation near gain ties), so it trades exact losslessness for
    # in-transit privacy — the same trade-off the paper concedes in §4.3
    # ("there will be a trade-off between the security protection and the
    # computational efficiency").
    mask_regression: bool = False
    # DEPRECATED: per-estimator histogram override.  The backend choice is
    # session-level now — set Federation(hist_impl=...) or params.hist_impl.
    hist_impl: str | None = None
    # execution substrate (federation.substrate); None -> vmap simulation
    substrate: Any = None

    # fitted state
    trees_: tree.PartyTree | None = None      # leading axes (M, T, ...)
    partition_: VerticalPartition | None = None
    _decode: Callable | None = None

    def __post_init__(self) -> None:
        if self.hist_impl is not None:
            warnings.warn(
                "FederatedForest(hist_impl=...) is deprecated: the histogram "
                "backend is owned by the session (Federation(hist_impl=...)) "
                "or by ForestParams.hist_impl",
                DeprecationWarning, stacklevel=3)

    def _sub(self):
        from repro.federation.substrate import default_substrate
        return default_substrate(self.substrate)

    # ------------------------------------------------------------------ fit
    def fit(self, partition: VerticalPartition, y: np.ndarray) -> "FederatedForest":
        from repro.federation import programs
        # "auto" build knobs resolve against the actual training set — the
        # concrete values land back on self.params so refits/serving see them
        self.params = self.params.resolved(partition.n_samples)
        p = self.params
        if partition.xb.shape[2] == 0:
            raise ValueError("empty feature space")
        y = np.asarray(y)
        if self.encrypt_labels and p.task == "classification":
            y_enc, self._decode = crypto.encode_labels(y, p.n_classes, p.seed)
        elif self.mask_regression and p.task == "regression":
            y_enc, self._decode = crypto.mask_regression_targets(y, p.seed)
        else:
            y_enc, self._decode = y, lambda v: np.asarray(v)

        y_stats = impurity.stat_channels(jnp.asarray(y_enc), p.task, p.n_classes)
        weights, feat_sels = self._master_randomness(partition)

        run = self._sub().compile(programs.forest_fit_program(self._sub(), p,
                                                              self.hist_impl))
        with self._sub().context():
            self.trees_ = jax.block_until_ready(run(
                jnp.asarray(partition.xb), jnp.asarray(partition.feat_gid),
                jnp.asarray(feat_sels), jnp.asarray(weights), y_stats))
        self.partition_ = partition
        return self

    def _master_randomness(self, partition: VerticalPartition):
        """Paper Alg. 2: master samples rows (bootstrap) + per-tree features.

        Each tree draws from its own seeded stream
        (``default_rng([seed, t])``), so tree t's bootstrap and feature
        subset depend only on (seed, t) — never on how many trees the forest
        will eventually hold.  That prefix-stability is what makes an
        incremental continuation exact: extending a fitted T-tree forest to
        T' trees produces bit-identically the first T trees of a from-scratch
        T'-tree fit (fit_resumable's tree-extension path relies on it)."""
        p = self.params
        n, f = partition.n_samples, partition.n_features
        t = p.n_estimators
        k = max(1, int(np.ceil(p.max_features * f)))
        weights = np.ones((t, n))
        feat_sels = np.zeros((t, f), dtype=bool)
        for i in range(t):
            rng = np.random.default_rng([p.seed, i])
            if p.bootstrap:
                weights[i] = np.bincount(rng.integers(0, n, size=n),
                                         minlength=n)
            feat_sels[i, rng.choice(f, size=k, replace=False)] = True
        return weights.astype(np.float32), feat_sels

    def _fit_fingerprint(self, partition: VerticalPartition,
                         y: np.ndarray) -> str:
        """Content hash of everything a resumable fit depends on EXCEPT the
        tree count: the binned data, the labels, and the params.  A
        checkpoint tagged with a different fingerprint must not be resumed —
        appending rows (ingest_append) changes the partition, and welding
        old-data trees onto new-data trees would silently produce a
        franken-forest.  n_estimators is excluded so growing the tree count
        IS resumable (per-tree randomness makes the prefix exact)."""
        import hashlib
        h = hashlib.sha256()
        for a in (partition.xb, partition.feat_gid, partition.boundaries,
                  np.asarray(y)):
            h.update(np.ascontiguousarray(a).tobytes())
        h.update(repr(dataclasses.replace(
            self.params, n_estimators=0)).encode())
        h.update(repr((self.encrypt_labels, self.mask_regression)).encode())
        return h.hexdigest()

    # -------------------------------------------------------------- predict
    def _run_predict(self, x_test: np.ndarray, program, *shared) -> np.ndarray:
        from repro.federation import programs
        if self.trees_ is None:
            raise ValueError("model is not fitted: call fit() first")
        xb_parts = self.partition_.bin_test(np.asarray(x_test))
        with self._sub().context():
            out = self._sub().compile(program)(self.trees_,
                                               jnp.asarray(xb_parts), *shared)
        return self._decode(programs.party0(out))

    def predict(self, x_test: np.ndarray) -> np.ndarray:
        """One-round prediction (the paper's algorithm)."""
        from repro.federation import programs
        return self._run_predict(
            x_test, programs.forest_predict_program(self._sub(), self.params))

    def predict_classical(self, x_test: np.ndarray) -> np.ndarray:
        """Multi-round baseline (paper's comparison in Figs. 4–6)."""
        from repro.federation import programs
        return self._run_predict(
            x_test,
            programs.forest_predict_classical_program(self._sub(), self.params))

    def leaf_table(self, pad_multiple: int = 8):
        """Live-leaf compaction plan of the fitted forest (serving/plan.py)."""
        from repro.serving import plan
        if self.trees_ is None:
            raise ValueError("model is not fitted: call fit() first")
        return plan.build_leaf_table(self.trees_, self.params,
                                     pad_multiple=pad_multiple)

    def predict_compact(self, x_test: np.ndarray,
                        leaf_table=None) -> np.ndarray:
        """One-round prediction through the leaf-compacted mask.

        Bit-identical to :meth:`predict` (Prop. 1 is unchanged; only dead
        heap columns are dropped from the psum and the vote) — the serving
        engine's kernel, exposed here for parity tests and ad-hoc use."""
        from repro.federation import programs
        if self.trees_ is None:
            raise ValueError("model is not fitted: call fit() first")
        lt = leaf_table if leaf_table is not None else self.leaf_table()
        return self._run_predict(
            x_test,
            programs.forest_predict_program(self._sub(), self.params,
                                            compact=True),
            lt.leaf_idx)

    # ------------------------------------------------- break-point recovery
    def fit_resumable(self, partition: VerticalPartition, y: np.ndarray,
                      ckpt_dir: str, trees_per_chunk: int = 2) -> "FederatedForest":
        """Paper §4.1: "if the connection is down, the modeling can be easily
        recovered from the break point."  Trees are independent (bagging), so
        recovery granularity = tree chunks: each chunk's PartyTree stack is
        checkpointed; a restarted fit resumes after the last complete chunk
        and produces the IDENTICAL forest (master randomness is derived from
        the seed, not from progress).

        Checkpoints carry a fingerprint of (binned data, labels, params sans
        tree count): a checkpoint from different data or params is ignored
        and the fit restarts from scratch instead of welding incompatible
        tree prefixes together.  Two incremental moves are therefore exact:

          * **more trees** — rerun with a larger ``n_estimators``: the
            checkpointed prefix is reused and only the new trees build
            (per-tree randomness makes the result bit-identical to a
            from-scratch fit at the larger count);
          * **more rows** — after ``Federation.ingest_append`` the partition
            changed, the fingerprint mismatches, and the refit is cleanly
            from scratch on the concatenated data.

        A checkpoint AHEAD of ``n_estimators`` (trained further in a prior
        run) restores and slices its first ``n_estimators`` trees — also
        exact, for the same reason."""
        from repro import ckpt
        self.params = self.params.resolved(partition.n_samples)
        p = self.params
        y = np.asarray(y)
        if self.encrypt_labels and p.task == "classification":
            y_enc, self._decode = crypto.encode_labels(y, p.n_classes, p.seed)
        else:
            y_enc, self._decode = y, lambda v: np.asarray(v)
        y_stats = impurity.stat_channels(jnp.asarray(y_enc), p.task, p.n_classes)
        weights, feat_sels = self._master_randomness(partition)
        fingerprint = self._fit_fingerprint(partition, y)

        from repro.federation import programs
        run = self._sub().compile(programs.forest_fit_program(self._sub(), p,
                                                              self.hist_impl))

        def restore(done):
            # PartyTree stack shapes are fully determined by (M, done, params)
            # — no need to trace the fit program (which the distributed
            # substrate could not trace anyway).
            m, nn, c = partition.n_parties, p.n_nodes, p.n_stat_channels
            sds = jax.ShapeDtypeStruct
            like = tree.PartyTree(
                is_leaf=sds((m, done, nn), jnp.bool_),
                leaf_stats=sds((m, done, nn, c), jnp.float32),
                has_split=sds((m, done, nn), jnp.bool_),
                split_floc=sds((m, done, nn), jnp.int32),
                split_bin=sds((m, done, nn), jnp.int32),
                owner=sds((m, done, nn), jnp.int32),
                split_gid=sds((m, done, nn), jnp.int32))
            return ckpt.restore_checkpoint(ckpt_dir, done, like)

        chunks: list = []
        done = ckpt.latest_step(ckpt_dir)
        if done is not None:
            # legacy pre-fingerprint checkpoints (meta without the key) are
            # trusted as before; a PRESENT-but-different fingerprint means
            # the data or params moved under the checkpoint — start over
            stamp = ckpt.read_meta(ckpt_dir, done).get("fingerprint")
            if stamp is not None and stamp != fingerprint:
                done = None
        start = 0
        if done is not None and done >= p.n_estimators:
            full = restore(done)
            self.trees_ = jax.tree.map(
                lambda a: a[:, : p.n_estimators], full)
            self.partition_ = partition
            return self
        if done is not None:
            chunks.append(restore(done))
            start = done
        for lo in range(start, p.n_estimators, trees_per_chunk):
            hi = min(lo + trees_per_chunk, p.n_estimators)
            part_trees = run(jnp.asarray(partition.xb),
                             jnp.asarray(partition.feat_gid),
                             jnp.asarray(feat_sels[lo:hi]),
                             jnp.asarray(weights[lo:hi]), y_stats)
            chunks.append(part_trees)
            merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                                  *chunks)
            ckpt.save_checkpoint(ckpt_dir, hi, merged,
                                 meta={"family": "forest",
                                       "fingerprint": fingerprint})
            chunks = [merged]
        self.trees_ = chunks[0]
        self.partition_ = partition
        return self

    # ------------------------------------------------------------ inspection
    def feature_importance(self, view: str = "master") -> np.ndarray:
        """Split-count importance over encoded feature ids (privacy-aware:
        ``view='party:i'`` restricts to party i's own splits — what each
        participant may legitimately compute locally)."""
        if self.trees_ is None:
            raise ValueError("model is not fitted: call fit() first")
        trees = jax.tree.map(np.asarray, self.trees_)
        counts = np.zeros(self.partition_.n_features, np.float64)
        gids = trees.split_gid[0]             # master view (T, nn)
        weights = trees.leaf_stats[0].sum(-1)  # node weighted counts (T, nn)
        if view.startswith("party:"):
            i = int(view.split(":")[1])
            mine = trees.has_split[i]
            gids = np.where(mine, gids, -1)
        sel = gids >= 0
        np.add.at(counts, gids[sel], weights[sel])
        total = counts.sum()
        return counts / total if total else counts

    def master_tree_view(self):
        """The complete model T as the master stores it (owner + encoded id)."""
        if self.trees_ is None:
            raise ValueError("model is not fitted: call fit() first")
        t = jax.tree.map(lambda a: np.asarray(a[0]), self.trees_)
        return {"owner": t.owner, "split_gid": t.split_gid,
                "is_leaf": t.is_leaf, "leaf_stats": t.leaf_stats}


def fit_federated_forest(x: np.ndarray, y: np.ndarray, n_parties: int,
                         params: ForestParams, *, contiguous: bool = True,
                         **forest_kw) -> FederatedForest:
    """Convenience: vertical-partition a raw matrix and fit."""
    part = make_vertical_partition(x, n_parties, params.n_bins,
                                   contiguous=contiguous, seed=params.seed)
    return FederatedForest(params, **forest_kw).fit(part, y)
