"""Federated Forest core — the paper's contribution as a composable JAX module."""
from repro.core.boosting import BoostParams, FederatedBoosting  # noqa: F401
from repro.core.fedlinear import FederatedLinear, LinearParams  # noqa: F401
from repro.core.forest import FederatedForest, fit_federated_forest  # noqa: F401
from repro.core.party import (VerticalPartition, make_vertical_partition,  # noqa: F401
                              partition_from_blocks)
from repro.core.partyblock import (CSVSource, DataSource, PartyBlock,  # noqa: F401
                                   align_party_blocks)
from repro.core.types import ForestParams, PARTY_AXIS  # noqa: F401
