"""Privacy layer (paper §4.3) — simulation-grade, same trust model as the paper.

The paper's own mechanisms are deliberately lightweight (MD5-hashed IDs,
"encrypted" labels shared to every client, RSA/AES on the wire).  We mirror
that model honestly rather than pretend at MPC:

  * sample IDs: salted SHA-256 (MD5 is broken; same role, stronger hash) —
    alignment happens on hashed IDs only;
  * labels: class-id permutation "encoding" for classification (training is
    invariant to it), affine masking for regression targets (variance-based
    split gains are invariant to affine maps of y);
  * feature names: random integer encoding (the master only ever sees encoded
    ids — our ``feat_gid``);
  * gains in transit: additive masks that cancel under the all-reduce, so the
    aggregate argmax input is exact while any single message is masked.

None of this is semantically-secure MPC — neither is the paper's. The point
is that the *information flow* matches §4.3: raw features never leave a
party; the master sees only encoded ids and masked statistics.
"""
from __future__ import annotations

import hashlib

import numpy as np

DEFAULT_SALT = "repro-ff"

# preimage "salt:id" -> hexdigest.  The serving request path re-hashes every
# request's sample IDs for alignment; production traffic revisits the same ID
# universe wave after wave, so the crypto loop is memoized.  Bounded: when
# full it is cleared wholesale (IDs re-hash on the next request) rather than
# growing one entry per distinct ID forever.
_HASH_CACHE: dict[str, str] = {}
_HASH_CACHE_MAX = 1 << 20


def hash_ids(ids, salt: str = DEFAULT_SALT) -> np.ndarray:
    """Irreversible sample-ID encryption for alignment (paper: MD5).

    Memoized per (salt, id) preimage — repeated serving requests over the
    same ID universe skip the sha256 loop entirely.  Bit-identical to the
    uncached digest by construction (the cache stores the digest itself)."""
    cache, sha256 = _HASH_CACHE, hashlib.sha256
    if len(cache) > _HASH_CACHE_MAX:
        cache.clear()
    out = []
    for i in ids:
        key = f"{salt}:{i}"
        h = cache.get(key)
        if h is None:
            h = sha256(key.encode()).hexdigest()
            cache[key] = h
        out.append(h)
    return np.asarray(out)


def align_ids(*hashed_parties: np.ndarray,
              check_unique: bool = True) -> tuple[np.ndarray, ...]:
    """Private-set-intersection stand-in, generalized to M parties.

    Iterated hashed-ID intersection (paper §4.3: alignment sees hashed IDs
    only).  Returns one int64 position array per party; gathering party i's
    rows at ``positions[i]`` puts every party on the same **canonical common
    ordering** — the lexicographic sort of the common hashed IDs — which is
    invariant to each party's row order and to the order the parties are
    listed in.

    Raises ValueError on duplicate hashed IDs within a party (alignment
    would be ambiguous) and on an empty intersection (no shared samples).
    Callers that already validated per-party uniqueness upstream (with
    better error context, e.g. partyblock.align_party_blocks naming the
    party) pass ``check_unique=False`` to skip the second O(n log n) sort —
    the serving request path hits this per request.
    """
    if not hashed_parties:
        raise ValueError("align_ids needs at least one party's hashed IDs")
    hs = [np.asarray(h).reshape(-1) for h in hashed_parties]
    if check_unique:
        for i, h in enumerate(hs):
            if np.unique(h).size != h.size:
                raise ValueError(
                    f"party {i} has duplicate sample IDs: alignment on "
                    f"hashed IDs is ambiguous — deduplicate before ingest")
    common = np.sort(hs[0])
    for h in hs[1:]:
        common = np.intersect1d(common, h, assume_unique=True)
    if common.size == 0:
        raise ValueError(
            f"empty hashed-ID intersection across {len(hs)} parties: the "
            f"parties share no samples (same salt on every party?)")
    out = []
    for h in hs:
        order = np.argsort(h)
        out.append(order[np.searchsorted(h, common, sorter=order)]
                   .astype(np.int64))
    return tuple(out)


def align_hashed(hashes, names, *, check_unique: bool = True,
                 identity_fast_path: bool = True):
    """Align M parties' already-hashed ID arrays with the loud-error contract.

    The shared back half of every ingest path (distributed workers,
    streaming sources): validates per-party uniqueness with the party *name*
    attached, takes the pre-aligned identity fast path when all arrays are
    equal (preserving the caller's row order bit-for-bit), and otherwise
    runs :func:`align_ids` onto the canonical sorted-hash common ordering —
    rewording the empty-intersection error with the party names.

    Callers that decide the fast path on *raw* IDs themselves (the local
    streaming plane, mirroring align_party_blocks exactly) pass
    ``identity_fast_path=False`` so equal hashes of unequal raw IDs cannot
    skip the canonical reordering.

    Returns ``(positions, common_hashed)``: one int64 position array per
    party and the common hashed IDs in the aligned order.
    """
    hs = [np.asarray(h).reshape(-1) for h in hashes]
    if check_unique:
        for h, name in zip(hs, names):
            if np.unique(h).size != h.size:
                raise ValueError(
                    f"party {name!r} has duplicate sample IDs: alignment "
                    f"would be ambiguous — deduplicate before ingest")
    first = hs[0]
    if identity_fast_path and all(h.shape == first.shape
                                  and np.array_equal(h, first)
                                  for h in hs[1:]):
        if first.size == 0:     # the fast path must keep the loud-error
            raise ValueError(   # contract, not fall through to binning
                f"empty hashed-ID intersection across parties "
                f"{list(names)}: no shared samples to align")
        pos = np.arange(len(first), dtype=np.int64)
        return [pos.copy() for _ in hs], first.copy()
    try:
        positions = list(align_ids(*hs, check_unique=False))
    except ValueError as e:
        if "intersection" not in str(e):
            raise
        raise ValueError(
            f"empty hashed-ID intersection across parties "
            f"{list(names)}: no shared samples to align "
            f"(same ID space and salt on every party?)") from e
    return positions, hs[0][positions[0]]


def encode_labels(y: np.ndarray, n_classes: int, seed: int = 0):
    """Permute class ids: clients train on encoded labels (classification is
    invariant); only the label owner can decode. Returns (y_enc, decode)."""
    perm = np.random.default_rng(seed).permutation(n_classes)
    return perm[y.astype(np.int64)], label_decoder(n_classes, seed)


def label_decoder(n_classes: int, seed: int = 0):
    """Reconstruct encode_labels' decode from (n_classes, seed) alone — the
    label owner can decode a checkpoint-restored forest without the original
    training labels in memory (Federation.load relies on this)."""
    inv = np.argsort(np.random.default_rng(seed).permutation(n_classes))
    return lambda y_enc: inv[np.asarray(y_enc, dtype=np.int64)]


def mask_regression_targets(y: np.ndarray, seed: int = 0):
    """Affine mask a*y + b (a>0): SSE split gains scale by a^2, so the argmax
    split — hence the tree — is unchanged; leaf values decode affinely."""
    a, b = _regression_mask(seed)
    return a * y + b, regression_unmasker(seed)


def _regression_mask(seed: int) -> tuple[float, float]:
    rng = np.random.default_rng(seed)
    return float(rng.uniform(0.5, 2.0)), float(rng.normal())


def regression_unmasker(seed: int = 0):
    """Reconstruct mask_regression_targets' decode from the seed alone
    (Federation.load, same role as label_decoder for classification)."""
    a, b = _regression_mask(seed)
    return lambda p: (np.asarray(p) - b) / a


def encode_feature_names(names: list[str], seed: int = 0) -> dict[str, int]:
    """Random integer encoding of feature names (master sees only these)."""
    perm = np.random.default_rng(seed).permutation(len(names))
    return {n: int(e) for n, e in zip(names, perm)}


def pairwise_cancelling_masks(n_parties: int, shape, seed: int = 0) -> np.ndarray:
    """(M, *shape) float32 masks with sum_i mask_i == 0: adding mask_i to party
    i's message hides it point-to-point while psum recovers the exact sum."""
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(n_parties, *shape)).astype(np.float32)
    m[-1] = -m[:-1].sum(0)
    return m
