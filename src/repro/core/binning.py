"""Quantile binning of raw features (LightGBM-style, TPU adaptation).

The paper searches exact thresholds over raw feature values — a sort-heavy,
scatter-heavy pattern that is hostile to the TPU's dense compute units.  We
instead discretize each feature once into <=256 quantile bins (uint8) so that
split finding becomes a dense histogram contraction on the MXU.

Binning is a *per-feature* transformation, so it is identical whether computed
by one central party or independently by each vertical party on its own
columns — losslessness of FF vs. the centralized baseline is unaffected.
"""
from __future__ import annotations

import numpy as np


def interior_quantiles(n_bins: int) -> np.ndarray:
    """The n_bins - 1 interior quantile levels a bin grid is cut at.

    Single owner of the grid definition: the in-memory path
    (:func:`quantile_boundaries`) and the streaming quantile sketch
    (repro.streaming.sketch) both cut at exactly these levels, which is what
    makes an uncompacted sketch's edges bit-identical to the dense build.
    """
    return np.linspace(0.0, 1.0, n_bins + 1)[1:-1]


def quantile_boundaries(x: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature upper-boundary grid, shape (F, n_bins - 1).

    Bin b of feature f holds values in (boundaries[f, b-1], boundaries[f, b]].
    Constant features get degenerate (all-equal) boundaries and always land in
    bin 0, which makes every candidate split on them gainless.
    """
    x = np.asarray(x, dtype=np.float64)
    if x.ndim != 2:
        raise ValueError("expected (n_samples, n_features)")
    qs = interior_quantiles(n_bins)
    return np.quantile(x, qs, axis=0).T.astype(np.float64)  # (F, n_bins-1)


def apply_bins(x: np.ndarray, boundaries: np.ndarray) -> np.ndarray:
    """Digitize raw values into uint8 bin ids with the given boundaries."""
    x = np.asarray(x, dtype=np.float64)
    out = np.empty(x.shape, dtype=np.uint8)
    for f in range(x.shape[1]):  # per-feature searchsorted (fit-time, NumPy)
        out[:, f] = np.searchsorted(boundaries[f], x[:, f], side="left")
    return out


def bin_dataset(x: np.ndarray, n_bins: int) -> tuple[np.ndarray, np.ndarray]:
    """Fit + apply quantile binning. Returns (binned uint8, boundaries)."""
    b = quantile_boundaries(x, n_bins)
    return apply_bins(x, b), b
