"""Level-synchronous federated CART builder (the paper's Alg. 1/2/5/6).

This is SPMD code: one logical "party" per index of the ``parties`` axis.  It
runs unchanged under

  * ``jax.vmap(..., axis_name=PARTY_AXIS)``           — single-host simulation
  * ``shard_map`` over a mesh axis named ``parties``  — production (dry-run)

TPU adaptation of the paper's recursive MPI algorithm (see DESIGN.md §2):

  * breadth-first level building: all ``2^d`` nodes of a depth split together;
    the master's per-node gather/argmax/notify/broadcast round-trips collapse
    into THREE collectives per level (all_gather of masked local bests, and
    one psum carrying the owner-computed partition bits);
  * the master is dissolved into those collectives — every party evaluates the
    argmax of the gathered (gain, feature-id) pairs identically, which is the
    same function the trusted server computes in the paper;
  * trees live in fixed-shape heap arrays (node i -> children 2i+1, 2i+2).

Distributed model storage is preserved exactly: a party records (feature,
threshold) only for nodes it owns (``has_split``); the shared structure
(``is_leaf`` + heap layout) is what the paper calls "keeping the node
structure"; ``owner``/``split_gid`` are the master-side view.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import impurity
from repro.core.types import PARTY_AXIS, ForestParams
from repro.kernels import ops

_BIG = jnp.int32(2**30)


class PartyTree(NamedTuple):
    """One party's view of one tree (all arrays sized n_nodes = 2^(k+1)-1)."""

    is_leaf: jnp.ndarray      # (nn,)  bool   — shared structure
    leaf_stats: jnp.ndarray   # (nn,C) f32    — shared (labels are shared, §4.3)
    has_split: jnp.ndarray    # (nn,)  bool   — "this node's split is mine"
    split_floc: jnp.ndarray   # (nn,)  int32  — LOCAL feature index (mine only)
    split_bin: jnp.ndarray    # (nn,)  int32  — split bin   (mine only)
    owner: jnp.ndarray        # (nn,)  int32  — master view: owning party
    split_gid: jnp.ndarray    # (nn,)  int32  — master view: encoded feature id


def _local_argbest(gains: jnp.ndarray, feat_gid: jnp.ndarray):
    """Per-node best split with the deterministic lexicographic tie-break
    (max gain, then min global feature id, then min bin).

    The two-stage max — local per party, then global across parties — yields
    exactly the same winner as a centralized single pass because max and the
    lexicographic tie-break are associative.  This is what makes FF(M) ==
    FF(1) *bit-identical*, not just statistically close.
    """
    _, fp, bm1 = gains.shape
    g = gains.max((1, 2))
    elig = (gains == g[:, None, None]) & jnp.isfinite(gains)
    gid_m = jnp.broadcast_to(feat_gid[None, :, None].astype(jnp.int32), gains.shape)
    gid = jnp.where(elig, gid_m, _BIG).min((1, 2))
    sel = elig & (gid_m == gid[:, None, None])
    bin_m = jnp.broadcast_to(jnp.arange(bm1, dtype=jnp.int32)[None, None, :], gains.shape)
    bin_ = jnp.where(sel, bin_m, _BIG).min((1, 2))
    floc_m = jnp.broadcast_to(jnp.arange(fp, dtype=jnp.int32)[None, :, None], gains.shape)
    floc = jnp.where(sel, floc_m, _BIG).min((1, 2))
    return g, gid, bin_, floc


def build_tree(xb: jnp.ndarray, feat_gid: jnp.ndarray, feat_sel: jnp.ndarray,
               weight: jnp.ndarray, y_stats: jnp.ndarray,
               params: ForestParams, *, hist_impl: str = "scatter") -> PartyTree:
    """Build one tree, SPMD over PARTY_AXIS.

    Args:
      xb:       (N, Fp) uint8 party-local binned features (padded).
      feat_gid: (Fp,) int32 global feature ids, -1 for padding.
      feat_sel: (F,) bool master's per-tree feature subsample (global ids).
      weight:   (N,) float32 bootstrap weights (0 excludes a sample).
      y_stats:  (N, C) label stat channels — shared across parties (the paper
                copies encrypted labels to every client, §3.1).
    """
    n, fp_dim = xb.shape
    c = y_stats.shape[-1]
    nn = params.n_nodes
    me = lax.axis_index(PARTY_AXIS)
    task = params.task

    fmask = (feat_gid >= 0) & feat_sel[jnp.clip(feat_gid, 0)]
    wstats = y_stats.astype(jnp.float32) * weight[:, None]

    node = jnp.zeros((n,), jnp.int32)
    is_leaf = jnp.zeros((nn,), bool)
    leaf_stats = jnp.zeros((nn, c), jnp.float32)
    has_split = jnp.zeros((nn,), bool)
    split_floc = jnp.full((nn,), -1, jnp.int32)
    split_bin = jnp.full((nn,), -1, jnp.int32)
    owner = jnp.full((nn,), -1, jnp.int32)
    split_gid = jnp.full((nn,), -1, jnp.int32)
    prev_hist = None  # parent-level histograms (hist_subtraction)

    for d in range(params.max_depth + 1):
        off, width = params.level_slice(d)
        nil = node - off
        in_lvl = (nil >= 0) & (nil < width)
        seg = jnp.where(in_lvl, nil, -1)

        # Node label stats — computed identically by every party (shared y).
        dump = jnp.where(seg >= 0, seg, width)
        nstats = jnp.zeros((width + 1, c), jnp.float32).at[dump].add(wstats)[:width]
        cnt = impurity.count_of(nstats, task)
        leaf_stats = lax.dynamic_update_slice(leaf_stats, nstats, (off, 0))

        if d == params.max_depth:  # bottom level: everything alive is a leaf
            is_leaf = lax.dynamic_update_slice(is_leaf, cnt > 0, (off,))
            break

        # ---- local split search (the Pallas histogram hot spot) ------------
        if params.hist_subtraction and prev_hist is not None:
            # Beyond-paper: histogram only the LEFT children (half the node
            # one-hot width), derive the right siblings by subtraction from
            # the retained parent histograms. Children of leaf parents get
            # garbage rows, but do_split is gated on cnt (true sample
            # counts), so they can never be selected.
            left_seg = jnp.where((seg >= 0) & (seg % 2 == 0), seg // 2, -1)
            hist_left = ops.histogram(xb.astype(jnp.int32), left_seg, wstats,
                                      width // 2, params.n_bins,
                                      impl=hist_impl)
            hist = jnp.stack([hist_left, prev_hist - hist_left],
                             axis=1).reshape(width, fp_dim, params.n_bins, c)
        else:
            hist = ops.histogram(xb.astype(jnp.int32), seg, wstats, width,
                                 params.n_bins, impl=hist_impl)
        prev_hist = hist
        gains = impurity.split_gains(hist, task, params.min_samples_leaf)
        gains = jnp.where(fmask[None, :, None], gains, -jnp.inf)
        g_loc, gid_loc, bin_loc, floc_loc = _local_argbest(gains, feat_gid)

        # ---- the paper's master: gather -> argmax -> notify, as collectives
        g_all = lax.all_gather(g_loc, PARTY_AXIS)          # (M, width)
        gid_all = lax.all_gather(gid_loc, PARTY_AXIS)
        bin_all = lax.all_gather(bin_loc, PARTY_AXIS)
        g_best = g_all.max(0)
        elig = (g_all == g_best[None]) & jnp.isfinite(g_all)
        gid_best = jnp.where(elig, gid_all, _BIG).min(0)
        sel = elig & (gid_all == gid_best[None])
        m = g_all.shape[0]
        owner_lv = jnp.where(sel, jnp.arange(m, dtype=jnp.int32)[:, None], _BIG).min(0)
        bin_best = jnp.where(sel, bin_all, _BIG).min(0)

        thr = max(params.min_impurity_decrease, 1e-9)
        do_split = (jnp.isfinite(g_best) & (g_best > thr)
                    & (cnt >= params.min_samples_split))
        is_leaf = lax.dynamic_update_slice(is_leaf, (cnt > 0) & ~do_split, (off,))

        mine = do_split & (owner_lv == me)  # "receive the split message" (Alg.1)
        has_split = lax.dynamic_update_slice(has_split, mine, (off,))
        split_floc = lax.dynamic_update_slice(
            split_floc, jnp.where(mine, floc_loc, -1), (off,))
        split_bin = lax.dynamic_update_slice(
            split_bin, jnp.where(mine, bin_loc, -1), (off,))
        owner = lax.dynamic_update_slice(
            owner, jnp.where(do_split, owner_lv.astype(jnp.int32), -1), (off,))
        split_gid = lax.dynamic_update_slice(
            split_gid, jnp.where(do_split, gid_best, -1), (off,))

        # ---- owner computes the partition; one psum broadcasts it ----------
        # (paper Alg.2: "Receive split indices from client j and broadcast")
        nil_c = jnp.clip(nil, 0, width - 1)
        floc_lv = jnp.where(mine, floc_loc, 0)
        bin_lv = jnp.where(mine, bin_loc, 0)
        mine_s = in_lvl & mine[nil_c]
        vals = jnp.take_along_axis(
            xb.astype(jnp.int32), floc_lv[nil_c][:, None], axis=1)[:, 0]
        go_r_loc = jnp.where(mine_s, (vals > bin_lv[nil_c]).astype(jnp.int32), 0)
        go_r = lax.psum(go_r_loc, PARTY_AXIS)  # exactly one party contributes
        advance = in_lvl & do_split[nil_c]
        node = jnp.where(advance, 2 * node + 1 + go_r, node)

    return PartyTree(is_leaf, leaf_stats, has_split, split_floc, split_bin,
                     owner, split_gid)


def build_forest(xb, feat_gid, feat_sels, weights, y_stats,
                 params: ForestParams, *, hist_impl: str = "scatter") -> PartyTree:
    """SPMD bagging loop: stack T trees (leading axis T on every leaf).

    ``lax.map`` keeps HLO size O(1) in the number of trees and bounds peak
    histogram memory to one tree's level at a time.
    """
    def one(args):
        sel, w = args
        return build_tree(xb, feat_gid, sel, w, y_stats, params,
                          hist_impl=hist_impl)
    return lax.map(one, (feat_sels, weights))


def fit_spmd(params: ForestParams, hist_impl: str = "scatter"):
    """Returns the party-local SPMD fit function (for vmap or shard_map)."""
    return functools.partial(build_forest, params=params, hist_impl=hist_impl)
