"""Level-synchronous federated CART builder (the paper's Alg. 1/2/5/6).

This is SPMD code: one logical "party" per index of the ``parties`` axis.  It
runs unchanged under

  * ``jax.vmap(..., axis_name=PARTY_AXIS)``           — single-host simulation
  * ``shard_map`` over a mesh axis named ``parties``  — production (dry-run)

TPU adaptation of the paper's recursive MPI algorithm (see DESIGN.md §2):

  * breadth-first level building: all ``2^d`` nodes of a depth split together;
    the master's per-node gather/argmax/notify/broadcast round-trips collapse
    into THREE collectives per level (all_gather of masked local bests, and
    one psum carrying the owner-computed partition bits);
  * the master is dissolved into those collectives — every party evaluates the
    argmax of the gathered (gain, feature-id) pairs identically, which is the
    same function the trusted server computes in the paper;
  * trees live in fixed-shape heap arrays (node i -> children 2i+1, 2i+2).

Frontier compaction (the §Perf tentpole): deep levels are mostly dead — a
node stays "live" only while samples are still routed to it, so the live
count is bounded by the sample count and, in practice, shrinks further as
branches bottom out into leaves.  At depths where the heap level is wider
than ``params.frontier_cap``, live nodes are remapped (in heap order) into a
dense segment index of static capacity ``min(2^d, N, frontier_cap)`` and the
histogram -> gains -> per-node argbest stage runs over compact slots, one
while_loop pass per ``cap`` live nodes — so histogram/gain compute scales
with the ACTUAL live-node count, not the worst-case ``2^d`` width.  The
per-node best-split results are scattered back to heap order before the
collectives, which keeps the cross-party protocol (and therefore the built
``PartyTree``) bit-identical to the dense build: compaction only re-indexes
which histogram row a live node's samples accumulate into, never which
samples they are.

Distributed model storage is preserved exactly: a party records (feature,
threshold) only for nodes it owns (``has_split``); the shared structure
(``is_leaf`` + heap layout) is what the paper calls "keeping the node
structure"; ``owner``/``split_gid`` are the master-side view.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import impurity
from repro.core.types import PARTY_AXIS, ForestParams
from repro.kernels import ops

_BIG = jnp.int32(2**30)


class PartyTree(NamedTuple):
    """One party's view of one tree (all arrays sized n_nodes = 2^(k+1)-1)."""

    is_leaf: jnp.ndarray      # (nn,)  bool   — shared structure
    leaf_stats: jnp.ndarray   # (nn,C) f32    — shared (labels are shared, §4.3)
    has_split: jnp.ndarray    # (nn,)  bool   — "this node's split is mine"
    split_floc: jnp.ndarray   # (nn,)  int32  — LOCAL feature index (mine only)
    split_bin: jnp.ndarray    # (nn,)  int32  — split bin   (mine only)
    owner: jnp.ndarray        # (nn,)  int32  — master view: owning party
    split_gid: jnp.ndarray    # (nn,)  int32  — master view: encoded feature id


def _local_argbest(gains: jnp.ndarray, feat_gid: jnp.ndarray):
    """Per-node best split with the deterministic lexicographic tie-break
    (max gain, then min global feature id, then min bin).

    The two-stage max — local per party, then global across parties — yields
    exactly the same winner as a centralized single pass because max and the
    lexicographic tie-break are associative.  This is what makes FF(M) ==
    FF(1) *bit-identical*, not just statistically close.
    """
    _, fp, bm1 = gains.shape
    g = gains.max((1, 2))
    elig = (gains == g[:, None, None]) & jnp.isfinite(gains)
    gid_m = jnp.broadcast_to(feat_gid[None, :, None].astype(jnp.int32), gains.shape)
    gid = jnp.where(elig, gid_m, _BIG).min((1, 2))
    sel = elig & (gid_m == gid[:, None, None])
    bin_m = jnp.broadcast_to(jnp.arange(bm1, dtype=jnp.int32)[None, None, :], gains.shape)
    bin_ = jnp.where(sel, bin_m, _BIG).min((1, 2))
    floc_m = jnp.broadcast_to(jnp.arange(fp, dtype=jnp.int32)[None, :, None], gains.shape)
    floc = jnp.where(sel, floc_m, _BIG).min((1, 2))
    return g, gid, bin_, floc


def reduce_level(g_all, gid_all, bin_all, cnt, params: ForestParams):
    """The paper's master reduce over one level's gathered party bests.

    ``g_all``/``gid_all``/``bin_all`` are the (M, width) stacked per-party
    best (gain, global feature id, bin) from the local split search; ``cnt``
    the (width,) shared node sample counts.  Returns
    ``(do_split, owner_lv, gid_best, bin_best)`` — the decision every party
    (and the paper's trusted master) computes identically: max gain with the
    lexicographic tie-break (min gid, then min bin via min owner), gated on
    the impurity threshold and ``min_samples_split``.

    Pure max/min/compare arithmetic — exact in any execution order — so the
    in-graph collective build (``build_tree``) and the transport-backed
    distributed build (federation/distributed.py), which calls this eagerly
    on gathered numpy arrays, make bit-identical decisions.
    """
    g_best = g_all.max(0)
    elig = (g_all == g_best[None]) & jnp.isfinite(g_all)
    gid_best = jnp.where(elig, gid_all, _BIG).min(0)
    sel = elig & (gid_all == gid_best[None])
    m = g_all.shape[0]
    owner_lv = jnp.where(sel, jnp.arange(m, dtype=jnp.int32)[:, None],
                         _BIG).min(0)
    bin_best = jnp.where(sel, bin_all, _BIG).min(0)
    thr = max(params.min_impurity_decrease, 1e-9)
    do_split = (jnp.isfinite(g_best) & (g_best > thr)
                & (cnt >= params.min_samples_split))
    return do_split, owner_lv, gid_best, bin_best


def _split_search_dense(xb, seg, wstats, fmask, feat_gid, width, params,
                        hist_impl, prev_hist):
    """Seed path: histogram every heap slot of the level at once."""
    fp_dim = xb.shape[1]
    if params.hist_subtraction and prev_hist is not None:
        # Beyond-paper: histogram only the LEFT children (half the node
        # one-hot width), derive the right siblings by subtraction from
        # the retained parent histograms. Children of leaf parents get
        # garbage rows, but do_split is gated on cnt (true sample
        # counts), so they can never be selected.
        left_seg = jnp.where((seg >= 0) & (seg % 2 == 0), seg // 2, -1)
        hist_left = ops.histogram(xb, left_seg, wstats, width // 2,
                                  params.n_bins, impl=hist_impl)
        hist = jnp.stack([hist_left, prev_hist - hist_left],
                         axis=1).reshape(width, fp_dim, params.n_bins,
                                         wstats.shape[-1])
    else:
        hist = ops.histogram(xb, seg, wstats, width, params.n_bins,
                             impl=hist_impl)
    gains = impurity.split_gains(hist, params.task, params.min_samples_leaf)
    gains = jnp.where(fmask[None, :, None], gains, -jnp.inf)
    return _local_argbest(gains, feat_gid), hist


def _split_search_frontier(xb, seg, wstats, fmask, feat_gid, width, cap,
                           params, hist_impl):
    """Compacted path: histogram ``cap`` live slots per pass, scatter back.

    Live node j (heap-level index, any routed sample) gets compact slot
    ``rank(j among live)``; pass k handles slots [k*cap, (k+1)*cap) and a
    while_loop stops as soon as every live node has been processed — dead
    width costs nothing.  Scatter targets are disjoint across passes, and
    each live node's histogram row accumulates exactly the samples the dense
    row would (in the same sample order), so the per-node (gain, gid, bin,
    floc) results written back to heap order are bit-identical to the dense
    search on every live node.  Dead nodes keep the -inf/_BIG defaults;
    ``do_split`` can never select them on either path (cnt gate + positive
    gain threshold), so the protocol downstream sees no difference.
    """
    n = xb.shape[0]
    # live-node ranking, shared by construction: `seg` is derived from the
    # shared routing state, so every party compacts identically.
    dump = jnp.where(seg >= 0, seg, width)
    occ = jnp.zeros((width + 1,), bool).at[dump].set(True)[:width]
    slot_of_node = jnp.cumsum(occ.astype(jnp.int32)) - 1       # (width,)
    n_live = occ.sum().astype(jnp.int32)
    sslot = jnp.where(seg >= 0, slot_of_node[jnp.clip(seg, 0)], -1)  # (n,)
    nil_idx = jnp.arange(width, dtype=jnp.int32)

    def cond(state):
        k = state[0]
        return k * cap < n_live

    def body(state):
        k, g_lv, gid_lv, bin_lv, floc_lv = state
        lo = k * cap
        in_pass = (sslot >= lo) & (sslot < lo + cap)
        seg_k = jnp.where(in_pass, sslot - lo, -1)
        hist = ops.histogram(xb, seg_k, wstats, cap, params.n_bins,
                             impl=hist_impl)
        gains = impurity.split_gains(hist, params.task,
                                     params.min_samples_leaf)
        gains = jnp.where(fmask[None, :, None], gains, -jnp.inf)
        g_c, gid_c, bin_c, floc_c = _local_argbest(gains, feat_gid)
        # slot -> heap-level node of THIS pass (cap is the dump row)
        node_in_pass = occ & (slot_of_node >= lo) & (slot_of_node < lo + cap)
        tgt = jnp.where(node_in_pass, slot_of_node - lo, cap)
        inv = jnp.full((cap + 1,), width, jnp.int32).at[tgt].set(
            jnp.where(node_in_pass, nil_idx, width))[:cap]
        # scatter results back to heap order (width is the dump row)
        g_lv = g_lv.at[inv].set(g_c)
        gid_lv = gid_lv.at[inv].set(gid_c)
        bin_lv = bin_lv.at[inv].set(bin_c)
        floc_lv = floc_lv.at[inv].set(floc_c)
        return k + 1, g_lv, gid_lv, bin_lv, floc_lv

    init = (jnp.int32(0),
            jnp.full((width + 1,), -jnp.inf, jnp.float32),
            jnp.full((width + 1,), _BIG, jnp.int32),
            jnp.full((width + 1,), _BIG, jnp.int32),
            jnp.full((width + 1,), _BIG, jnp.int32))
    _, g_lv, gid_lv, bin_lv, floc_lv = lax.while_loop(cond, body, init)
    return g_lv[:width], gid_lv[:width], bin_lv[:width], floc_lv[:width]


def build_tree(xb: jnp.ndarray, feat_gid: jnp.ndarray, feat_sel: jnp.ndarray,
               weight: jnp.ndarray, y_stats: jnp.ndarray,
               params: ForestParams, *,
               hist_impl: str | None = None) -> PartyTree:
    """Build one tree, SPMD over PARTY_AXIS.

    Args:
      xb:       (N, Fp) uint8 party-local binned features (padded).
      feat_gid: (Fp,) int32 global feature ids, -1 for padding.
      feat_sel: (F,) bool master's per-tree feature subsample (global ids).
      weight:   (N,) float32 bootstrap weights (0 excludes a sample).
      y_stats:  (N, C) label stat channels — shared across parties (the paper
                copies encrypted labels to every client, §3.1).
      hist_impl: histogram backend override; None uses ``params.hist_impl``.
    """
    n, _ = xb.shape
    c = y_stats.shape[-1]
    nn = params.n_nodes
    me = lax.axis_index(PARTY_AXIS)
    task = params.task
    hist_impl = params.hist_impl if hist_impl is None else hist_impl

    fmask = (feat_gid >= 0) & feat_sel[jnp.clip(feat_gid, 0)]
    wstats = y_stats.astype(jnp.float32) * weight[:, None]
    xb_i32 = xb.astype(jnp.int32)

    node = jnp.zeros((n,), jnp.int32)
    is_leaf = jnp.zeros((nn,), bool)
    leaf_stats = jnp.zeros((nn, c), jnp.float32)
    has_split = jnp.zeros((nn,), bool)
    split_floc = jnp.full((nn,), -1, jnp.int32)
    split_bin = jnp.full((nn,), -1, jnp.int32)
    owner = jnp.full((nn,), -1, jnp.int32)
    split_gid = jnp.full((nn,), -1, jnp.int32)
    prev_hist = None  # parent-level histograms (hist_subtraction)

    for d in range(params.max_depth + 1):
        off, width = params.level_slice(d)
        nil = node - off
        in_lvl = (nil >= 0) & (nil < width)
        seg = jnp.where(in_lvl, nil, -1)

        # Node label stats — computed identically by every party (shared y).
        dump = jnp.where(seg >= 0, seg, width)
        nstats = jnp.zeros((width + 1, c), jnp.float32).at[dump].add(wstats)[:width]
        cnt = impurity.count_of(nstats, task)
        leaf_stats = lax.dynamic_update_slice(leaf_stats, nstats, (off, 0))

        if d == params.max_depth:  # bottom level: everything alive is a leaf
            is_leaf = lax.dynamic_update_slice(is_leaf, cnt > 0, (off,))
            break

        # ---- local split search (the Pallas histogram hot spot) ------------
        # static per level: live nodes <= min(width, N) always, so the
        # compacted path only engages where it can actually shrink the
        # histogram (cap < width); shallow levels keep the seed's dense path.
        cap = min(width, n, params.frontier_cap or width)
        if params.frontier_cap and cap < width:
            g_loc, gid_loc, bin_loc, floc_loc = _split_search_frontier(
                xb_i32, seg, wstats, fmask, feat_gid, width, cap, params,
                hist_impl)
            prev_hist = None  # compacted levels retain no dense parent hist
        else:
            (g_loc, gid_loc, bin_loc, floc_loc), prev_hist = \
                _split_search_dense(xb_i32, seg, wstats, fmask, feat_gid,
                                    width, params, hist_impl, prev_hist)

        # ---- the paper's master: gather -> argmax -> notify, as collectives
        g_all = lax.all_gather(g_loc, PARTY_AXIS)          # (M, width)
        gid_all = lax.all_gather(gid_loc, PARTY_AXIS)
        bin_all = lax.all_gather(bin_loc, PARTY_AXIS)
        do_split, owner_lv, gid_best, bin_best = reduce_level(
            g_all, gid_all, bin_all, cnt, params)
        is_leaf = lax.dynamic_update_slice(is_leaf, (cnt > 0) & ~do_split, (off,))

        mine = do_split & (owner_lv == me)  # "receive the split message" (Alg.1)
        has_split = lax.dynamic_update_slice(has_split, mine, (off,))
        split_floc = lax.dynamic_update_slice(
            split_floc, jnp.where(mine, floc_loc, -1), (off,))
        split_bin = lax.dynamic_update_slice(
            split_bin, jnp.where(mine, bin_loc, -1), (off,))
        owner = lax.dynamic_update_slice(
            owner, jnp.where(do_split, owner_lv.astype(jnp.int32), -1), (off,))
        split_gid = lax.dynamic_update_slice(
            split_gid, jnp.where(do_split, gid_best, -1), (off,))

        # ---- owner computes the partition; one psum broadcasts it ----------
        # (paper Alg.2: "Receive split indices from client j and broadcast")
        nil_c = jnp.clip(nil, 0, width - 1)
        floc_lv = jnp.where(mine, floc_loc, 0)
        bin_lv = jnp.where(mine, bin_loc, 0)
        mine_s = in_lvl & mine[nil_c]
        vals = jnp.take_along_axis(
            xb_i32, floc_lv[nil_c][:, None], axis=1)[:, 0]
        go_r_loc = jnp.where(mine_s, (vals > bin_lv[nil_c]).astype(jnp.int32), 0)
        go_r = lax.psum(go_r_loc, PARTY_AXIS)  # exactly one party contributes
        advance = in_lvl & do_split[nil_c]
        node = jnp.where(advance, 2 * node + 1 + go_r, node)

    return PartyTree(is_leaf, leaf_stats, has_split, split_floc, split_bin,
                     owner, split_gid)


def build_forest(xb, feat_gid, feat_sels, weights, y_stats,
                 params: ForestParams, *,
                 hist_impl: str | None = None) -> PartyTree:
    """SPMD bagging loop: stack T trees (leading axis T on every leaf).

    ``lax.map`` keeps HLO size O(1) in the number of trees and bounds peak
    histogram memory to one tree's level at a time.  With
    ``params.trees_per_batch > 1`` the map runs over tree CHUNKS and a vmap
    builds each chunk's trees together — per-tree results are unchanged
    (the batch dimension is independent), the chunk just shares one traversal
    of the data.
    """
    def one(args):
        sel, w = args
        return build_tree(xb, feat_gid, sel, w, y_stats, params,
                          hist_impl=hist_impl)

    tpb = params.trees_per_batch
    t = feat_sels.shape[0]
    if tpb <= 1 or t <= 1:
        return lax.map(one, (feat_sels, weights))

    # pad T up to a multiple of the batch; padded trees carry zero weights
    # and an empty feature subsample, build to all-dead stubs, and are
    # sliced off below.
    pad = -t % tpb
    sels_p = jnp.pad(feat_sels, ((0, pad), (0, 0)))
    w_p = jnp.pad(weights, ((0, pad), (0, 0)))
    n_chunks = (t + pad) // tpb
    chunked = (sels_p.reshape(n_chunks, tpb, -1),
               w_p.reshape(n_chunks, tpb, -1))
    out = lax.map(jax.vmap(one), chunked)        # leaves (n_chunks, tpb, ...)
    return jax.tree.map(
        lambda a: a.reshape((n_chunks * tpb,) + a.shape[2:])[:t], out)


def fit_spmd(params: ForestParams, hist_impl: str | None = None):
    """Returns the party-local SPMD fit function (for vmap or shard_map)."""
    return functools.partial(build_forest, params=params, hist_impl=hist_impl)
