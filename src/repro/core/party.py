"""Vertical partitioning of a dataset across M regional parties.

Mirrors the paper's data distribution (§3.1): identical, pre-aligned sample
space; disjoint feature sets per party.  To run the protocol as SPMD code we
store the partition as *stacked, padded* arrays with a leading party axis —
the same representation feeds vmap (single-host simulation) and shard_map
(production mesh) unchanged.

Two roads lead here:
  * ``partition_from_blocks`` — the canonical party-first path: per-party
    PartyBlocks (core/partyblock.py) are aligned on hashed sample IDs and
    binned *party-locally*; quantile binning is a per-feature transform, so
    the result is bit-identical to binning the assembled central matrix
    (``validate=True`` asserts it).
  * ``make_vertical_partition`` — the raw-matrix compat adapter: a central
    (N, F) matrix is split into pre-aligned PartyBlocks and fed through the
    exact same assembly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import binning, crypto
from repro.core.partyblock import (PartyBlock, align_party_blocks,
                                   feature_groups, resolve_blocks)


@dataclasses.dataclass
class VerticalPartition:
    """Vertically partitioned, binned dataset.

    Attributes:
      xb:        (M, N, Fp) uint8 — party-local binned features, zero-padded.
      feat_gid:  (M, Fp) int32    — global (encoded) feature id, -1 for padding.
      n_parties: M.
      n_features: total real features F.
      boundaries: (F, n_bins-1) float64 — per-feature bin boundaries (kept by
                  the owning party only in a real deployment; stored centrally
                  here for test-time re-binning).
      raw_parts:  optional per-party raw (unbinned) feature blocks — what a
                  party actually holds locally.  Linear models (fedlinear.py)
                  train on these; tree models only ever see ``xb``.
      party_names: per-party identifiers in party-axis order (canonical:
                  sorted).  Serving matches per-party request blocks to
                  fit-time parties by name (``bin_party_blocks``).
    """

    xb: np.ndarray
    feat_gid: np.ndarray
    n_features: int
    boundaries: np.ndarray
    raw_parts: list[np.ndarray] | None = None
    party_names: tuple[str, ...] | None = None

    @property
    def n_parties(self) -> int:
        return int(self.xb.shape[0])

    @property
    def n_samples(self) -> int:
        return int(self.xb.shape[1])

    @property
    def n_bins(self) -> int:
        """Bin count this partition was quantized with (boundaries are the
        n_bins-1 inner edges)."""
        return int(self.boundaries.shape[1]) + 1

    def bin_test(self, x_test: np.ndarray) -> np.ndarray:
        """Bin a raw test matrix (N_t, F) and partition it like training data."""
        xb = binning.apply_bins(x_test, self.boundaries)
        return _partition_binned(xb, self.feat_gid)

    def split_raw(self, x: np.ndarray) -> list[np.ndarray]:
        """Split a raw (N, F) matrix into per-party column blocks, matching
        the feature assignment of this partition (no binning)."""
        x = np.asarray(x)
        return [x[:, self.feat_gid[i][self.feat_gid[i] >= 0]]
                for i in range(self.n_parties)]

    def dense_raw(self) -> np.ndarray:
        """The equivalent centrally pre-aligned raw (N, F) matrix — the
        parties' aligned blocks scattered back to global column positions
        (the inverse of split_raw; needs ``raw_parts``)."""
        if self.raw_parts is None:
            raise ValueError("this partition was built without raw_parts")
        out = np.empty((self.n_samples, self.n_features), dtype=np.float64)
        for i, rp in enumerate(self.raw_parts):
            out[:, self.feat_gid[i][self.feat_gid[i] >= 0]] = rp
        return out

    def party_index(self, name: str) -> int:
        if self.party_names is None:
            raise ValueError("partition carries no party names")
        if name not in self.party_names:
            raise ValueError(f"unknown party {name!r} (partition has "
                             f"{list(self.party_names)})")
        return self.party_names.index(name)

    def _match_blocks(self, blocks) -> list:
        """Resolve request blocks against this partition's parties: matched
        by name when the partition carries ``party_names`` (any input
        order), else they must arrive in party-axis order."""
        blocks = resolve_blocks(blocks)
        if self.party_names is not None:
            by_name = {b.name: b for b in blocks}
            missing = [n for n in self.party_names if n not in by_name]
            extra = [n for n in by_name if n not in self.party_names]
            if missing or extra:
                raise ValueError(
                    f"request blocks must cover exactly the fit-time "
                    f"parties {list(self.party_names)}; missing {missing}, "
                    f"unknown {extra}")
            return [by_name[n] for n in self.party_names]
        if len(blocks) != self.n_parties:
            raise ValueError(f"expected {self.n_parties} request blocks, "
                             f"got {len(blocks)}")
        return blocks

    def raw_party_rows(self, blocks, *, salt: str = crypto.DEFAULT_SALT):
        """Align per-party *request* blocks against this fit-time partition
        and return their raw rows: out-of-order and superset rows are
        re-aligned on hashed IDs (non-common rows dropped) and each block's
        columns are put in fit-time party-local order (``feature_ids``
        validated against the fit-time assignment when present).

        Returns ``(common_ids, raw_parts)`` — the canonical aligned IDs and
        one raw (n, F_i) block per party.  The shared re-alignment step of
        both serving request paths: tree engines bin these rows
        (:meth:`bin_party_blocks`), the F-LR engine standardizes them."""
        blocks = self._match_blocks(blocks)
        common, positions = align_party_blocks(blocks, salt=salt)
        parts = []
        for i, (b, pos) in enumerate(zip(blocks, positions)):
            gid = self.feat_gid[i][self.feat_gid[i] >= 0]
            x_i = b.x[pos]
            if b.feature_ids is not None:       # request columns may arrive
                order = np.argsort(b.feature_ids)  # in any global-id order
                if not np.array_equal(b.feature_ids[order], gid):
                    raise ValueError(
                        f"party {b.name!r}: request feature_ids "
                        f"{sorted(b.feature_ids)} != fit-time features "
                        f"{list(gid)}")
                x_i = x_i[:, order]
            elif b.n_features != len(gid):
                raise ValueError(
                    f"party {b.name!r}: request block has {b.n_features} "
                    f"features but the fit-time partition holds {len(gid)}")
            parts.append(np.asarray(x_i))
        return common, parts

    def bin_party_blocks(self, blocks, *, salt: str = crypto.DEFAULT_SALT):
        """Align + bin per-party *request* blocks against this fit-time
        partition: the rows from :meth:`raw_party_rows`, binned party-locally
        with each feature's fit-time boundaries and stacked into the
        (M, n, Fp) request tensor the serving programs consume.

        Returns ``(common_ids, xb_parts)``.
        """
        common, parts = self.raw_party_rows(blocks, salt=salt)
        m, fp = self.feat_gid.shape
        out = np.zeros((m, len(common), fp), dtype=np.uint8)
        for i, x_i in enumerate(parts):
            gid = self.feat_gid[i][self.feat_gid[i] >= 0]
            out[i, :, : len(gid)] = binning.apply_bins(
                x_i, self.boundaries[gid])
        return common, out


def assign_features(n_features: int, n_parties: int, *, contiguous: bool = True,
                    rng: np.random.Generator | None = None) -> list[np.ndarray]:
    """Split global feature ids across parties (disjoint cover of F).

    ``contiguous=True`` (default) slices features in order — this keeps the
    global tie-break ordering identical between M=1 and M=k runs, which is what
    makes the losslessness check *exact*.  ``contiguous=False`` permutes first
    (the realistic deployment; losslessness then holds up to gain ties).
    """
    ids = np.arange(n_features)
    if not contiguous:
        if rng is None:
            raise ValueError("contiguous=False requires an rng for the feature permutation")
        ids = rng.permutation(ids)
    return [np.sort(a) for a in np.array_split(ids, n_parties)]


def partition_from_blocks(blocks, n_bins: int, *,
                          salt: str = crypto.DEFAULT_SALT,
                          validate: bool = False):
    """Assemble per-party PartyBlocks into the stacked VerticalPartition.

    The canonical party-first ingest path:
      1. order parties canonically (sorted by name — permuting the input
         list cannot change the result);
      2. align on hashed sample IDs (crypto.align_ids): common rows in
         canonical sorted-hash order, superset rows dropped;
      3. bin each block **party-locally** over its aligned rows.  Quantile
         binning is per-feature, so this is lossless by construction —
         bit-identical to binning the assembled central matrix
         (``validate=True`` re-derives the central binning and asserts it);
      4. stack into the (M, N, Fp) padded partition every downstream
         consumer (fit / predict / serve, both substrates) already speaks.

    Global feature ids are assigned contiguously in canonical party order,
    unless every block carries ``feature_ids`` (they must then partition
    0..F-1 — the raw-matrix compat adapter preserves the original column
    encoding this way).

    Returns ``(partition, y, common_ids)``; ``y`` is the label-holding
    party's labels gathered onto the aligned ordering (None if no party
    holds labels — at most one may).
    """
    blocks = sorted(resolve_blocks(blocks), key=lambda b: b.name)
    common, positions = align_party_blocks(blocks, salt=salt)

    groups, n_features = feature_groups(
        [b.feature_ids for b in blocks], [b.n_features for b in blocks])

    feat_gid = _pad_groups(groups)
    m, fp = feat_gid.shape
    xb = np.zeros((m, len(common), fp), dtype=np.uint8)
    boundaries = np.zeros((n_features, max(n_bins - 1, 0)), dtype=np.float64)
    raw_parts = []
    for i, (b, pos, g) in enumerate(zip(blocks, positions, groups)):
        x_i = b.x[pos]
        if b.feature_ids is not None:           # party-local column order ->
            x_i = x_i[:, np.argsort(b.feature_ids)]  # ascending global id
        xb_i, b_i = binning.bin_dataset(x_i, n_bins)
        xb[i, :, : x_i.shape[1]] = xb_i
        boundaries[g] = b_i
        raw_parts.append(x_i)

    part = VerticalPartition(xb=xb, feat_gid=feat_gid,
                             n_features=n_features, boundaries=boundaries,
                             raw_parts=raw_parts,
                             party_names=tuple(b.name for b in blocks))
    if validate:
        _assert_party_local_binning_lossless(part, n_bins)

    y, holder = None, None
    for b, pos in zip(blocks, positions):
        if b.y is None:
            continue
        if holder is not None:
            raise ValueError(f"labels held by more than one party "
                             f"({holder!r} and {b.name!r}); exactly one "
                             f"party owns the labels")
        holder, y = b.name, b.y[pos]
    return part, y, common


def _assert_party_local_binning_lossless(part: VerticalPartition,
                                         n_bins: int) -> None:
    """Binning is per-feature, so party-local binning of aligned blocks must
    equal central binning of the assembled matrix — assert it (guarded
    behind ``validate=True``: it re-bins the whole dataset).  Raises, not
    ``assert``: the check must survive ``python -O``."""
    xb_central, b_central = binning.bin_dataset(part.dense_raw(), n_bins)
    if not np.array_equal(part.boundaries, b_central):
        raise AssertionError(
            "party-local boundaries diverge from central binning")
    if not np.array_equal(part.xb, _partition_binned(xb_central,
                                                     part.feat_gid)):
        raise AssertionError(
            "party-local binned values diverge from central binning")


def make_vertical_partition(x: np.ndarray, n_parties: int, n_bins: int, *,
                            contiguous: bool = True, seed: int = 0,
                            validate: bool = False) -> VerticalPartition:
    """Split a centrally held, pre-aligned raw (N, F) matrix across
    ``n_parties`` — the thin compat adapter over the party-first path:
    per-party PartyBlocks with identical implicit row IDs take the
    pre-aligned fast path (row order preserved) through
    :func:`partition_from_blocks`."""
    x = np.asarray(x)
    groups = assign_features(x.shape[1], n_parties, contiguous=contiguous,
                             rng=np.random.default_rng(seed))
    ids = np.arange(x.shape[0])
    blocks = [PartyBlock(name=f"party{i:03d}", x=x[:, g], ids=ids,
                         feature_ids=g)
              for i, g in enumerate(groups)]
    part, _, _ = partition_from_blocks(blocks, n_bins, validate=validate)
    return part


def _pad_groups(groups: list[np.ndarray]) -> np.ndarray:
    fp = max(len(g) for g in groups)
    out = np.full((len(groups), fp), -1, dtype=np.int32)
    for i, g in enumerate(groups):
        out[i, : len(g)] = g
    return out


def _partition_binned(xb: np.ndarray, feat_gid: np.ndarray) -> np.ndarray:
    """Gather party-local columns from a globally binned matrix, zero-padding."""
    m, fp = feat_gid.shape
    n = xb.shape[0]
    out = np.zeros((m, n, fp), dtype=np.uint8)
    for i in range(m):
        sel = feat_gid[i] >= 0
        out[i, :, sel] = xb[:, feat_gid[i][sel]].T
    return out
