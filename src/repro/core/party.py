"""Vertical partitioning of a dataset across M regional parties.

Mirrors the paper's data distribution (§3.1): identical, pre-aligned sample
space; disjoint feature sets per party.  To run the protocol as SPMD code we
store the partition as *stacked, padded* arrays with a leading party axis —
the same representation feeds vmap (single-host simulation) and shard_map
(production mesh) unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import binning


@dataclasses.dataclass
class VerticalPartition:
    """Vertically partitioned, binned dataset.

    Attributes:
      xb:        (M, N, Fp) uint8 — party-local binned features, zero-padded.
      feat_gid:  (M, Fp) int32    — global (encoded) feature id, -1 for padding.
      n_parties: M.
      n_features: total real features F.
      boundaries: (F, n_bins-1) float64 — per-feature bin boundaries (kept by
                  the owning party only in a real deployment; stored centrally
                  here for test-time re-binning).
      raw_parts:  optional per-party raw (unbinned) feature blocks — what a
                  party actually holds locally.  Linear models (fedlinear.py)
                  train on these; tree models only ever see ``xb``.
    """

    xb: np.ndarray
    feat_gid: np.ndarray
    n_features: int
    boundaries: np.ndarray
    raw_parts: list[np.ndarray] | None = None

    @property
    def n_parties(self) -> int:
        return int(self.xb.shape[0])

    @property
    def n_samples(self) -> int:
        return int(self.xb.shape[1])

    @property
    def n_bins(self) -> int:
        """Bin count this partition was quantized with (boundaries are the
        n_bins-1 inner edges)."""
        return int(self.boundaries.shape[1]) + 1

    def bin_test(self, x_test: np.ndarray) -> np.ndarray:
        """Bin a raw test matrix (N_t, F) and partition it like training data."""
        xb = binning.apply_bins(x_test, self.boundaries)
        return _partition_binned(xb, self.feat_gid)

    def split_raw(self, x: np.ndarray) -> list[np.ndarray]:
        """Split a raw (N, F) matrix into per-party column blocks, matching
        the feature assignment of this partition (no binning)."""
        x = np.asarray(x)
        return [x[:, self.feat_gid[i][self.feat_gid[i] >= 0]]
                for i in range(self.n_parties)]


def assign_features(n_features: int, n_parties: int, *, contiguous: bool = True,
                    rng: np.random.Generator | None = None) -> list[np.ndarray]:
    """Split global feature ids across parties (disjoint cover of F).

    ``contiguous=True`` (default) slices features in order — this keeps the
    global tie-break ordering identical between M=1 and M=k runs, which is what
    makes the losslessness check *exact*.  ``contiguous=False`` permutes first
    (the realistic deployment; losslessness then holds up to gain ties).
    """
    ids = np.arange(n_features)
    if not contiguous:
        assert rng is not None
        ids = rng.permutation(ids)
    return [np.sort(a) for a in np.array_split(ids, n_parties)]


def make_vertical_partition(x: np.ndarray, n_parties: int, n_bins: int, *,
                            contiguous: bool = True, seed: int = 0) -> VerticalPartition:
    """Bin a raw (N, F) matrix and split its columns across ``n_parties``."""
    xb, boundaries = binning.bin_dataset(x, n_bins)
    groups = assign_features(x.shape[1], n_parties, contiguous=contiguous,
                             rng=np.random.default_rng(seed))
    feat_gid = _pad_groups(groups)
    return VerticalPartition(xb=_partition_binned(xb, feat_gid),
                             feat_gid=feat_gid, n_features=x.shape[1],
                             boundaries=boundaries,
                             raw_parts=[np.asarray(x[:, g]) for g in groups])


def _pad_groups(groups: list[np.ndarray]) -> np.ndarray:
    fp = max(len(g) for g in groups)
    out = np.full((len(groups), fp), -1, dtype=np.int32)
    for i, g in enumerate(groups):
        out[i, : len(g)] = g
    return out


def _partition_binned(xb: np.ndarray, feat_gid: np.ndarray) -> np.ndarray:
    """Gather party-local columns from a globally binned matrix, zero-padding."""
    m, fp = feat_gid.shape
    n = xb.shape[0]
    out = np.zeros((m, n, fp), dtype=np.uint8)
    for i in range(m):
        sel = feat_gid[i] >= 0
        out[i, :, sel] = xb[:, feat_gid[i][sel]].T
    return out
