"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The ViT tower +
projector is a stub per the assignment carve-out: ``input_specs`` supplies
patch embeddings (B, n_patches, d_model) which replace the first n_patches
token positions.  M-RoPE splits each rotary half-dim into (t, h, w)
sections (16/24/24 of head_dim/2 = 64); text tokens advance t only, vision
patches advance h/w on a grid.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    mrope_sections=(16, 24, 24),
    n_patches=256,
    rope_theta=1e6,
)
