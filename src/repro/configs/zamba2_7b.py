"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Zamba2 interleaves a SHARED (weight-tied) attention+MLP block into the
Mamba2 stack; we use a 6-block repeating unit (5×mamba2 + 1×attn_shared),
81 = 13 units + 3 tail mamba2 blocks.  The shared block's weights live
outside the scan and are reused by every unit — the defining Zamba trick.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "attn_shared"),
    ssm_state=64,
    ssm_expand=2,
)
