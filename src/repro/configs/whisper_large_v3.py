"""whisper-large-v3 [audio] — encoder-decoder, conv frontend STUB
[arXiv:2212.04356].

32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866. The mel-spectrogram +
conv feature extractor is a stub per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings (B, 1500, 1280); the
implemented system is the 32L bidirectional encoder + 32L decoder with
causal self-attention and cross-attention.  No RoPE (learned positions).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    arch_type="audio",
    n_layers=32,            # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    rope_theta=0.0,         # 0 -> learned absolute positions
    enc_layers=32,
    enc_frames=1500,
    cross_attention=True,
)
