"""Config registry: ``get(name)`` resolves an ArchConfig by id."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

ARCH_IDS = (
    "xlstm-350m",
    "zamba2-7b",
    "qwen3-32b",
    "mistral-nemo-12b",
    "glm4-9b",
    "whisper-large-v3",
    "internlm2-1.8b",
    "phi3.5-moe-42b-a6.6b",
    "qwen2-vl-2b",
    "qwen2-moe-a2.7b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get(a) for a in ARCH_IDS}
