"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
d_ff=1408 is the routed-expert width; the shared expert is 4×1408=5632 wide
(n_shared_experts=4).  60 routed experts don't divide the 16-way model axis;
the sharding rule pads the expert dim to 64 slots (4 per shard) — see
models/sharding.py.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_expert=1408,
    rope_theta=1e6,
)
