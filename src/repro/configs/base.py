"""Architecture config system.

One frozen dataclass describes every supported architecture family: dense
(GQA/RoPE/qk-norm), MoE (routed + shared experts), SSM (Mamba2 / xLSTM),
hybrid (Mamba2 + shared attention), encoder-decoder audio (whisper) and VLM
(M-RoPE + patch-embedding stub).

Layers are grouped into a repeating ``pattern`` of block kinds so the model
can be lowered as a ``lax.scan`` over stacked pattern-units (HLO size and
compile time O(1) in depth — required to dry-run 81-layer models on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

BLOCK_KINDS = ("attn", "attn_shared", "mamba2", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                    # 0 -> d_model // n_heads
    pattern: tuple[str, ...] = ("attn",)
    qk_norm: bool = False
    rope_theta: float = 1e6
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0          # qwen2-moe: shared-expert ffn = n*d_expert
    d_expert: int = 0                  # routed expert ffn width (0 -> d_ff)
    moe_capacity: float = 1.25
    # --- SSM (mamba2 / xlstm) ---
    ssm_state: int = 0
    ssm_heads: int = 0                 # 0 -> derived from d_inner / 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- encoder-decoder (audio) ---
    enc_layers: int = 0
    enc_frames: int = 0                # stub frontend positions (whisper: 1500)
    cross_attention: bool = False
    # --- vlm ---
    mrope_sections: Optional[tuple[int, int, int]] = None
    n_patches: int = 0                 # stub vision tokens prepended
    # --- serving / variants ---
    sliding_window: Optional[int] = None   # set by the long_500k SWA variant
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # activation rematerialization for the unit scan:
    #   "none" | "unit" (checkpoint whole unit) | "dots" (save matmul outputs)
    remat: str = "unit"
    # --- §Perf hillclimb levers (baseline = False) ---
    attn_probs_bf16: bool = False   # cast softmax probs to bf16 before P@V
    attn_scores_bf16: bool = False  # materialize S×S scores in bf16 too
    moe_shard_acts: bool = False    # sharding constraints on MoE dispatch acts
    pad_experts: bool = False       # pad E to a multiple of 16 dead experts
                                    # (router never routes to them) so the
                                    # expert dim shards cleanly

    # ------------------------------------------------------------- derived
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    @property
    def ssm_head_dim(self) -> int:
        return self.d_inner // self.n_ssm_heads

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_blocks(self) -> tuple[str, ...]:
        """Blocks left over after scanning n_units full patterns."""
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def has_attention(self) -> bool:
        return (any(b.startswith("attn") for b in self.pattern)
                or self.cross_attention or self.enc_layers > 0)

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k natively (recurrent-state blocks only)."""
        return all(b in ("mamba2", "mlstm", "slstm") for b in self.pattern)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # --------------------------------------------------------- param count
    def param_count(self) -> int:
        """Approximate N for MODEL_FLOPS = 6·N·D bookkeeping."""
        d, dh = self.d_model, self.head_dim
        per: dict[str, int] = {}
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        dense_mlp = 3 * d * self.d_ff
        per["attn"] = attn + (dense_mlp if self.n_experts == 0 else self._moe_params())
        per["attn_shared"] = 0  # shared weights counted once below
        di, n = self.d_inner, self.ssm_state
        per["mamba2"] = d * (2 * di + 2 * n * self.n_ssm_heads + self.n_ssm_heads) + di * d + self.ssm_conv * di
        per["mlstm"] = d * 2 * di + 3 * di * di // max(1, self.n_ssm_heads) + di * d
        per["slstm"] = 4 * d * di + 4 * di * self.ssm_head_dim + di * d + 3 * di * d
        total = sum(per.get(b, 0) for b in self.pattern) * self.n_units
        total += sum(per.get(b, 0) for b in self.tail_blocks)
        if "attn_shared" in self.pattern:
            total += attn + dense_mlp
        total += 2 * self.vocab * d                      # embed + lm head
        if self.enc_layers:
            total += self.enc_layers * (attn + dense_mlp)
        return total

    def _moe_params(self) -> int:
        d = self.d_model
        fe = self.d_expert or self.d_ff
        routed = self.n_experts * 3 * d * fe
        shared = self.n_shared_experts * 3 * d * fe
        return routed + shared + d * self.n_experts

    def active_param_count(self) -> int:
        """N_active for MoE MODEL_FLOPS."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        fe = self.d_expert or self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * fe
        return self.param_count() - inactive * self.n_units


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: <=2 pattern units, d_model<=256, <=4 experts."""
    pat = cfg.pattern
    return cfg.with_(
        n_layers=max(len(pat), 2 if len(pat) == 1 else len(pat)),
        d_model=256, n_heads=4, n_kv_heads=2, d_head=64,
        d_ff=512 if cfg.d_ff else 0, vocab=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        # no-drop capacity so decode == full forward in consistency tests
        # (capacity dropping is a train/serve discrepancy inherent to the
        # routing algorithm, not a cache bug)
        moe_capacity=8.0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        d_expert=128 if cfg.d_expert else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=0, ssm_chunk=32,
        enc_layers=min(cfg.enc_layers, 2),
        enc_frames=min(cfg.enc_frames, 16),
        n_patches=min(cfg.n_patches, 8),
        mrope_sections=(8, 12, 12) if cfg.mrope_sections else None,
        dtype="float32",
    )
