"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
16 experts shard exactly over the 16-way model axis (1 expert per shard) —
the expert-parallel all-to-all is the closest neural analogue of the paper's
vertical owner-computes pattern (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    vocab=32064,
    n_experts=16,
    top_k=2,
    d_expert=6400,
    rope_theta=1e6,
)
