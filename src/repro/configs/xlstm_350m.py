"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
carry their own up-projection (expand=2) instead of a separate MLP.  Blocks
alternate mLSTM (matrix memory, parallelizable) and sLSTM (scalar memory,
true recurrence), per the paper's mixed-stack configuration.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    arch_type="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "slstm"),
    ssm_state=64,      # mLSTM key/value head state width
    ssm_heads=4,
    ssm_expand=2,
)
