"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts every while-loop body ONCE, regardless of
trip count (verified empirically — a lax.scan of length 4 and 16 report the
same flops).  Our production steps are scan-heavy (unit scan over layers,
grad-accumulation scan, SSD chunk scan, sLSTM time scan), so XLA's numbers
undercount by orders of magnitude.  This module parses the *optimized* HLO
text and computes:

  * flops:            2·prod(result)·prod(contracting dims) per dot/conv,
  * hbm bytes:        Σ (operand + result bytes) of top-level (post-fusion)
                      instructions — a first-order HBM-traffic proxy that
                      ignores on-chip reuse within a fusion (exactly what we
                      want) but not across fusions,
  * collective bytes: result-shape bytes of all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute,

each multiplied by the product of enclosing while-loop trip counts.  Trip
counts come from XLA's own ``known_trip_count`` backend_config annotation
(present for lax.scan-derived loops); unknown loops count once and are
reported so the caller can see the blind spot.

The whole analysis is text-based on ``compiled.as_text()`` — no XLA APIs
beyond what jax exposes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
    "iota", "while", "conditional", "call",
}


def _shape_list(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_shapes: list
    operand_names: list[str]
    raw: str
    called: list[str]
    trip_count: Optional[int] = None


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    param_shapes: dict[str, list]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"^((?:\([^)]*\)|[^(])*?)\s*([\w\-]+)\(")
_CALLS = re.compile(r"(?:calls=|body=|condition=|to_apply=)%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[\\\":{]+n[\\\":]+(\d+)')
_OPERAND = re.compile(r"%([\w.\-]+)")


def _split_toplevel(s: str) -> list[str]:
    """Split on commas that are not nested inside (), {} or []."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        hdr = _COMP_HDR.match(stripped) if stripped.endswith("{") else None
        if hdr is not None:
            params: dict[str, list] = {}
            for part in _split_toplevel(hdr.group(2)):
                part = part.strip()
                if ":" in part:
                    pname, ptype = part.split(":", 1)
                    params[pname.strip().lstrip("%")] = _shape_list(ptype)
            cur = Computation(hdr.group(1), [], params)
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR.match(stripped)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE.match(rhs)
        if om is None:
            continue
        shapes_part, opcode = om.group(1), om.group(2)
        # operands: inside the first (...) after the opcode
        paren = rhs[om.end() - 1:]
        depth = 0
        arglist = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                arglist += ch
        ops = _OPERAND.findall(arglist)
        called = _CALLS.findall(rhs)
        trip = None
        tm = _TRIP.search(rhs)
        if tm:
            trip = int(tm.group(1))
        cur.instructions.append(Instruction(
            name=name, opcode=opcode, result_shapes=_shape_list(shapes_part),
            operand_names=ops, raw=rhs, called=called, trip_count=trip))
    return comps


def _dot_flops(instr: Instruction, shapes_by_name) -> float:
    """2 · prod(result) · prod(contracting dims of lhs)."""
    res = instr.result_shapes
    if not res:
        return 0.0
    n_out = 1
    for d in res[0][1]:
        n_out *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    lhs_shape = None
    if instr.operand_names:
        lhs_shape = shapes_by_name.get(instr.operand_names[0])
    if m and lhs_shape:
        contract = 1
        for d in m.group(1).split(","):
            if d:
                idx = int(d)
                if idx < len(lhs_shape[0][1]):
                    contract *= lhs_shape[0][1][idx]
        return 2.0 * n_out * contract
    return 2.0 * n_out  # unknown contraction: lower bound


def _conv_flops(instr: Instruction, shapes_by_name) -> float:
    res = instr.result_shapes
    if not res or len(instr.operand_names) < 2:
        return 0.0
    n_out = 1
    for d in res[0][1]:
        n_out *= d
    rhs = shapes_by_name.get(instr.operand_names[1])
    k = 1
    if rhs:
        for d in rhs[0][1]:
            k *= d
    # per output element: one MAC per kernel element per input channel (folded
    # into prod(kernel shape) / out_channels); crude but convs are rare here.
    out_ch = res[0][1][-1] if res[0][1] else 1
    return 2.0 * n_out * max(k // max(out_ch, 1), 1)


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0
    bytes_by_op: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CostTotals", scale: float = 1.0, *,
            with_bytes: bool = True):
        self.flops += other.flops * scale
        if with_bytes:
            # fused computations' internal ops never touch HBM; only the
            # fusion instruction's own operands/results count (callers pass
            # with_bytes=False for fusion/apply children).
            self.bytes += other.bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        for k, v in other.coll.items():
            slot = self.coll.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += v["count"] * scale
            slot["bytes"] += v["bytes"] * scale
        if with_bytes:
            for k, v in other.bytes_by_op.items():
                self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * scale
        self.unknown_trip_loops += other.unknown_trip_loops


def _comp_cost(comp: Computation, comps, memo) -> CostTotals:
    if comp.name in memo:
        return memo[comp.name]
    total = CostTotals()
    memo[comp.name] = total  # guard cycles
    shapes_by_name: dict[str, list] = dict(comp.param_shapes)
    for ins in comp.instructions:
        shapes_by_name[ins.name] = ins.result_shapes
    for ins in comp.instructions:
        op = ins.opcode
        if op == "while":
            trip = ins.trip_count
            if trip is None:
                trip = 1
                total.unknown_trip_loops += 1
            for cname in ins.called:
                child = comps.get(cname)
                if child is None:
                    continue
                total.add(_comp_cost(child, comps, memo), trip)
            continue
        if op in ("fusion", "call", "conditional", "map", "reduce",
                  "reduce-window", "scatter", "select-and-scatter", "sort",
                  "custom-call"):
            for cname in ins.called:
                child = comps.get(cname)
                if child is not None:
                    total.add(_comp_cost(child, comps, memo), 1.0,
                              with_bytes=(op in ("call", "conditional")))
        if op == "dot":
            total.flops += _dot_flops(ins, shapes_by_name)
        elif op == "convolution":
            total.flops += _conv_flops(ins, shapes_by_name)
        if op in _COLLECTIVES or op.rstrip("-start") in _COLLECTIVES:
            base = op[:-6] if op.endswith("-start") else op
            if not op.endswith("-done") and base in _COLLECTIVES:
                b = _bytes_of(ins.result_shapes)
                slot = total.coll.setdefault(base, {"count": 0.0, "bytes": 0.0})
                slot["count"] += 1
                slot["bytes"] += b
                total.coll_bytes += b
        if op not in _SKIP_BYTES_OPS:
            key = op
            if op == "fusion":
                fm = re.search(r'op_name="[^"]*?/([\w\-\.]+)"', ins.raw)
                key = f"fusion:{fm.group(1)}" if fm else "fusion"
            res_b = _bytes_of(ins.result_shapes)
            op_sizes = [_bytes_of(shapes_by_name[o])
                        for o in ins.operand_names if o in shapes_by_name]
            if "dynamic_update_slice" in key or op == "dynamic-update-slice":
                # in-place: XLA aliases the big buffer; traffic = the update
                # region (read update + write region), not the whole buffer
                big = max(op_sizes, default=0)
                b = 2 * (sum(op_sizes) - big) if op_sizes else res_b
            elif ("dynamic_slice" in key or op == "dynamic-slice"
                  or "fusion:slice" == key or op == "slice"):
                # a slice reads only the slice, not its full operand
                b = 2 * res_b
            else:
                b = res_b + sum(op_sizes)
            total.bytes += b
            total.bytes_by_op[key] = total.bytes_by_op.get(key, 0.0) + b
    memo[comp.name] = total
    return total


def analyze_hlo(text: str, entry: Optional[str] = None) -> CostTotals:
    comps = parse_hlo(text)
    if not comps:
        return CostTotals()
    if entry is None:
        em = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry = em.group(1) if em else next(iter(comps))
    # computations reachable only via ENTRY are counted through the call graph
    return _comp_cost(comps[entry], comps, {})
