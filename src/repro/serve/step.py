"""Serving steps: prefill and single-token decode against a ring-buffer cache.

``serve_step`` is what decode_32k / long_500k lower: ONE new token with a KV
(or SSM-state) cache of the context length.
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import ArchConfig
from repro.models import transformer

Params = Any


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        return transformer.prefill(params, batch["tokens"], cfg, extras)
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, token, pos):
        return transformer.decode_step(params, cache, token, pos, cfg)
    return serve_step


def make_cache(cfg: ArchConfig, batch: int, seq_len: int):
    return transformer.make_cache(cfg, batch, seq_len)
