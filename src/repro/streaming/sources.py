"""Chunked party data sources — PartyBlock-shaped chunks, never the whole silo.

The in-memory plane loads one :class:`~repro.core.partyblock.PartyBlock` per
party (``DataSource.load``).  The streaming plane replaces that with a
:class:`ChunkedSource`: ``iter_chunks(rows)`` yields PartyBlock-shaped chunks
(same name / feature layout, a bounded slice of rows each), so a scan touches
``O(chunk)`` raw feature values at a time no matter how big the extract is.

:class:`ChunkedCSVSource` streams a per-party CSV through the exact parse
helpers ``PartyBlock.from_csv`` uses (core/partyblock.py: one owner of the
header layout, float parse with the loud NaN/missing contract, label dtype
rule), which is what makes a chunked read bit-identical to the whole-file
load.  :class:`ArraySource` adapts an in-memory block (tests, oracles).
:class:`ChunkedParquetSource` streams a parquet extract through the same
column-layout rules (optional ``pyarrow`` dependency, imported lazily).

:class:`DataProduct` is the data-mesh wrapper (SNIPPETS.md): a party's
published extract as a versioned product with a declared schema — feature
ids/count/dtype, the ID contract, label ownership — validated **loudly**
against every chunk at ingest, plus a monotonic version the session enforces
across ``ingest_append`` calls.
"""
from __future__ import annotations

import csv
import dataclasses
import os
from typing import Iterator, Protocol, runtime_checkable

import numpy as np

from repro.core.partyblock import (CSVSource, DataSource, PartyBlock,
                                   csv_layout, parse_feature_rows,
                                   parse_labels)

DEFAULT_CHUNK_ROWS = 4096


@runtime_checkable
class ChunkedSource(Protocol):
    """Anything that can stream one party's extract as PartyBlock chunks.

    Every yielded chunk must agree on ``name``, feature layout
    (``n_features`` / ``feature_ids`` / ``feature_names``) and label
    presence; rows arrive in a stable order (two passes over the same
    source see the same rows in the same order — the scan pass and the
    bin pass both rely on it)."""

    def iter_chunks(self, rows: int) -> Iterator[PartyBlock]: ...


@dataclasses.dataclass
class ArraySource:
    """ChunkedSource over an in-memory PartyBlock — row-sliced views, no
    copies.  The adapter that lets blocks and true streams mix in one
    ingest, and the oracle-side twin in the bit-identity tests."""

    block: PartyBlock

    def iter_chunks(self, rows: int) -> Iterator[PartyBlock]:
        if rows < 1:
            raise ValueError(f"chunk rows must be >= 1, got {rows}")
        b = self.block
        if b.n_samples == 0:
            yield PartyBlock(name=b.name, x=b.x, ids=b.ids, y=b.y,
                             feature_ids=b.feature_ids,
                             feature_names=b.feature_names)
            return
        for lo in range(0, b.n_samples, rows):
            yield PartyBlock(
                name=b.name, x=b.x[lo:lo + rows], ids=b.ids[lo:lo + rows],
                y=None if b.y is None else b.y[lo:lo + rows],
                feature_ids=b.feature_ids, feature_names=b.feature_names)


@dataclasses.dataclass
class ChunkedCSVSource:
    """Stream a per-party CSV extract in bounded-row chunks.

    Same file format and parse rules as ``PartyBlock.from_csv`` (shared
    helpers), but the file is read incrementally: at no point is more than
    one chunk of raw feature values materialized.  The label dtype rule is
    applied per chunk; concatenation's dtype promotion makes the assembled
    column equal to the whole-file parse (int chunks promote to float64
    exactly when any chunk parses float-formatted labels).
    """

    path: str
    name: str | None = None
    id_column: str = "id"
    label_column: str = "label"
    delimiter: str = ","

    def iter_chunks(self, rows: int) -> Iterator[PartyBlock]:
        if rows < 1:
            raise ValueError(f"chunk rows must be >= 1, got {rows}")
        name = self.name \
            or os.path.splitext(os.path.basename(self.path))[0]
        with open(self.path, newline="") as fh:
            reader = csv.reader(fh, delimiter=self.delimiter)
            header = next(reader, None)
            if header is None:
                raise ValueError(f"{self.path}: empty CSV")
            id_idx, label_idx, feat_idx, names, feature_ids = csv_layout(
                header, self.path, id_column=self.id_column,
                label_column=self.label_column)
            offset, yielded = 0, False
            while True:
                body = []
                for r in reader:
                    body.append(r)
                    if len(body) >= rows:
                        break
                if not body and yielded:
                    return
                ids = np.array([r[id_idx] for r in body]) if body \
                    else np.empty(0, dtype="U1")
                x = parse_feature_rows(body, feat_idx, header, self.path,
                                       row_offset=offset)
                y = parse_labels([r[label_idx] for r in body]) \
                    if label_idx is not None else None
                yield PartyBlock(name=name, x=x, ids=ids, y=y,
                                 feature_ids=feature_ids,
                                 feature_names=names)
                offset += len(body)
                yielded = True
                if len(body) < rows:
                    return


def _require_pyarrow():
    """Lazy optional import: parquet reading needs pyarrow, everything
    else in the streaming plane must keep working without it."""
    try:
        import pyarrow.parquet as pq
    except ImportError as e:
        raise ImportError(
            "ChunkedParquetSource needs the optional 'pyarrow' package "
            "(pip install pyarrow); CSV and in-memory sources work "
            "without it") from e
    return pq


@dataclasses.dataclass
class ChunkedParquetSource:
    """Stream a per-party parquet extract in bounded-row chunks.

    Column semantics mirror :class:`ChunkedCSVSource` — the schema's column
    names go through the same ``csv_layout`` header rules (``id_column``
    names the sample-ID column, ``label_column`` the optional labels,
    every other column is a feature; ``gf<N>`` names carry explicit global
    feature ids).  Feature columns are read as float64, IDs keep their
    native kind (integer columns stay integers, anything else becomes
    strings — the same contract ``ProductSchema.id_kind`` speaks).

    Requires the optional ``pyarrow`` dependency; the import is deferred to
    ``iter_chunks`` so merely constructing (or pickling) the source works
    without it.
    """

    path: str
    name: str | None = None
    id_column: str = "id"
    label_column: str = "label"

    def iter_chunks(self, rows: int) -> Iterator[PartyBlock]:
        if rows < 1:
            raise ValueError(f"chunk rows must be >= 1, got {rows}")
        pq = _require_pyarrow()
        name = self.name \
            or os.path.splitext(os.path.basename(self.path))[0]
        pf = pq.ParquetFile(self.path)
        header = list(pf.schema_arrow.names)
        id_idx, label_idx, feat_idx, names, feature_ids = csv_layout(
            header, self.path, id_column=self.id_column,
            label_column=self.label_column)
        yielded = False
        for batch in pf.iter_batches(batch_size=rows):
            yield self._chunk_of(batch, name, header, id_idx, label_idx,
                                 feat_idx, names, feature_ids)
            yielded = True
        if not yielded:
            # zero-row file: one empty chunk, like the CSV source, so the
            # scan pass still learns the party's shape
            empty = pf.schema_arrow.empty_table()
            yield self._chunk_of(empty, name, header, id_idx, label_idx,
                                 feat_idx, names, feature_ids)

    @staticmethod
    def _chunk_of(batch, name, header, id_idx, label_idx, feat_idx, names,
                  feature_ids) -> PartyBlock:
        cols = [np.asarray(batch.column(j)) for j in range(batch.num_columns)]
        n = cols[0].shape[0] if cols else 0
        x = (np.column_stack([cols[j].astype(np.float64)
                              for j in feat_idx]) if n
             else np.empty((0, len(feat_idx)), dtype=np.float64))
        ids = cols[id_idx]
        if ids.dtype.kind not in "iu":
            ids = ids.astype(str)
        y = None
        if label_idx is not None:
            y = cols[label_idx]
            if y.dtype.kind not in "iuf":
                y = parse_labels([str(v) for v in y])
        return PartyBlock(name=name, x=x, ids=ids, y=y,
                          feature_ids=feature_ids, feature_names=names)


@dataclasses.dataclass(frozen=True)
class ProductSchema:
    """A data product's declared contract, validated against every chunk.

    Attributes:
      n_features: feature count every chunk must carry.
      feature_ids: the global column ids (None: contiguous assignment at
        ingest) — chunks must declare exactly these.
      feature_dtype: numpy dtype name the raw feature chunks must arrive
        as (``PartyBlock`` preserves float dtypes, promotes the rest to
        float64).
      id_kind: the ID contract — "str" or "int" sample keys.
      has_labels: whether this party publishes the labels.
    """

    n_features: int
    feature_ids: tuple[int, ...] | None = None
    feature_dtype: str = "float64"
    id_kind: str = "str"
    has_labels: bool = False

    def __post_init__(self):
        if self.id_kind not in ("str", "int"):
            raise ValueError(f"id_kind must be 'str' or 'int', got "
                             f"{self.id_kind!r}")
        np.dtype(self.feature_dtype)   # loud on an undeclarable dtype

    @classmethod
    def of(cls, block: PartyBlock) -> "ProductSchema":
        """Infer the schema a block already satisfies (test convenience)."""
        return cls(
            n_features=block.n_features,
            feature_ids=(tuple(int(f) for f in block.feature_ids)
                         if block.feature_ids is not None else None),
            feature_dtype=block.x.dtype.name,
            id_kind="int" if block.ids.dtype.kind in "iu" else "str",
            has_labels=block.y is not None)


@dataclasses.dataclass
class DataProduct:
    """A versioned party extract: source + declared schema + monotonic
    version (the data-mesh unit of exchange).

    Itself a :class:`ChunkedSource` — iteration re-yields the inner
    source's chunks after validating each against the schema, so a
    contract break surfaces at the first offending chunk with the product
    name, version, and the mismatch spelled out.  The session enforces
    version monotonicity across ``ingest_append`` calls.
    """

    name: str
    source: ChunkedSource
    schema: ProductSchema
    version: int = 1

    def __post_init__(self):
        if int(self.version) < 0:
            raise ValueError(f"product {self.name!r}: version must be >= 0, "
                             f"got {self.version}")

    def iter_chunks(self, rows: int) -> Iterator[PartyBlock]:
        for chunk in as_chunked(self.source).iter_chunks(rows):
            self._validate(chunk)
            yield chunk

    def _validate(self, chunk: PartyBlock) -> None:
        s, tag = self.schema, f"product {self.name!r} v{self.version}"
        if chunk.name != self.name:
            raise ValueError(f"{tag}: source yields chunks named "
                             f"{chunk.name!r} — a product's chunks must "
                             f"carry the product name")
        if chunk.n_features != s.n_features:
            raise ValueError(f"{tag}: declared {s.n_features} features but "
                             f"a chunk carries {chunk.n_features}")
        declared = None if s.feature_ids is None \
            else np.asarray(s.feature_ids, dtype=np.int64)
        got = chunk.feature_ids
        if (declared is None) != (got is None) \
                or (declared is not None
                    and not np.array_equal(declared, got)):
            raise ValueError(
                f"{tag}: declared feature_ids "
                f"{None if declared is None else declared.tolist()} but a "
                f"chunk carries "
                f"{None if got is None else got.tolist()}")
        if chunk.x.dtype != np.dtype(s.feature_dtype):
            raise ValueError(f"{tag}: declared feature dtype "
                             f"{s.feature_dtype!r} but a chunk arrived as "
                             f"{chunk.x.dtype.name!r}")
        kind = "int" if chunk.ids.dtype.kind in "iu" else "str"
        if chunk.ids.size and kind != s.id_kind:
            raise ValueError(f"{tag}: ID contract is {s.id_kind!r} keys but "
                             f"a chunk's ids are {chunk.ids.dtype} "
                             f"({kind!r})")
        if (chunk.y is not None) != s.has_labels:
            raise ValueError(
                f"{tag}: schema says has_labels={s.has_labels} but a chunk "
                f"{'carries' if chunk.y is not None else 'is missing'} "
                f"labels")


def as_chunked(source) -> ChunkedSource:
    """Normalize any party input into a ChunkedSource: chunked sources pass
    through, a whole-file CSVSource re-opens as its chunked twin, blocks
    and block-loading DataSources wrap in :class:`ArraySource`."""
    if hasattr(source, "iter_chunks"):
        return source
    if isinstance(source, CSVSource):
        return ChunkedCSVSource(
            path=source.path, name=source.name,
            id_column=source.id_column, label_column=source.label_column,
            delimiter=source.delimiter)
    if isinstance(source, PartyBlock):
        return ArraySource(source)
    if isinstance(source, DataSource):
        return ArraySource(source.load())
    raise TypeError(f"cannot stream a {type(source).__name__}: expected a "
                    f"ChunkedSource, PartyBlock, CSVSource or DataSource")


def is_chunked_sequence(data) -> bool:
    """True when ``data`` is a non-empty sequence containing at least one
    true chunked source (everything else adaptable) — the dispatch test
    behind Federation.ingest's streaming path."""
    if not isinstance(data, (list, tuple)) or not data:
        return False
    ok = (PartyBlock, DataSource)
    if not all(hasattr(b, "iter_chunks") or isinstance(b, ok) for b in data):
        return False
    return any(hasattr(b, "iter_chunks") for b in data)
