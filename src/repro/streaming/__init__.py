"""Streaming, out-of-core data plane: chunked sources, mergeable quantile
sketches, and append-extensible party streams.

Entry points:
  * sources — :class:`ChunkedSource` protocol, :class:`ChunkedCSVSource`,
    :class:`ArraySource`, :class:`DataProduct` / :class:`ProductSchema`.
  * sketch — :class:`QuantileSketch` / :class:`FeatureSketches` (exact until
    compaction, tracked rank-error bound after).
  * ingest — scan / align / assemble engine; :class:`PartyStream` is the
    session- and worker-held append state.

``Federation.ingest`` dispatches here automatically when handed chunked
sources; ``Federation.ingest_append`` lands new product versions.
"""
from repro.streaming.ingest import (PartyStream, SourceScan, append_streams,
                                    assemble_streams, open_streams,
                                    party_stream_bin, scan_source,
                                    streaming_ingest)
from repro.streaming.sketch import (DEFAULT_CAPACITY, FeatureSketches,
                                    QuantileSketch)
from repro.streaming.sources import (DEFAULT_CHUNK_ROWS, ArraySource,
                                     ChunkedCSVSource, ChunkedParquetSource,
                                     ChunkedSource, DataProduct,
                                     ProductSchema, as_chunked,
                                     is_chunked_sequence)

__all__ = [
    "ArraySource", "ChunkedCSVSource", "ChunkedParquetSource",
    "ChunkedSource", "DataProduct",
    "DEFAULT_CAPACITY", "DEFAULT_CHUNK_ROWS", "FeatureSketches",
    "PartyStream", "ProductSchema", "QuantileSketch", "SourceScan",
    "append_streams", "as_chunked", "assemble_streams", "is_chunked_sequence",
    "open_streams", "party_stream_bin", "scan_source", "streaming_ingest",
]
