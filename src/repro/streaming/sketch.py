"""Mergeable quantile sketches — out-of-core party-local binning.

The in-memory data plane derives each party's bin edges with one
``np.quantile`` over the party's full raw column (core/binning.py).  A silo
extract that doesn't fit in RAM can't do that, so the streaming plane feeds
every chunk through a :class:`QuantileSketch` — an MRL/KLL-style compactor —
and cuts the bin grid from the sketch instead.

Two regimes, one object:

* **Exact** — while the sketch has never compacted (total items within
  ``capacity``), it *is* the data: ``edges(n_bins)`` calls ``np.quantile``
  on the buffered values at exactly the grid levels
  (:func:`repro.core.binning.interior_quantiles`), so the resulting edges
  are **bit-identical** to the dense in-memory build.  This is the regime
  the losslessness oracle (streamed build == in-memory build) runs in.

* **Compacted** — past capacity, levels compact: the level-``l`` buffer
  (every element weighing ``2**l``) is sorted and every other element of its
  even-length prefix is promoted to level ``l+1`` with doubled weight.  For
  any threshold ``t``, if ``c`` of the ``m`` even-prefix elements are
  ``<= t``, the promoted set holds ``floor((c + 1 - offset) / 2)`` of them
  (``offset`` alternates 0/1 per compaction), so the weighted
  rank of ``t`` moves by ``|w*c - 2w*floor((c+1-offset)/2)| <= w = 2**l``;
  the odd remainder is untouched.  Each compaction therefore adds at most
  ``2**l`` to the absolute rank error, and the sketch *tracks that sum
  exactly* in :attr:`err`: every rank answered is within ``err`` of truth.
  With capacity ``k``, level ``l`` compacts about ``n / (k * 2**l)`` times
  over ``n`` items, giving the classic ``err/n ~= log2(n/k) / k`` relative
  bound — the property test asserts the tracked ``err`` directly.

Merging concatenates level-wise and re-compacts; bounds add
(``merged.err <= a.err + b.err + compaction cost``, all tracked).  Merge is
order-invariant in the exact regime (the buffer is a multiset) and
bound-respecting in the compacted one.
"""
from __future__ import annotations

import numpy as np

from repro.core import binning
from repro.observability import registry as telemetry

DEFAULT_CAPACITY = 2048


class QuantileSketch:
    """Deterministic mergeable rank sketch over one feature column.

    Args:
      capacity: per-level buffer size that triggers compaction.  Memory is
        ``O(capacity * log(n / capacity))`` floats regardless of stream
        length.  Streams with at most ``capacity`` total values never
        compact and stay exact (``err == 0``).
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 8:
            raise ValueError(f"capacity must be >= 8, got {capacity}")
        self.capacity = int(capacity)
        # levels[l]: unordered float64 buffer whose elements each weigh 2**l
        self.levels: list[np.ndarray] = [np.empty(0, dtype=np.float64)]
        self.n = 0          # total values observed (exact count, always)
        self.err = 0        # proven additive rank-error bound (0 == exact)
        self._parity = 0    # alternating compaction offset (deterministic)

    # --------------------------------------------------------------- build
    def update(self, values) -> "QuantileSketch":
        """Absorb a chunk of values; returns self for chaining."""
        v = np.asarray(values, dtype=np.float64).reshape(-1)
        if not np.isfinite(v).all():
            raise ValueError("QuantileSketch.update: non-finite values "
                             "(NaN/inf) have no rank — clean them upstream")
        if v.size == 0:
            return self
        self.levels[0] = np.concatenate([self.levels[0], v])
        self.n += int(v.size)
        self._compress()
        return self

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Combine two sketches into a new one; inputs are untouched.
        Error bounds add (then grow by any re-compaction, still tracked)."""
        out = QuantileSketch(capacity=min(self.capacity, other.capacity))
        depth = max(len(self.levels), len(other.levels))
        out.levels = []
        for l in range(depth):
            mine = self.levels[l] if l < len(self.levels) \
                else np.empty(0, dtype=np.float64)
            theirs = other.levels[l] if l < len(other.levels) \
                else np.empty(0, dtype=np.float64)
            out.levels.append(np.concatenate([mine, theirs]))
        out.n = self.n + other.n
        out.err = self.err + other.err
        out._parity = (self._parity + other._parity) % 2
        out._compress()
        return out

    def _compress(self) -> None:
        """Compact every over-capacity level upward (weights double)."""
        l = 0
        while l < len(self.levels):
            buf = self.levels[l]
            if buf.size <= self.capacity:
                l += 1
                continue
            buf = np.sort(buf, kind="stable")
            offset, self._parity = self._parity, self._parity ^ 1
            m = buf.size - (buf.size % 2)        # even prefix compacts;
            promoted = buf[:m][offset::2]        # odd remainder stays put
            self.levels[l] = buf[m:]
            if l + 1 == len(self.levels):
                self.levels.append(np.empty(0, dtype=np.float64))
            self.levels[l + 1] = np.concatenate(
                [self.levels[l + 1], promoted])
            self.err += 2 ** l
            telemetry.REGISTRY.counter("streaming.sketch_compactions").inc()
            l += 1

    # --------------------------------------------------------------- query
    @property
    def exact(self) -> bool:
        """True while no compaction ever happened — quantiles are exact and
        bit-identical to np.quantile over the streamed values."""
        return self.err == 0

    def quantiles(self, qs) -> np.ndarray:
        """Quantile estimates at levels ``qs`` (np.quantile's linear method).

        Exact regime: literally ``np.quantile`` on the buffer.  Compacted:
        weighted interpolation over the level-stacked multiset — every
        answer's rank is within :attr:`err` of the true rank.
        """
        if self.n == 0:
            raise ValueError("empty sketch has no quantiles")
        qs = np.asarray(qs, dtype=np.float64).reshape(-1)
        if self.exact:
            return np.quantile(self.levels[0], qs)
        vals = np.concatenate(self.levels)
        wts = np.concatenate([np.full(lv.size, 2 ** l, dtype=np.int64)
                              for l, lv in enumerate(self.levels)])
        order = np.argsort(vals, kind="stable")
        vals, wts = vals[order], wts[order]
        cw = np.cumsum(wts)                      # cw[-1] == self.n
        pos = (cw[-1] - 1) * qs                  # np.quantile: (n-1) * q
        lo = np.minimum(np.searchsorted(cw, np.floor(pos) + 1, side="left"),
                        vals.size - 1)
        hi = np.minimum(np.searchsorted(cw, np.ceil(pos) + 1, side="left"),
                        vals.size - 1)
        frac = pos - np.floor(pos)
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    def edges(self, n_bins: int) -> np.ndarray:
        """The ``n_bins - 1`` interior bin edges, cut at exactly the grid
        levels the dense build uses (binning.interior_quantiles)."""
        return np.asarray(
            self.quantiles(binning.interior_quantiles(n_bins)),
            dtype=np.float64)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"QuantileSketch(n={self.n}, err={self.err}, "
                f"levels={[lv.size for lv in self.levels]})")


class FeatureSketches:
    """One :class:`QuantileSketch` per feature column of a party block —
    the unit a streaming scan builds and the bin-edge derivation consumes.
    """

    def __init__(self, n_features: int, capacity: int = DEFAULT_CAPACITY):
        self.sketches = [QuantileSketch(capacity)
                         for _ in range(int(n_features))]

    @property
    def n_features(self) -> int:
        return len(self.sketches)

    @property
    def n(self) -> int:
        return self.sketches[0].n if self.sketches else 0

    @property
    def err(self) -> int:
        """The worst per-feature tracked rank-error bound."""
        return max((s.err for s in self.sketches), default=0)

    @property
    def exact(self) -> bool:
        return self.err == 0

    def update(self, x: np.ndarray) -> "FeatureSketches":
        x = np.asarray(x, dtype=np.float64)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(f"expected (n, {self.n_features}) chunk, got "
                             f"shape {x.shape}")
        for f, s in enumerate(self.sketches):
            s.update(x[:, f])
        return self

    def merge(self, other: "FeatureSketches") -> "FeatureSketches":
        if self.n_features != other.n_features:
            raise ValueError(
                f"cannot merge sketches over {self.n_features} vs "
                f"{other.n_features} features")
        out = FeatureSketches.__new__(FeatureSketches)
        out.sketches = [a.merge(b)
                        for a, b in zip(self.sketches, other.sketches)]
        return out

    def edges(self, n_bins: int) -> np.ndarray:
        """Per-feature boundary grid, shape (F, n_bins - 1) — the streamed
        stand-in for binning.quantile_boundaries (bit-identical while
        :attr:`exact`)."""
        return np.stack([s.edges(n_bins) for s in self.sketches]) \
            if self.sketches \
            else np.empty((0, max(n_bins - 1, 0)), dtype=np.float64)
