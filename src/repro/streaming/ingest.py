"""Streaming ingest engine: out-of-core alignment + binning + assembly.

The in-memory path (core/party.py: ``partition_from_blocks``) materializes
every party's raw block, aligns on hashed IDs, and bins each aligned block in
one ``np.quantile`` pass.  This engine produces the **same**
``VerticalPartition`` without ever holding a party's raw features densely:

  pass 1 (scan)   every chunk is hashed (IDs) and fed into per-feature
                  :class:`~repro.streaming.sketch.FeatureSketches`; only IDs,
                  hashes, labels, and the sketches are retained — all
                  O(rows) metadata or O(capacity) sketch state, never the
                  (rows x features) raw block.
  align           the retained hashed IDs go through the exact in-memory
                  alignment contract: per-party duplicate rejection, the
                  pre-aligned raw-ID fast path (caller row order preserved
                  bit-for-bit), else ``crypto.align_ids`` onto the canonical
                  sorted-hash common ordering, loud on empty intersections.
  pass 2 (bin)    per party: bin edges come from the sketch (exact — hence
                  bit-identical to ``np.quantile`` — while it never
                  compacted; within the tracked rank-error bound after);
                  if alignment dropped rows, a re-sketch pass over the kept
                  rows runs first, because the in-memory build bins aligned
                  rows only.  Each chunk is then binned independently
                  (``binning.apply_bins`` is row-separable) and scattered
                  into the stacked (M, N, Fp) partition at its aligned
                  positions.

Bit-identity holds end to end while every party's sketch stays exact: the
streamed, chunked, out-of-order build equals the in-memory build on the same
rows (tests/test_streaming.py asserts it, partition and fitted forest both).

:class:`PartyStream` is one party's append-extensible source list — the unit
the session keeps between ``ingest`` and ``ingest_append`` and the state a
distributed party worker holds process-side (only hashes, binned values and
labels ever cross the wire; sketches and raw chunks stay with the party).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis import runtime as egress_runtime
from repro.core import binning, crypto
from repro.observability import registry as telemetry
from repro.observability import trace as tracing
from repro.core.party import VerticalPartition, _pad_groups
from repro.core.partyblock import feature_groups
from repro.streaming.sketch import DEFAULT_CAPACITY, FeatureSketches
from repro.streaming.sources import DEFAULT_CHUNK_ROWS, as_chunked


@dataclasses.dataclass
class SourceScan:
    """What the scan pass retains of one source: everything downstream
    passes need *except* the raw feature values."""

    name: str
    n_rows: int
    ids: np.ndarray                  # raw sample IDs, stream order
    hashes: np.ndarray               # salted hashes of the same
    sketches: FeatureSketches        # full-stream per-feature sketches
    y: np.ndarray | None
    feature_ids: np.ndarray | None
    feature_names: tuple[str, ...] | None
    version: int | None = None       # DataProduct version, if any

    def __post_init__(self) -> None:
        # tag the retained raw arrays for the runtime egress guard (no-op
        # unless REPRO_EGRESS_GUARD=1); `hashes` is wire-safe by policy
        egress_runtime.taint(
            self.ids, f"SourceScan[{self.name!r}].ids (raw sample IDs)")
        if self.y is not None:
            egress_runtime.taint(
                self.y, f"SourceScan[{self.name!r}].y (raw labels)")


def scan_source(source, *, chunk_rows: int = DEFAULT_CHUNK_ROWS,
                capacity: int = DEFAULT_CAPACITY,
                salt: str = crypto.DEFAULT_SALT) -> SourceScan:
    """Pass 1 over one source: hash IDs, sketch features, retain labels.
    Validates that every chunk agrees on the party's shape (name, feature
    layout, label presence) and raises loudly naming the chunk if not."""
    src = as_chunked(source)
    name = n_feat = fids = fnames = has_y = sk = None
    ids_parts, hash_parts, y_parts = [], [], []
    for k, chunk in enumerate(src.iter_chunks(chunk_rows)):
        if name is None:
            name, n_feat = chunk.name, chunk.n_features
            fids, fnames = chunk.feature_ids, chunk.feature_names
            has_y = chunk.y is not None
            sk = FeatureSketches(n_feat, capacity)
        else:
            if chunk.name != name:
                raise ValueError(f"source for party {name!r}: chunk {k} is "
                                 f"named {chunk.name!r} — one source, one "
                                 f"party")
            if chunk.n_features != n_feat:
                raise ValueError(f"party {name!r}: chunk {k} carries "
                                 f"{chunk.n_features} features, previous "
                                 f"chunks carried {n_feat}")
            if (fids is None) != (chunk.feature_ids is None) or (
                    fids is not None
                    and not np.array_equal(fids, chunk.feature_ids)):
                raise ValueError(f"party {name!r}: chunk {k} changes "
                                 f"feature_ids mid-stream")
            if (chunk.y is not None) != has_y:
                raise ValueError(f"party {name!r}: chunk {k} "
                                 f"{'grew' if chunk.y is not None else 'lost'}"
                                 f" labels mid-stream — label presence must "
                                 f"be uniform across chunks")
        sk.update(chunk.x)
        ids_parts.append(chunk.ids)
        hash_parts.append(crypto.hash_ids(chunk.ids, salt=salt))
        if has_y:
            y_parts.append(chunk.y)
        telemetry.REGISTRY.counter("streaming.chunks_scanned").inc()
        telemetry.REGISTRY.counter("streaming.rows_scanned").inc(
            int(chunk.n_samples))
    if name is None:
        raise ValueError(f"{source!r}: source yielded no chunks")
    tracing.TRACER.event("stream.scan", category="host", party=name,
                         rows=sum(int(a.size) for a in ids_parts))
    return SourceScan(
        name=name, n_rows=sum(int(a.size) for a in ids_parts),
        ids=_concat(ids_parts), hashes=_concat(hash_parts),
        sketches=sk, y=_concat(y_parts) if has_y else None,
        feature_ids=fids, feature_names=fnames,
        version=getattr(source, "version", None))


def _concat(parts: list[np.ndarray]) -> np.ndarray:
    """Concatenate, ignoring empty arrays so their placeholder dtypes can't
    poison the promotion (an empty '<U1' chunk must not stringify int IDs);
    all-empty falls back to the first part."""
    filled = [a for a in parts if a.size]
    return np.concatenate(filled) if filled \
        else np.asarray(parts[0]).reshape(-1)


class PartyStream:
    """One party's append-extensible chunked data feed + its scan state.

    ``extend`` lands a new source (an ``ingest_append``): the source is
    scanned once, validated against the party's established shape and the
    product-version contract (versions must strictly increase), and its scan
    cached — re-assembly after an append re-reads raw chunks (bin edges move
    when rows land, so old rows re-bin) but never re-hashes or re-sketches
    what was already scanned.
    """

    def __init__(self, *, chunk_rows: int = DEFAULT_CHUNK_ROWS,
                 capacity: int = DEFAULT_CAPACITY,
                 salt: str = crypto.DEFAULT_SALT):
        self.chunk_rows = int(chunk_rows)
        self.capacity = int(capacity)
        self.salt = salt
        self.sources: list = []
        self.scans: list[SourceScan] = []
        self._merged: SourceScan | None = None

    @property
    def name(self) -> str:
        if not self.scans:
            raise ValueError("empty PartyStream has no name yet")
        return self.scans[0].name

    @property
    def version(self) -> int | None:
        """The latest product version landed (None: unversioned sources)."""
        for s in reversed(self.scans):
            if s.version is not None:
                return int(s.version)
        return None

    def extend(self, source) -> SourceScan:
        scan = scan_source(source, chunk_rows=self.chunk_rows,
                           capacity=self.capacity, salt=self.salt)
        _extend_with_scan(self, source, scan)
        return scan

    def merged_scan(self) -> SourceScan:
        """The party's scans fused into one (cached until the next extend).
        Sketch merges and array concatenation only — no chunk re-reads."""
        if self._merged is not None:
            return self._merged
        if not self.scans:
            raise ValueError("empty PartyStream: extend() a source first")
        if len(self.scans) == 1:
            self._merged = self.scans[0]
            return self._merged
        head = self.scans[0]
        sk = head.sketches
        for s in self.scans[1:]:
            sk = sk.merge(s.sketches)
        self._merged = SourceScan(
            name=head.name,
            n_rows=sum(s.n_rows for s in self.scans),
            ids=_concat([s.ids for s in self.scans]),
            hashes=_concat([s.hashes for s in self.scans]),
            sketches=sk,
            y=_concat([s.y for s in self.scans])
            if head.y is not None else None,
            feature_ids=head.feature_ids,
            feature_names=head.feature_names,
            version=self.version)
        return self._merged

    def iter_chunks(self):
        """Raw chunks across all landed sources, scan order (pass 2)."""
        for src in self.sources:
            yield from src.iter_chunks(self.chunk_rows)


def party_stream_bin(stream: PartyStream, positions, n_bins: int):
    """Pass 2 for one party: derive bin edges from the sketch and bin every
    chunk into the aligned row order.  Returns ``(xb_i, boundaries_i, y_i)``
    with ``xb_i`` (n_common, F_i) uint8 in ascending-global-id column order,
    ``boundaries_i`` (F_i, n_bins - 1), and the aligned labels (or None).

    This is the party-side half of streamed ingest — the distributed worker
    runs exactly this function process-side, so only its return values ever
    cross the wire.

    When alignment kept every row (``positions`` is a permutation), the
    scan-pass sketch is already the sketch of the aligned rows (same
    multiset), so no second read of the raw data happens.  Otherwise the
    kept rows are re-sketched first: the in-memory build derives edges from
    aligned rows only, and bit-identity is the contract.
    """
    s = stream.merged_scan()
    pos = np.asarray(positions, dtype=np.int64)
    col_order = np.argsort(s.feature_ids) if s.feature_ids is not None \
        else None
    sk = s.sketches
    if pos.size != s.n_rows:
        keep = np.zeros(s.n_rows, dtype=bool)
        keep[pos] = True
        sk = FeatureSketches(s.sketches.n_features, stream.capacity)
        off = 0
        for chunk in stream.iter_chunks():
            sk.update(chunk.x[keep[off:off + chunk.n_samples]])
            off += chunk.n_samples
    edges = sk.edges(n_bins)                       # original column order
    if col_order is not None:
        edges = edges[col_order]                   # ascending global id
    out_pos = np.full(s.n_rows, -1, dtype=np.int64)
    out_pos[pos] = np.arange(pos.size, dtype=np.int64)
    xb_i = np.zeros((pos.size, s.sketches.n_features), dtype=np.uint8)
    off = 0
    for chunk in stream.iter_chunks():
        sel = out_pos[off:off + chunk.n_samples]
        kept = sel >= 0
        if kept.any():
            x_c = chunk.x[kept]
            if col_order is not None:
                x_c = x_c[:, col_order]
            xb_i[sel[kept]] = binning.apply_bins(x_c, edges)
        off += chunk.n_samples
    y_i = s.y[pos] if s.y is not None else None
    telemetry.REGISTRY.counter("streaming.rows_binned").inc(int(pos.size))
    tracing.TRACER.event("stream.bin", category="host", party=s.name,
                         rows=int(pos.size))
    return xb_i, edges, y_i


def align_streams(streams: list[PartyStream]):
    """The alignment step over scanned streams — decision for decision the
    in-memory ``align_party_blocks`` contract (duplicate rejection naming
    the party, raw-ID fast path preserving caller row order, canonical
    sorted-hash ordering otherwise, loud empty-intersection errors).

    Returns ``(common_ids, positions)`` like align_party_blocks."""
    scans = [st.merged_scan() for st in streams]
    names = [s.name for s in scans]
    for s in scans:
        if np.unique(s.ids).size != s.ids.size:
            raise ValueError(
                f"party {s.name!r} has duplicate sample IDs: alignment "
                f"would be ambiguous — deduplicate before ingest")
    first = scans[0].ids
    if all(s.ids.shape == first.shape and np.array_equal(s.ids, first)
           for s in scans[1:]):
        if first.size == 0:
            raise ValueError(
                f"empty hashed-ID intersection across parties "
                f"{names}: no shared samples to align")
        pos = np.arange(len(first), dtype=np.int64)
        return first.copy(), [pos.copy() for _ in scans]
    positions, _ = crypto.align_hashed(
        [s.hashes for s in scans], names,
        check_unique=False, identity_fast_path=False)
    return scans[0].ids[positions[0]], positions


def assemble_streams(streams: list[PartyStream], n_bins: int):
    """Align scanned party streams and assemble the stacked partition
    (pass 2 per party).  Returns ``(partition, y, common_ids)`` exactly like
    ``partition_from_blocks`` — except ``raw_parts`` is None, because no
    dense raw block ever existed."""
    streams = sorted(streams, key=lambda st: st.name)   # canonical order
    names = [st.name for st in streams]
    if len(set(names)) != len(names):
        raise ValueError(f"party names must be unique, got {names}")
    common_ids, positions = align_streams(streams)
    scans = [st.merged_scan() for st in streams]
    groups, n_features = feature_groups(
        [s.feature_ids for s in scans],
        [s.sketches.n_features for s in scans])
    feat_gid = _pad_groups(groups)
    m, fp = feat_gid.shape
    xb = np.zeros((m, len(common_ids), fp), dtype=np.uint8)
    boundaries = np.zeros((n_features, max(n_bins - 1, 0)), dtype=np.float64)
    y, holder = None, None
    for i, (st, pos, g) in enumerate(zip(streams, positions, groups)):
        xb_i, edges_i, y_i = party_stream_bin(st, pos, n_bins)
        xb[i, :, : xb_i.shape[1]] = xb_i
        boundaries[g] = edges_i
        if y_i is not None:
            if holder is not None:
                raise ValueError(
                    f"labels held by more than one party ({holder!r} and "
                    f"{names[i]!r}); exactly one party owns the labels")
            holder, y = names[i], y_i
    part = VerticalPartition(xb=xb, feat_gid=feat_gid,
                             n_features=n_features, boundaries=boundaries,
                             raw_parts=None, party_names=tuple(names))
    return part, y, common_ids


def open_streams(sources, *, chunk_rows: int = DEFAULT_CHUNK_ROWS,
                 capacity: int = DEFAULT_CAPACITY,
                 salt: str = crypto.DEFAULT_SALT) -> list[PartyStream]:
    """Scan one source per party into fresh PartyStreams (pass 1)."""
    streams = []
    for src in sources:
        st = PartyStream(chunk_rows=chunk_rows, capacity=capacity, salt=salt)
        st.extend(src)
        streams.append(st)
    names = [st.name for st in streams]
    if len(set(names)) != len(names):
        raise ValueError(f"party names must be unique, got {names}")
    return streams


def append_streams(streams: list[PartyStream], sources) -> None:
    """Land appended sources onto existing streams, matched by the party
    name each source's chunks carry.  Any subset of parties may publish new
    rows; rows only join the training set once every party has them (the
    intersection semantics of alignment do the bookkeeping)."""
    by_name = {st.name: st for st in streams}
    for src in sources:
        scan = scan_source(src, chunk_rows=streams[0].chunk_rows,
                           capacity=streams[0].capacity,
                           salt=streams[0].salt)
        st = by_name.get(scan.name)
        if st is None:
            raise ValueError(
                f"ingest_append: source names party {scan.name!r} but the "
                f"session ingested parties {sorted(by_name)} — appends "
                f"extend existing parties, they cannot add new ones")
        # hand the already-computed scan to the stream: re-scanning would
        # double the pass-1 IO, so extend() is bypassed in favor of its
        # validations on the cached scan
        _extend_with_scan(st, src, scan)


def _extend_with_scan(st: PartyStream, source, scan: SourceScan) -> None:
    """PartyStream.extend's validations + landing, for a pre-computed scan."""
    if not st.scans:
        st.sources.append(as_chunked(source))
        st.scans.append(scan)
        st._merged = None
        return
    head = st.scans[0]
    if scan.name != head.name:
        raise ValueError(f"cannot append source named {scan.name!r} "
                         f"to party {head.name!r}")
    if scan.sketches.n_features != head.sketches.n_features:
        raise ValueError(
            f"party {head.name!r}: appended source carries "
            f"{scan.sketches.n_features} features, the stream carries "
            f"{head.sketches.n_features}")
    if (head.feature_ids is None) != (scan.feature_ids is None) or (
            head.feature_ids is not None and not np.array_equal(
                head.feature_ids, scan.feature_ids)):
        raise ValueError(f"party {head.name!r}: appended source changes "
                         f"feature_ids")
    if (scan.y is not None) != (head.y is not None):
        raise ValueError(
            f"party {head.name!r}: the label holder must append labelled "
            f"rows and label-free parties label-free rows")
    prev = st.version
    if prev is not None and (scan.version is None
                             or int(scan.version) <= prev):
        raise ValueError(
            f"party {head.name!r}: appended product version {scan.version!r} "
            f"does not advance v{prev} — product versions are monotonic "
            f"(re-publishing an old extract would silently double-ingest "
            f"its rows)")
    st.sources.append(as_chunked(source))
    st.scans.append(scan)
    st._merged = None


def streaming_ingest(sources, n_bins: int, *,
                     chunk_rows: int = DEFAULT_CHUNK_ROWS,
                     capacity: int = DEFAULT_CAPACITY,
                     salt: str = crypto.DEFAULT_SALT,
                     validate: bool = False):
    """One-call streamed ingest: scan, align, assemble.

    Returns ``(partition, y, common_ids, streams)``; keep ``streams`` to
    land appends later (``append_streams`` + ``assemble_streams``).
    """
    if validate:
        raise ValueError(
            "validate=True re-bins the assembled central matrix, which a "
            "streamed build never holds — validate an in-memory ingest of "
            "the same rows instead (the bit-identity tests do exactly that)")
    streams = open_streams(sources, chunk_rows=chunk_rows,
                           capacity=capacity, salt=salt)
    part, y, common = assemble_streams(streams, n_bins)
    return part, y, common, streams
