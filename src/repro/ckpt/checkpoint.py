"""Checkpointing: msgpack+zstd pytree snapshots with atomic step directories.

No orbax on the box; this covers the same contract at the scale we run:
  * pytree structure captured as a path->array flat dict;
  * atomic rename so a killed run never leaves a half checkpoint (the paper's
    "modeling can be easily recovered from the break point" requirement, §4.1
    — tree-build state is a pytree like any other here);
  * works for model params, optimizer state, and fitted PartyTree forests.

``zstandard`` is optional: hosts without it fall back to stdlib ``zlib``
(the codec is recorded in the file extension, so either build restores the
other's zlib checkpoints; a .zst checkpoint does require zstandard).
"""
from __future__ import annotations

import os
import pathlib
import zlib
from typing import Any

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:                       # pragma: no cover - env dependent
    zstandard = None

_ZSTD_NAME = "arrays.msgpack.zst"
_ZLIB_NAME = "arrays.msgpack.zlib"
_META_NAME = "meta.msgpack"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any,
                    meta: dict | None = None) -> str:
    """Snapshot a pytree; ``meta`` (small JSON-like dict, e.g. the model
    family tag Federation.save writes) rides inside the same atomic step
    directory as ``meta.msgpack`` — old checkpoints without it read back as
    an empty dict (read_meta)."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_step_{step:08d}"
    final = d / f"step_{step:08d}"
    flat = _flatten(tree)
    payload = {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                   "data": v.tobytes()} for k, v in flat.items()}
    raw = msgpack.packb(payload, use_bin_type=True)
    if tmp.exists():
        # a crashed save may have left a payload in the other codec; a stale
        # file surviving the rename would shadow the fresh one on restore
        import shutil
        shutil.rmtree(tmp)
    tmp.mkdir()
    if zstandard is not None:
        (tmp / _ZSTD_NAME).write_bytes(
            zstandard.ZstdCompressor(level=3).compress(raw))
    else:
        (tmp / _ZLIB_NAME).write_bytes(zlib.compress(raw, 3))
    if meta:
        (tmp / _META_NAME).write_bytes(
            msgpack.packb(dict(meta), use_bin_type=True))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    return str(final)


def read_meta(directory: str | os.PathLike, step: int) -> dict:
    """The ``meta`` dict a checkpoint was saved with ({} for legacy
    checkpoints that predate metadata)."""
    p = pathlib.Path(directory) / f"step_{step:08d}" / _META_NAME
    if not p.exists():
        return {}
    return msgpack.unpackb(p.read_bytes(), raw=False)


def peek_checkpoint(directory: str | os.PathLike,
                    step: int) -> dict[str, np.ndarray]:
    """Read a checkpoint's flat path->array dict without a ``like`` pytree.

    The payload records dtype/shape per leaf, so readers that know the
    container layout (e.g. the serving engine reconstructing a PartyTree by
    field order) can restore without first materializing matching
    ShapeDtypeStructs."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    if (d / _ZLIB_NAME).exists():
        raw = zlib.decompress((d / _ZLIB_NAME).read_bytes())
    else:
        if zstandard is None:
            raise ModuleNotFoundError(
                f"{d / _ZSTD_NAME} is zstd-compressed but 'zstandard' is "
                "not installed; pip install zstandard to restore it")
        raw = zstandard.ZstdDecompressor().decompress(
            (d / _ZSTD_NAME).read_bytes())
    payload = msgpack.unpackb(raw, raw=False)
    return {k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
            for k, v in payload.items()}


def restore_checkpoint(directory: str | os.PathLike, step: int,
                       like: Any) -> Any:
    flat = peek_checkpoint(directory, step)
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_like:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def latest_step(directory: str | os.PathLike) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None
