from repro.ckpt.checkpoint import (save_checkpoint, restore_checkpoint,  # noqa: F401
                                   peek_checkpoint, latest_step, read_meta)
