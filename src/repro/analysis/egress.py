"""AST taint analysis: prove raw party data cannot reach a wire sink.

The pass walks every module (it PARSES files, it never imports them), seeds
taint at reads of SECRET attributes (``.x`` / ``.ids`` / ``.y`` — the raw
fields of `PartyBlock`, streaming `SourceScan`s and chunk blocks), and
propagates it through assignments, containers, f-strings, arithmetic and
calls.  A finding fires when a secret-labelled value arrives at a wire
sink (`send`/`sendall`/`pack`/`request`/`_send`/`exchange`) without having
passed a registered sanitizer from `policy.SANITIZERS`.

Interprocedural reach comes from lightweight function summaries: every
function is abstractly executed with opaque markers bound to its
parameters, recording

  * ``param_to_sink`` — parameter positions that flow to a wire sink
    inside the function (or transitively through callees resolved in the
    same module), so ``helper(ch, block.ids)`` is flagged at the *call
    site* when ``helper`` forwards its argument to ``ch.send``;
  * ``param_to_return`` / ``returns_secret`` — whether the return value
    carries argument taint or freshly-read secrets.

Summaries are iterated to a fixpoint (bounded), then a final pass emits
findings.  Known, accepted imprecision: object *field* states don't
persist across methods (``self.f = secret`` in one method is not seen by
another), and cross-module calls are matched by bare name only — sinks and
sanitizers are name-based by policy, which keeps the pass sound for the
wire verbs that exist in this repo.

Flow handling is path-insensitive but order-sensitive: branches of an
``if``/``try`` are analyzed from the same entry state and merged (taint
union), loop bodies run twice to stabilize loop-carried taint, and a
reassignment strongly updates a variable — so ``ids = hash_ids(ids)``
really does clean ``ids``.
"""
from __future__ import annotations

import ast

from .base import Finding, ModuleSource
from .policy import DEFAULT_POLICY, Policy

_PARAM = "@p"
_SECRET_DESC = {"x": "raw feature matrix", "ids": "raw sample IDs",
                "y": "raw labels"}


def _is_param(label: str) -> bool:
    return label.startswith(_PARAM)


def _fmt_labels(labels) -> str:
    return ", ".join(sorted(l for l in labels if not _is_param(l)))


class _FnSummary:
    __slots__ = ("param_to_sink", "param_to_return", "returns_secret")

    def __init__(self):
        self.param_to_sink: dict[int, str] = {}
        self.param_to_return: set[int] = set()
        self.returns_secret: set[str] = set()

    def state(self):
        return (len(self.param_to_sink), len(self.param_to_return),
                len(self.returns_secret))


class _FnInfo:
    __slots__ = ("qualname", "node", "params", "is_method")

    def __init__(self, qualname, node, is_method):
        self.qualname = qualname
        self.node = node
        a = node.args
        self.params = [p.arg for p in (a.posonlyargs + a.args)]
        self.is_method = is_method and self.params[:1] in (["self"], ["cls"])


def _collect_functions(tree) -> list[_FnInfo]:
    fns = []

    def walk(node, prefix, in_class):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fns.append(_FnInfo(prefix + child.name, child, in_class))
                walk(child, prefix + child.name + ".", False)
            elif isinstance(child, ast.ClassDef):
                walk(child, prefix + child.name + ".", True)
            else:
                walk(child, prefix, in_class)

    walk(tree, "", False)
    return fns


class _ModuleCtx:
    def __init__(self, mod: ModuleSource):
        self.mod = mod
        self.functions = _collect_functions(mod.tree)
        self.by_name: dict[str, list[_FnInfo]] = {}
        for fn in self.functions:
            self.by_name.setdefault(fn.node.name, []).append(fn)


class _Eval:
    """Abstract interpreter for one function (or the module body)."""

    def __init__(self, ctx: _ModuleCtx, policy: Policy,
                 summaries: dict, qualname: str, emit: bool,
                 findings: list[Finding] | None):
        self.ctx = ctx
        self.policy = policy
        self.summaries = summaries
        self.qualname = qualname
        self.emit = emit
        self.findings = findings
        self.summary = summaries[(ctx.mod.rel, qualname)]
        self._reported: set[tuple[int, str]] = set()

    # -- helpers -------------------------------------------------------------

    def _finding(self, node, message):
        key = (node.lineno, message)
        if self.emit and key not in self._reported:
            self._reported.add(key)
            self.findings.append(Finding(
                rule="egress", path=self.ctx.mod.rel, line=node.lineno,
                symbol=self.qualname or "<module>", message=message))

    def _sink_hit(self, node, sink_name, labels):
        secrets = {l for l in labels if not _is_param(l)}
        if secrets:
            self._finding(node, f"SECRET value ({_fmt_labels(secrets)}) "
                                f"reaches wire sink `{sink_name}` without a "
                                f"registered sanitizer")
        for l in labels:
            if _is_param(l):
                self.summary.param_to_sink.setdefault(int(l[len(_PARAM):]),
                                                      sink_name)

    def _resolve_local(self, name: str) -> list[_FnInfo]:
        return self.ctx.by_name.get(name, [])

    # -- expressions ---------------------------------------------------------

    def ev(self, node, env) -> frozenset:
        if node is None or isinstance(node, ast.Constant):
            return frozenset()
        if isinstance(node, ast.Name):
            return env.get(node.id, frozenset())
        if isinstance(node, ast.Attribute):
            base = self.ev(node.value, env)
            if node.attr in self.policy.safe_attrs:
                return frozenset()
            if node.attr in self.policy.secret_attrs:
                try:
                    expr = ast.unparse(node)[:60]
                except Exception:
                    expr = f"<expr>.{node.attr}"
                desc = _SECRET_DESC.get(node.attr, "raw data")
                return base | {f"{desc} `{expr}`"}
            return base
        if isinstance(node, ast.Subscript):
            return self.ev(node.value, env)
        if isinstance(node, ast.Call):
            return self.ev_call(node, env)
        if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
            out = frozenset()
            for elt in node.elts:
                out |= self.ev(elt, env)
            return out
        if isinstance(node, ast.Dict):
            out = frozenset()
            for v in node.values:
                out |= self.ev(v, env)
            return out
        if isinstance(node, ast.BinOp):
            return self.ev(node.left, env) | self.ev(node.right, env)
        if isinstance(node, ast.BoolOp):
            out = frozenset()
            for v in node.values:
                out |= self.ev(v, env)
            return out
        if isinstance(node, ast.Compare):
            # comparisons yield booleans (protocol metadata, e.g.
            # `block.y is not None`) — evaluate operands for sink
            # side-effects, but the boolean itself is clean
            self.ev(node.left, env)
            for comp in node.comparators:
                self.ev(comp, env)
            return frozenset()
        if isinstance(node, ast.Lambda):
            return frozenset()      # opaque, unanalyzed
        if isinstance(node, ast.UnaryOp):
            if isinstance(node.op, ast.Not):
                return frozenset()
            return self.ev(node.operand, env)
        if isinstance(node, ast.IfExp):
            return self.ev(node.body, env) | self.ev(node.orelse, env)
        if isinstance(node, ast.JoinedStr):
            out = frozenset()
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    out |= self.ev(v.value, env)
            return out
        if isinstance(node, ast.Starred):
            return self.ev(node.value, env)
        if isinstance(node, (ast.Await, ast.Yield, ast.YieldFrom)):
            return self.ev(getattr(node, "value", None), env)
        if isinstance(node, ast.NamedExpr):
            labels = self.ev(node.value, env)
            if isinstance(node.target, ast.Name):
                env[node.target.id] = labels
            return labels
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            inner = dict(env)
            for gen in node.generators:
                src = self.ev(gen.iter, inner)
                self._bind_target(gen.target, src, inner)
                for cond in gen.ifs:
                    self.ev(cond, inner)
            if isinstance(node, ast.DictComp):
                return self.ev(node.key, inner) | self.ev(node.value, inner)
            return self.ev(node.elt, inner)
        if isinstance(node, ast.Slice):
            out = frozenset()
            for part in (node.lower, node.upper, node.step):
                out |= self.ev(part, env)
            return out
        # fall-through: union of child expression taint
        out = frozenset()
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                out |= self.ev(child, env)
        return out

    def ev_call(self, node: ast.Call, env) -> frozenset:
        # positional + keyword argument labels, in call order
        arg_labels = [self.ev(a, env) for a in node.args]
        kw_labels = [(kw.arg, self.ev(kw.value, env))
                     for kw in node.keywords]
        all_labels = arg_labels + [l for _, l in kw_labels]

        if isinstance(node.func, ast.Attribute):
            callee = node.func.attr
            base = self.ev(node.func.value, env)
            is_method_call = True
        elif isinstance(node.func, ast.Name):
            callee = node.func.id
            base = frozenset()
            is_method_call = False
        else:
            callee = None
            base = self.ev(node.func, env)
            is_method_call = False

        # 1. registered sanitizers break taint outright
        if callee in self.policy.sanitizers:
            return frozenset()
        # 2. wire sinks: every argument is inspected
        if callee in self.policy.sinks:
            for labels in all_labels:
                self._sink_hit(node, callee, labels)
            return frozenset()
        # 3. same-module functions: apply their summaries
        local = self._resolve_local(callee) if callee else []
        if local:
            result = frozenset()
            for fn in local:
                offset = 1 if (fn.is_method and is_method_call) else 0
                summary = self.summaries[(self.ctx.mod.rel, fn.qualname)]
                # map call arguments onto parameter positions
                bound: dict[int, frozenset] = {}
                for i, labels in enumerate(arg_labels):
                    bound[i + offset] = labels
                for kw, labels in kw_labels:
                    if kw in fn.params:
                        bound[fn.params.index(kw)] = labels
                for idx, sink in summary.param_to_sink.items():
                    for l in bound.get(idx, frozenset()):
                        if _is_param(l):
                            self.summary.param_to_sink.setdefault(
                                int(l[len(_PARAM):]), sink)
                        else:
                            self._finding(
                                node,
                                f"SECRET value ({_fmt_labels({l})}) reaches "
                                f"wire sink `{sink}` via `{fn.node.name}`")
                result |= frozenset(summary.returns_secret)
                for idx in summary.param_to_return:
                    result |= bound.get(idx, frozenset())
            return result
        # 4. neutral builtins: sizes/types/scalars, never payload
        if callee in self.policy.neutral_calls:
            return frozenset()
        # 5. unknown callable: conservatively propagate argument + receiver
        out = base
        for labels in all_labels:
            out |= labels
        return out

    # -- statements ----------------------------------------------------------

    def _bind_target(self, target, labels, env):
        if isinstance(target, ast.Name):
            env[target.id] = labels
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind_target(elt, labels, env)
        elif isinstance(target, ast.Starred):
            self._bind_target(target.value, labels, env)
        elif isinstance(target, (ast.Attribute, ast.Subscript)):
            # storing into a container/field taints the base object
            base = target
            while isinstance(base, (ast.Attribute, ast.Subscript)):
                base = base.value
            if isinstance(base, ast.Name):
                env[base.id] = env.get(base.id, frozenset()) | labels

    def exec_block(self, stmts, env):
        for stmt in stmts:
            self.exec_stmt(stmt, env)

    def _merge(self, env, *branches):
        keys = set(env)
        for b in branches:
            keys |= set(b)
        for k in keys:
            merged = frozenset()
            for b in branches:
                merged |= b.get(k, frozenset())
            env[k] = merged

    def exec_stmt(self, stmt, env):
        if isinstance(stmt, ast.Assign):
            labels = self.ev(stmt.value, env)
            for t in stmt.targets:
                self._bind_target(t, labels, env)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._bind_target(stmt.target, self.ev(stmt.value, env), env)
        elif isinstance(stmt, ast.AugAssign):
            labels = self.ev(stmt.value, env) | self.ev(stmt.target, env)
            self._bind_target(stmt.target, labels, env)
        elif isinstance(stmt, ast.Expr):
            self.ev(stmt.value, env)
        elif isinstance(stmt, ast.Return):
            labels = self.ev(stmt.value, env)
            for l in labels:
                if _is_param(l):
                    self.summary.param_to_return.add(int(l[len(_PARAM):]))
                else:
                    self.summary.returns_secret.add(l)
        elif isinstance(stmt, ast.If):
            self.ev(stmt.test, env)
            b1, b2 = dict(env), dict(env)
            self.exec_block(stmt.body, b1)
            self.exec_block(stmt.orelse, b2)
            self._merge(env, b1, b2)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._bind_target(stmt.target, self.ev(stmt.iter, env), env)
            for _ in range(2):      # stabilize loop-carried taint
                body = dict(env)
                self.exec_block(stmt.body, body)
                self._merge(env, body)
            self.exec_block(stmt.orelse, env)
        elif isinstance(stmt, ast.While):
            self.ev(stmt.test, env)
            for _ in range(2):
                body = dict(env)
                self.exec_block(stmt.body, body)
                self._merge(env, body)
            self.exec_block(stmt.orelse, env)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                labels = self.ev(item.context_expr, env)
                if item.optional_vars is not None:
                    self._bind_target(item.optional_vars, labels, env)
            self.exec_block(stmt.body, env)
        elif isinstance(stmt, ast.Try):
            self.exec_block(stmt.body, env)
            branches = []
            for handler in stmt.handlers:
                h = dict(env)
                if handler.name:
                    h[handler.name] = frozenset()
                self.exec_block(handler.body, h)
                branches.append(h)
            if branches:
                self._merge(env, *branches)
            self.exec_block(stmt.orelse, env)
            self.exec_block(stmt.finalbody, env)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            pass    # analyzed separately with their own summaries
        elif isinstance(stmt, (ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.ev(child, env)
        elif isinstance(stmt, ast.Delete):
            pass
        else:       # Import/Global/Pass/Break/Continue/...
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.ev(child, env)

    # -- entry points --------------------------------------------------------

    def run_function(self, info: _FnInfo):
        env = {p: frozenset({f"{_PARAM}{i}"})
               for i, p in enumerate(info.params)}
        self.exec_block(info.node.body, env)

    def run_module_body(self):
        env = {}
        body = [s for s in self.ctx.mod.tree.body
                if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef, ast.Import,
                                      ast.ImportFrom))]
        self.exec_block(body, env)


def run_egress(modules: list[ModuleSource],
               policy: Policy = DEFAULT_POLICY) -> list[Finding]:
    """Run the taint pass over parsed modules; returns raw findings
    (suppressions are applied by the caller via base.apply_suppressions)."""
    ctxs = [_ModuleCtx(m) for m in modules]
    summaries: dict[tuple, _FnSummary] = {}
    for ctx in ctxs:
        summaries[(ctx.mod.rel, "")] = _FnSummary()
        for fn in ctx.functions:
            summaries[(ctx.mod.rel, fn.qualname)] = _FnSummary()

    def sweep(emit, findings):
        for ctx in ctxs:
            for fn in ctx.functions:
                _Eval(ctx, policy, summaries, fn.qualname, emit,
                      findings).run_function(fn)
            _Eval(ctx, policy, summaries, "", emit,
                  findings).run_module_body()

    # fixpoint over interprocedural summaries (helper chains stabilize in
    # depth iterations; 4 covers everything in this repo with margin)
    prev = None
    for _ in range(4):
        sweep(emit=False, findings=None)
        state = tuple(s.state() for _, s in sorted(summaries.items()))
        if state == prev:
            break
        prev = state
    findings: list[Finding] = []
    sweep(emit=True, findings=findings)
    return findings
