"""Rule: protocol and sketch code must be deterministic and seedable.

Three checks:

  * legacy global-state numpy RNG (``np.random.rand`` & co.) and stdlib
    ``random.*`` calls are banned everywhere in ``src/repro`` — all
    randomness flows through seeded ``np.random.default_rng(seed)``
    generators (bit-identity across substrates depends on it);
  * ``np.random.default_rng()`` called with NO seed argument is flagged —
    an unseeded generator pulls OS entropy and breaks resumability;
  * inside declared deterministic zones (sketch/compaction code, binning,
    the tree builder, and any function decorated ``@register_program`` —
    the distributed protocol bodies), wall-clock reads
    (``time.time``/``monotonic``/``perf_counter``, ``datetime.now``,
    ``uuid4``) are flagged: time-dependent control flow there would make
    reruns diverge between parties.
"""
from __future__ import annotations

import ast

from ..base import Finding, ModuleSource, module_matches
from ..policy import DEFAULT_POLICY, Policy
from .asserts import _qualname_map


def _attr_chain(node) -> list[str]:
    """['np', 'random', 'rand'] for np.random.rand; [] if not a pure
    name/attribute chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _zone_functions(tree) -> list[tuple[int, int]]:
    """Line spans of functions decorated with register_program."""
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                chain = _attr_chain(target)
                if chain and chain[-1] == "register_program":
                    spans.append((node.lineno,
                                  node.end_lineno or node.lineno))
    return spans


def run(modules: list[ModuleSource],
        policy: Policy = DEFAULT_POLICY) -> list[Finding]:
    findings = []
    for m in modules:
        quals = _qualname_map(m.tree)
        whole_module_zone = module_matches(m, policy.determinism_zone_globs)
        zone_spans = _zone_functions(m.tree)

        def in_zone(line):
            return whole_module_zone or any(lo <= line <= hi
                                            for lo, hi in zone_spans)

        for node in ast.walk(m.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if not chain:
                continue
            line, sym = node.lineno, quals.get(node.lineno, "<module>")
            # legacy global-state RNG — banned everywhere
            if (len(chain) >= 3 and chain[-3] in ("np", "numpy")
                    and chain[-2] == "random"
                    and chain[-1] in policy.legacy_rng_fns):
                findings.append(Finding(
                    rule="determinism", path=m.rel, line=line, symbol=sym,
                    message=f"legacy global-state RNG "
                            f"`{'.'.join(chain)}` — use a seeded "
                            f"np.random.default_rng(seed) generator"))
            elif (len(chain) == 2 and chain[0] == "random"
                    and chain[1] in policy.legacy_rng_fns):
                findings.append(Finding(
                    rule="determinism", path=m.rel, line=line, symbol=sym,
                    message=f"stdlib global-state RNG `{'.'.join(chain)}` — "
                            f"use a seeded np.random.default_rng(seed)"))
            # unseeded default_rng() — OS entropy breaks resumability
            elif (chain[-1] == "default_rng" and not node.args
                    and not node.keywords):
                findings.append(Finding(
                    rule="determinism", path=m.rel, line=line, symbol=sym,
                    message="unseeded np.random.default_rng() pulls OS "
                            "entropy — pass an explicit seed"))
            # wall-clock reads inside deterministic zones
            elif chain[-1] in policy.time_calls and in_zone(line):
                findings.append(Finding(
                    rule="determinism", path=m.rel, line=line, symbol=sym,
                    message=f"time-dependent call `{'.'.join(chain)}` inside "
                            f"a deterministic protocol/sketch zone — reruns "
                            f"would diverge between parties"))
    return findings
