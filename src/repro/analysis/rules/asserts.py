"""Rule: no bare ``assert`` enforcing validation/privacy in library code.

``assert`` statements are stripped under ``python -O`` — an invariant that
matters (shape checks, fitted-state checks, privacy preconditions) must
``raise`` so it survives optimization.  Demo entry points under
``launch/`` are exempt by policy: CI executes them unoptimized and their
asserts *are* the integration gate.
"""
from __future__ import annotations

import ast

from ..base import Finding, ModuleSource, module_matches
from ..policy import DEFAULT_POLICY, Policy


def _qualname_map(tree) -> dict[int, str]:
    """Map each statement line to its enclosing def/class qualname."""
    spans: list[tuple[int, int, str]] = []

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                q = prefix + child.name
                spans.append((child.lineno, child.end_lineno or child.lineno,
                              q))
                walk(child, q + ".")
            else:
                walk(child, prefix)

    walk(tree, "")
    out = {}
    for lo, hi, q in sorted(spans, key=lambda s: s[1] - s[0], reverse=True):
        for line in range(lo, hi + 1):
            out[line] = q       # innermost (smallest) span wins
    return out


def run(modules: list[ModuleSource],
        policy: Policy = DEFAULT_POLICY) -> list[Finding]:
    findings = []
    for m in modules:
        if module_matches(m, policy.assert_exempt_globs):
            continue
        quals = _qualname_map(m.tree)
        for node in ast.walk(m.tree):
            if isinstance(node, ast.Assert):
                try:
                    test = ast.unparse(node.test)[:60]
                except Exception:
                    test = "<condition>"
                findings.append(Finding(
                    rule="asserts", path=m.rel, line=node.lineno,
                    symbol=quals.get(node.lineno, "<module>"),
                    message=f"bare `assert {test}` dies under `python -O` — "
                            f"raise ValueError/TypeError instead"))
    return findings
