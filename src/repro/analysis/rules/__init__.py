"""Companion rule passes sharing the egress framework's Finding plumbing."""
from . import asserts, determinism, locks  # noqa: F401

__all__ = ["asserts", "determinism", "locks"]
