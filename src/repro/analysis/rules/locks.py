"""Rule: thread-shared fields are mutated only under their owning lock.

The field→lock map is NOT hardcoded here — it is parsed from each
threading class's docstring, which is the single authoritative source
(satellite of PR 9).  A class that owns a ``threading.Lock``/``RLock``
must carry a section of the form::

    Lock discipline (checked by repro.analysis rules/locks):
        _lock: _pending, _next_id, request_stats
        unsynchronized (coordinator thread only): dead_letters, ring

Grammar: a line containing ``Lock discipline`` opens the section; each
following ``<lock-attr>[ (note) ]: field, field, ...`` line assigns fields
to the lock attribute that must be held (via ``with self.<lock-attr>:``)
when they are mutated.  The special group ``unsynchronized`` documents
fields that are single-thread-by-contract (with the reason in the
parenthetical).  The section ends at the first non-matching line.

Checks, for every class in ``policy.lock_modules``:

  * a class that creates a lock in ``__init__`` but has no section → finding
    (undocumented discipline);
  * a mutation of ``self.<field>`` (assign/augassign/subscript-store/del/
    in-place mutator call) outside ``__init__`` where the field is mapped
    to a lock but the mutation is not lexically inside
    ``with self.<lock>:`` → finding;
  * a mutation of a ``self.<field>`` not covered by any group → finding
    (the map must stay exhaustive or it rots).

Nested ``def``s reset the held-lock context: a closure's body runs later,
on some other thread's schedule, even if it is *defined* under the lock.
"""
from __future__ import annotations

import ast
import re

from ..base import Finding, ModuleSource, module_matches
from ..policy import DEFAULT_POLICY, Policy

_SECTION_RE = re.compile(r"Lock discipline")
_GROUP_RE = re.compile(r"^\s*(\w+)\s*(?:\([^)]*\))?\s*:\s*(.+?)\s*$")


def parse_lock_map(docstring: str | None):
    """-> {field: lock_attr | None}  (None = documented unsynchronized),
    or None when the docstring has no Lock discipline section."""
    if not docstring:
        return None
    lines = docstring.splitlines()
    start = None
    for i, line in enumerate(lines):
        if _SECTION_RE.search(line):
            start = i + 1
            break
    if start is None:
        return None
    field_map: dict[str, str | None] = {}
    for line in lines[start:]:
        if not line.strip():
            if field_map:
                break
            continue
        m = _GROUP_RE.match(line)
        if m is None:
            break
        lock, fields = m.group(1), m.group(2)
        owner = None if lock == "unsynchronized" else lock
        for f in fields.split(","):
            f = f.strip()
            if f:
                field_map[f] = owner
    return field_map


def _self_field(node):
    """The `f` in self.f / self.f[...] / self.f[...].g chains (outermost
    attribute hanging off `self`), else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        node = node.value
    return None


def _creates_lock(cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("Lock", "RLock")):
            return True
    return False


class _MutationScanner:
    def __init__(self, policy, findings, rel, cls_name, field_map):
        self.policy = policy
        self.findings = findings
        self.rel = rel
        self.cls_name = cls_name
        self.field_map = field_map

    def _flag(self, node, method, field, lock):
        if lock is _UNDECLARED:
            msg = (f"mutation of `self.{field}` not covered by the class "
                   f"docstring's Lock discipline map — declare its owning "
                   f"lock or document it as unsynchronized")
        else:
            msg = (f"`self.{field}` is owned by `self.{lock}` per the class "
                   f"docstring but is mutated outside `with self.{lock}:`")
        self.findings.append(Finding(
            rule="locks", path=self.rel, line=node.lineno,
            symbol=f"{self.cls_name}.{method}", message=msg))

    def _check(self, node, method, field, held):
        if field is None:
            return
        if field not in self.field_map:
            self._flag(node, method, field, _UNDECLARED)
            return
        lock = self.field_map[field]
        if lock is not None and lock not in held:
            self._flag(node, method, field, lock)

    def _scan_expr(self, expr, method_name, held):
        """Mutator calls (self.f.append(...) etc.) inside one expression."""
        if expr is None:
            return
        for node in ast.walk(expr):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self.policy.mutator_methods):
                self._check(node, method_name,
                            _self_field(node.func.value), held)

    def scan_method(self, method: ast.FunctionDef):
        if method.name == "__init__":
            return
        name = method.name

        def walk(stmts, held):
            for stmt in stmts:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    walk(stmt.body, frozenset())   # closures run unlocked
                elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                    inner = set(held)
                    for item in stmt.items:
                        self._scan_expr(item.context_expr, name, held)
                        ctx = item.context_expr
                        if (isinstance(ctx, ast.Attribute)
                                and isinstance(ctx.value, ast.Name)
                                and ctx.value.id == "self"):
                            inner.add(ctx.attr)
                    walk(stmt.body, frozenset(inner))
                elif isinstance(stmt, (ast.If, ast.While)):
                    self._scan_expr(stmt.test, name, held)
                    walk(stmt.body, held)
                    walk(stmt.orelse, held)
                elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                    self._scan_expr(stmt.iter, name, held)
                    self._check(stmt.target, name,
                                _self_field(stmt.target), held)
                    walk(stmt.body, held)
                    walk(stmt.orelse, held)
                elif isinstance(stmt, ast.Try):
                    walk(stmt.body, held)
                    for handler in stmt.handlers:
                        walk(handler.body, held)
                    walk(stmt.orelse, held)
                    walk(stmt.finalbody, held)
                else:
                    # simple statement: no nested statements inside, safe
                    # to scan the whole subtree with the current held set
                    if isinstance(stmt, (ast.Assign, ast.AnnAssign,
                                         ast.AugAssign)):
                        targets = (stmt.targets
                                   if isinstance(stmt, ast.Assign)
                                   else [stmt.target])
                        for t in targets:
                            self._check(t, name, _self_field(t), held)
                    elif isinstance(stmt, ast.Delete):
                        for t in stmt.targets:
                            self._check(t, name, _self_field(t), held)
                    for child in ast.iter_child_nodes(stmt):
                        if isinstance(child, ast.expr):
                            self._scan_expr(child, name, held)

        walk(method.body, frozenset())


_UNDECLARED = object()


def run(modules: list[ModuleSource],
        policy: Policy = DEFAULT_POLICY) -> list[Finding]:
    findings = []
    for m in modules:
        if not module_matches(m, policy.lock_modules):
            continue
        for node in ast.walk(m.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            field_map = parse_lock_map(ast.get_docstring(node))
            if field_map is None:
                if _creates_lock(node):
                    findings.append(Finding(
                        rule="locks", path=m.rel, line=node.lineno,
                        symbol=node.name,
                        message=f"class `{node.name}` owns a threading lock "
                                f"but its docstring has no 'Lock "
                                f"discipline' field→lock map"))
                continue
            scanner = _MutationScanner(policy, findings, m.rel,
                                       node.name, field_map)
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    scanner.scan_method(item)
    return findings
