"""Privacy-egress analysis: static taint linter + runtime wire guard.

Static side: ``python -m repro.analysis`` (or ``run_analysis(...)``) walks
``src/repro/**`` and proves raw party data (`PartyBlock.x/.ids/.y`,
streaming scans) cannot reach a wire sink unsanitized, plus companion
rules for bare asserts, determinism, and lock discipline.  Policy lives in
:mod:`repro.analysis.policy`.

Runtime side: :mod:`repro.analysis.runtime` tags raw arrays at
construction and `transport.Channel.send` refuses to ship them
(`PrivacyViolationError`), enabled by ``REPRO_EGRESS_GUARD=1``.

This ``__init__`` stays import-light on purpose — the transport layer
imports the runtime guard from every worker process.
"""
from .base import Finding
from .runtime import (PrivacyViolationError, allow_egress, check_egress,
                      taint, taint_block)

__all__ = ["Finding", "PrivacyViolationError", "allow_egress",
           "check_egress", "taint", "taint_block", "run_analysis"]

ALL_RULES = ("egress", "asserts", "determinism", "locks")


def run_analysis(paths, rules=ALL_RULES, policy=None) -> list[Finding]:
    """Run the selected rule passes over ``paths`` (dirs or files) and
    return suppression-filtered findings, sorted by (path, line)."""
    from . import base, egress
    from .policy import DEFAULT_POLICY
    from .rules import asserts, determinism, locks

    policy = policy or DEFAULT_POLICY
    modules = base.load_modules(paths, exclude_globs=policy.exclude_globs)
    findings: list[Finding] = []
    if "egress" in rules:
        findings += egress.run_egress(modules, policy)
    if "asserts" in rules:
        findings += asserts.run(modules, policy)
    if "determinism" in rules:
        findings += determinism.run(modules, policy)
    if "locks" in rules:
        findings += locks.run(modules, policy)
    return base.apply_suppressions(findings, modules)
