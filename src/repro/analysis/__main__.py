"""CLI for the privacy-egress analyzer.

    python -m repro.analysis [paths...] [--rules egress,asserts,...]
                             [--json] [--fail-on-findings]
                             [--baseline FILE | --no-baseline]
                             [--write-baseline FILE]

With no paths, analyzes the ``src/repro`` tree this package lives in.
``--baseline`` defaults to the checked-in ``analysis/baseline.json``
(currently empty: the tree is finding-free) so a future rule addition can
land by baselining its pre-existing findings instead of blocking.
Exit status: 0 clean (or findings tolerated without --fail-on-findings),
1 findings with --fail-on-findings, 2 usage/parse errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import ALL_RULES, run_analysis
from .base import filter_baseline, load_baseline

_DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Privacy-egress taint linter + rule passes for the "
                    "federated forest tree")
    parser.add_argument("paths", nargs="*",
                        help="files/dirs to analyze (default: src/repro)")
    parser.add_argument("--rules", default=",".join(ALL_RULES),
                        help=f"comma-separated subset of {ALL_RULES}")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit a JSON report instead of text")
    parser.add_argument("--fail-on-findings", action="store_true",
                        help="exit 1 if any non-baselined finding remains")
    parser.add_argument("--baseline", default=None, metavar="FILE",
                        help="fingerprint baseline to tolerate "
                             f"(default: {_DEFAULT_BASELINE.name} if present)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore any baseline file")
    parser.add_argument("--write-baseline", default=None, metavar="FILE",
                        help="write current findings as the new baseline "
                             "and exit 0")
    args = parser.parse_args(argv)

    rules = tuple(r.strip() for r in args.rules.split(",") if r.strip())
    unknown = [r for r in rules if r not in ALL_RULES]
    if unknown:
        parser.error(f"unknown rules {unknown}; choose from {ALL_RULES}")

    paths = args.paths or [Path(__file__).resolve().parents[1]]
    findings = run_analysis(paths, rules=rules)

    if args.write_baseline:
        Path(args.write_baseline).write_text(json.dumps(
            [f.fingerprint() for f in findings], indent=2) + "\n")
        print(f"wrote {len(findings)} fingerprint(s) to "
              f"{args.write_baseline}")
        return 0

    baselined = []
    if not args.no_baseline:
        baseline_path = args.baseline or _DEFAULT_BASELINE
        baseline = load_baseline(baseline_path)
        findings, baselined = filter_baseline(findings, baseline)

    if args.as_json:
        print(json.dumps({
            "findings": [dict(f.fingerprint(), line=f.line)
                         for f in findings],
            "baselined": len(baselined),
            "rules": list(rules),
        }, indent=2))
    else:
        for f in findings:
            print(f.render())
        suffix = f" ({len(baselined)} baselined)" if baselined else ""
        print(f"repro.analysis: {len(findings)} finding(s) across rules "
              f"{','.join(rules)}{suffix}")

    if findings and args.fail_on_findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
