"""Shared plumbing for the analysis passes: findings, files, suppressions.

Every rule pass (egress taint linter, asserts, determinism, locks) produces
:class:`Finding` records over a set of Python files; this module owns the
record type, the file iteration (with policy path excludes), and the
``# egress: ok(reason)`` suppression contract:

  * a finding anchored at line L is suppressed when line L — or the line
    directly above it — carries ``# egress: ok(<non-empty reason>)``;
  * an ``# egress: ok()`` with an EMPTY reason suppresses nothing and is
    itself reported (rule ``suppression``): a silenced warning without a
    written-down justification is how invariants rot.

Baselines: a JSON list of finding fingerprints (rule/path/symbol/message —
deliberately line-number-free so unrelated edits don't invalidate it) that
are tolerated; the CLI's ``--baseline`` filter lets a new rule land without
blocking on pre-existing findings while keeping them visible via
``--no-baseline``.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import re
from pathlib import Path

SUPPRESS_RE = re.compile(r"#\s*egress:\s*ok\(([^)]*)\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis finding, anchored to a file/line/function."""

    rule: str          # "egress" | "asserts" | "determinism" | "locks" | ...
    path: str          # path relative to the analysis root
    line: int
    symbol: str        # qualname of the enclosing def/class, or "<module>"
    message: str

    def fingerprint(self) -> dict:
        """Line-number-free identity used by baseline files."""
        return {"rule": self.rule, "path": self.path,
                "symbol": self.symbol, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.symbol}: " \
               f"{self.message}"


@dataclasses.dataclass
class ModuleSource:
    """One parsed module handed to the rule passes."""

    path: Path          # absolute
    rel: str            # path relative to the analysis root (policy matching)
    text: str
    tree: "object"      # ast.Module

    def lines(self) -> list[str]:
        return self.text.splitlines()


def iter_py_files(roots, exclude_globs=()) -> list[tuple[Path, str]]:
    """All .py files under ``roots`` (files pass through), as
    ``(absolute, root-relative)`` pairs, minus policy-excluded globs."""
    out = []
    for root in roots:
        root = Path(root).resolve()
        if root.is_file():
            files = [(root, root.name)]
        else:
            files = sorted((p, p.relative_to(root).as_posix())
                           for p in root.rglob("*.py"))
        for abs_path, rel in files:
            if any(fnmatch.fnmatch(rel, g) for g in exclude_globs):
                continue
            out.append((abs_path, rel))
    return out


def load_modules(roots, exclude_globs=()) -> list[ModuleSource]:
    import ast
    mods = []
    for abs_path, rel in iter_py_files(roots, exclude_globs):
        text = abs_path.read_text()
        mods.append(ModuleSource(path=abs_path, rel=rel, text=text,
                                 tree=ast.parse(text, filename=str(abs_path))))
    return mods


def module_matches(mod: ModuleSource, patterns) -> bool:
    """Glob match against the root-relative path, falling back to the
    absolute path — so `launch/*` exempts launch demos whether the
    analyzer was pointed at src/repro or at the launch dir itself."""
    apath = mod.path.as_posix()
    return any(fnmatch.fnmatch(mod.rel, g)
               or fnmatch.fnmatch(apath, "*/" + g)
               for g in patterns)


def suppressed_lines(text: str) -> dict[int, str]:
    """{1-based line: reason} for every ``# egress: ok(reason)`` comment."""
    out = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = SUPPRESS_RE.search(line)
        if m is not None:
            out[i] = m.group(1).strip()
    return out


def apply_suppressions(findings: list[Finding],
                       modules: list[ModuleSource]) -> list[Finding]:
    """Filter findings under valid suppression comments; report empty-reason
    suppressions as findings of their own."""
    supp = {m.rel: suppressed_lines(m.text) for m in modules}
    kept = []
    for f in findings:
        lines = supp.get(f.path, {})
        reason = lines.get(f.line)
        if reason is None:
            reason = lines.get(f.line - 1)
        if reason:          # non-empty reason suppresses
            continue
        kept.append(f)
    for m in modules:
        for line, reason in supp[m.rel].items():
            if not reason:
                kept.append(Finding(
                    rule="suppression", path=m.rel, line=line,
                    symbol="<module>",
                    message="egress suppression without a reason — write "
                            "the justification inside ok(...): an unexplained "
                            "silence is unauditable"))
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))


def load_baseline(path) -> list[dict]:
    p = Path(path)
    if not p.exists():
        return []
    data = json.loads(p.read_text() or "[]")
    if not isinstance(data, list):
        raise ValueError(f"{path}: baseline must be a JSON list of "
                         f"fingerprints")
    return data


def filter_baseline(findings: list[Finding], baseline: list[dict]):
    """Split findings into (new, baselined) against fingerprint entries."""
    known = {tuple(sorted(d.items())) for d in baseline}
    new, old = [], []
    for f in findings:
        (old if tuple(sorted(f.fingerprint().items())) in known
         else new).append(f)
    return new, old
