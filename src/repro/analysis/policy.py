"""The privacy-egress policy: what is SECRET, what sanitizes, what is a wire.

This file is the checked-in contract that `egress.py` (static taint pass)
and `runtime.py` (wire guard) both enforce.  The paper's trust model
(Federated Forest, arXiv:1905.10053) allows exactly three things to cross
a party boundary:

  * salted **hashed** sample IDs (the ingest alignment handshake),
  * **party-locally binned** feature codes plus bin boundaries,
  * **masked / encoded** label statistics (leaf stats, encoded class ids,
    pairwise-cancelling regression masks).

Everything else derived from `PartyBlock.x / .ids / .y` (and the streaming
equivalents retained on `SourceScan`) is SECRET and must never reach
`Channel.send` / the transport codec.

Extending the policy for a new message type
-------------------------------------------
1. If the new field is derived through a *new* party-local transform, add
   the transform's function name to ``SANITIZERS`` — and make sure it
   really is non-invertible party-side (binning, hashing, masking).
2. If a wire payload legitimately carries raw data (e.g. a party
   provisioning its *own* worker process), keep the static suppression
   ``# egress: ok(reason)`` on the send line AND wrap the runtime send in
   ``analysis.runtime.allow_egress(reason)`` — the two must stay paired so
   the linter and the wire agree.
3. New sink verbs (a second transport, a new RPC helper) go in ``SINKS``.
"""
from __future__ import annotations

import dataclasses

# Attribute names whose *read* introduces raw/private data, wherever the
# object came from.  These are the raw fields of PartyBlock, SourceScan and
# the per-chunk blocks yielded by ChunkedSource.iter_chunks.
SECRET_ATTRS = frozenset({"x", "ids", "y"})

# Attribute reads that are protocol metadata, never raw data — they break
# taint even on a tainted object.  (`hashes` is the salted-hash digest
# array retained by SourceScan; boundaries/edges are bin edges, which the
# paper sends in the clear.)
SAFE_ATTRS = frozenset({
    "name", "n_features", "n_rows", "n_samples", "n_chunks", "shape",
    "size", "dtype", "ndim", "feature_ids", "feature_names", "hashes",
    "boundaries", "edges", "version", "schema", "fingerprint", "capacity",
    "rank_error", "n_bins", "seed", "party", "index",
})

# Callables (matched by bare function / method name) whose RESULT is clean
# regardless of argument taint: the registered party-local transforms.
# Keep this list short and honest — everything here must be reviewed as
# non-invertible from the other side of the wire.
SANITIZERS = frozenset({
    "hash_ids",                 # crypto: salted SHA-256 of raw sample IDs
    "hashed_ids",               # PartyBlock method wrapping hash_ids
    "align_ids", "align_hashed",  # intersection positions of hashed IDs
    "bin_dataset", "apply_bins",  # core.binning: party-local quantile codes
    "interior_quantiles",
    "bin_party_blocks",         # party.VerticalPartition party-local binning
    "party_stream_bin",         # streaming.ingest sketch-boundary binning
    "encode_labels",            # crypto: dense class re-encoding
    "mask_regression_targets",  # crypto: additive target masking
    "pairwise_cancelling_masks",  # crypto: zero-sum mask shares
    "encode_feature_names",
})

# Call verbs that put their arguments on the wire.  Matched by bare name at
# the call site (method or function).  `send`/`sendall` are the socket
# layer, `pack` is the msgpack codec entry, `request`/`_send`/`exchange`
# are the coordinator RPC helpers that forward payloads to Channel.send.
# The observability verbs (`span`/`event`/`begin`/`observe` and the trace
# exporters) are wire-sensitive too: spans cross processes in the telemetry
# op and land in exported artifacts, so a tainted argument to any of them
# is raw data leaving the party exactly like a socket send — the linter
# proves span/metric payloads stay metadata-only.
SINKS = frozenset({"send", "sendall", "pack", "request", "_send",
                   "exchange",
                   "span", "event", "begin", "observe",
                   "export_jsonl", "write_chrome_trace", "chrome_trace"})

# Builtins/uti calls whose result never carries payload data even when fed
# tainted arguments (sizes, types, formatting of scalars).
NEUTRAL_CALLS = frozenset({
    "len", "int", "float", "bool", "str", "repr", "format", "type", "id",
    "isinstance", "issubclass", "hasattr", "range", "print", "min", "max",
    "sum", "abs", "round", "hash",
})

# Modules (globs relative to the analysis root) the passes skip entirely.
EXCLUDE_GLOBS = ("analysis/*", "analysis/**/*")

# --- rules/asserts.py -------------------------------------------------------
# Bare `assert` is allowed only in demo/self-check entry points: launch/*
# scripts are executed unoptimized by CI as integration gates, and their
# asserts ARE the test.  Library code must raise, or it silently passes
# under `python -O`.
ASSERT_EXEMPT_GLOBS = ("launch/*", "launch/**/*")

# --- rules/determinism.py ---------------------------------------------------
# Legacy global-state numpy RNG calls — banned everywhere in src/repro.
LEGACY_RNG_FNS = frozenset({
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "choice", "permutation", "shuffle", "normal", "uniform",
    "standard_normal", "get_state", "set_state",
})
# Deterministic zones: protocol bodies and sketch/compaction code where
# time-dependent values would break bit-identity and resumability.  A
# function decorated with `register_program` is a zone wherever it lives.
DETERMINISM_ZONE_GLOBS = (
    "streaming/sketch.py", "streaming/ingest.py",
    "core/tree.py", "core/binning.py", "core/impurity.py",
)
TIME_CALLS = frozenset({"time", "monotonic", "perf_counter",
                        "process_time", "now", "utcnow", "uuid4"})

# --- rules/locks.py ---------------------------------------------------------
# Modules whose threading classes must carry a "Lock discipline" docstring
# section (the single authoritative field→lock map the rule checks).
LOCK_MODULES = ("serving/fleet.py", "serving/queue.py")
# Method names that mutate a container in place.
MUTATOR_METHODS = frozenset({
    "append", "appendleft", "extend", "insert", "pop", "popleft",
    "clear", "update", "add", "remove", "discard", "setdefault",
    "insort",
})


@dataclasses.dataclass(frozen=True)
class Policy:
    secret_attrs: frozenset = SECRET_ATTRS
    safe_attrs: frozenset = SAFE_ATTRS
    sanitizers: frozenset = SANITIZERS
    sinks: frozenset = SINKS
    neutral_calls: frozenset = NEUTRAL_CALLS
    exclude_globs: tuple = EXCLUDE_GLOBS
    assert_exempt_globs: tuple = ASSERT_EXEMPT_GLOBS
    legacy_rng_fns: frozenset = LEGACY_RNG_FNS
    determinism_zone_globs: tuple = DETERMINISM_ZONE_GLOBS
    time_calls: frozenset = TIME_CALLS
    lock_modules: tuple = LOCK_MODULES
    mutator_methods: frozenset = MUTATOR_METHODS


DEFAULT_POLICY = Policy()
