"""Runtime egress guard: the wire-side twin of the static taint pass.

`taint(arr, label)` tags raw numpy arrays at the moment they are
constructed party-side (`PartyBlock.__post_init__`, streaming
`SourceScan`s), and `check_egress(msg)` — called by
`transport.Channel.send` before anything is encoded — walks the outgoing
payload pytree and raises a typed :class:`PrivacyViolationError` naming
the offending key path if any tagged array (or a view of one) is about to
cross the wire.

Design notes
------------
* The registry is keyed by ``id(array)`` with a ``weakref.ref`` holding
  the identity alive-check (``np.ndarray`` is unhashable, so a
  WeakKeyDictionary cannot be used; the ref-is-object check defeats id
  reuse after garbage collection).  Dead entries are pruned
  opportunistically so the registry stays bounded under streaming
  workloads that construct thousands of short-lived chunk blocks.
* Views are caught by walking ``arr.base``: slicing a tagged block's
  column out of it yields a view whose ``.base`` chain reaches the tagged
  buffer.  Fancy-indexed *copies* (e.g. ``block.y[positions]``) are new
  buffers and are deliberately NOT tainted — the paper's trust model
  allows aligned labels to return to the coordinator session, and the
  static pass documents that flow with an ``# egress: ok(...)``
  suppression at the send site.
* The guard is off by default (zero overhead in library use) and enabled
  by ``REPRO_EGRESS_GUARD=1`` — set by ``tests/conftest.py`` and the
  distributed demo.  Because worker processes are spawned, they inherit
  the environment and enforce the same policy on their side of the wire.
* `allow_egress(reason)` is the runtime twin of the static
  ``# egress: ok(reason)`` comment: a thread-local escape hatch for the
  one legitimate raw flow (a party provisioning its *own* worker
  process).  Static suppression and runtime allowance must stay paired.
"""
from __future__ import annotations

import os
import threading
import weakref

import numpy as np

_PRUNE_THRESHOLD = 4096

_enabled = os.environ.get("REPRO_EGRESS_GUARD", "") not in ("", "0")
_registry: dict[int, tuple] = {}        # id(arr) -> (weakref, label)
_lock = threading.Lock()
_local = threading.local()


class PrivacyViolationError(RuntimeError):
    """A raw-tagged array was about to cross a party boundary.

    Attributes:
        path: key path inside the outgoing message, e.g.
            ``msg['payload']['x']``.
        label: the taint label attached when the array was constructed,
            e.g. ``PartyBlock['credit'].x (raw features)``.
    """

    def __init__(self, path: str, label: str, context: str = ""):
        self.path = path
        self.label = label
        where = f" in {context}" if context else ""
        super().__init__(
            f"privacy egress blocked{where}: {path} carries {label} — raw "
            f"party data must pass a registered sanitizer (hash_ids / "
            f"party-local binning / label masking) before Channel.send")


def enabled() -> bool:
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def _prune_locked() -> None:
    dead = [k for k, (ref, _) in _registry.items() if ref() is None]
    for k in dead:
        del _registry[k]


def taint(arr, label: str):
    """Tag ``arr`` as raw party data; returns ``arr`` for chaining.

    The whole ``.base`` chain is registered under the same label: numpy
    COLLAPSES view chains (a view of a view points straight at the
    ultimate buffer), so a later view of ``arr`` may share ``arr``'s base
    without referencing ``arr`` itself — tagging the underlying buffer is
    what makes every future view detectable.  No-ops when the guard is
    disabled or ``arr`` is not an ndarray, so call sites stay
    unconditional.
    """
    if not _enabled or not isinstance(arr, np.ndarray):
        return arr
    chain, node, hops = [], arr, 0
    while isinstance(node, np.ndarray) and hops < 16:
        chain.append(node)
        node = node.base
        hops += 1
    with _lock:
        if len(_registry) > _PRUNE_THRESHOLD:
            _prune_locked()
        for node in chain:
            try:
                _registry[id(node)] = (weakref.ref(node), label)
            except TypeError:   # exotic subclass without weakref slots
                pass
    return arr


def taint_block(block) -> None:
    """Tag the raw fields of a PartyBlock-shaped object."""
    name = getattr(block, "name", "?")
    taint(block.x, f"PartyBlock[{name!r}].x (raw features)")
    taint(block.ids, f"PartyBlock[{name!r}].ids (raw sample IDs)")
    if block.y is not None:
        taint(block.y, f"PartyBlock[{name!r}].y (raw labels)")


def lookup(arr) -> str | None:
    """The taint label of ``arr`` or any array in its ``.base`` chain."""
    if not isinstance(arr, np.ndarray):
        return None
    seen = 0
    while arr is not None and seen < 16:
        entry = _registry.get(id(arr))
        if entry is not None:
            ref, label = entry
            if ref() is arr:        # identity check defeats id() reuse
                return label
        arr = arr.base if isinstance(arr.base, np.ndarray) else None
        seen += 1
    return None


class allow_egress:
    """Thread-local allowance for a legitimate raw send (provisioning a
    party's own worker).  Pair every use with a static
    ``# egress: ok(reason)`` on the send line."""

    def __init__(self, reason: str):
        if not reason or not reason.strip():
            raise ValueError("allow_egress requires a non-empty reason — "
                             "unexplained allowances are unauditable")
        self.reason = reason

    def __enter__(self):
        _local.depth = getattr(_local, "depth", 0) + 1
        return self

    def __exit__(self, *exc):
        _local.depth -= 1
        return False


def _allowed() -> bool:
    return getattr(_local, "depth", 0) > 0


def check_egress(msg, context: str = "") -> None:
    """Raise PrivacyViolationError if ``msg`` (a message pytree of dicts /
    lists / tuples / NamedTuples / arrays) contains a tainted array."""
    if not _enabled or _allowed() or not _registry:
        return
    _walk(msg, "msg", context, 0)


def _walk(obj, path, context, depth):
    if depth > 12 or obj is None:
        return
    if isinstance(obj, np.ndarray):
        label = lookup(obj)
        if label is not None:
            raise PrivacyViolationError(path, label, context)
        return
    if isinstance(obj, dict):
        for k, v in obj.items():
            _walk(v, f"{path}[{k!r}]", context, depth + 1)
        return
    if isinstance(obj, (list, tuple)):
        fields = getattr(obj, "_fields", None)
        if fields is not None:      # NamedTuple: name the field
            for name, v in zip(fields, obj):
                _walk(v, f"{path}.{name}", context, depth + 1)
        else:
            for i, v in enumerate(obj):
                _walk(v, f"{path}[{i}]", context, depth + 1)


def registry_size() -> int:
    with _lock:
        _prune_locked()
        return len(_registry)
