"""Jit'd public wrappers for the kernels, with backend dispatch.

``histogram`` has three interchangeable implementations:
  * ``pallas``  — the TPU kernel (interpret=True executes it on CPU);
  * ``scatter`` — index-add formulation, fastest on CPU hosts (used by the
                  single-host simulation path of the federated protocol);
  * ``ref``     — the einsum oracle.
All agree to float32 tolerance (tests/test_kernels.py sweeps them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import histogram as _hist_kernel
from repro.kernels import ref as _ref


def _histogram_scatter(xb, seg, stats, n_level: int, n_bins: int):
    n, f = xb.shape
    c = stats.shape[-1]
    xb = xb.astype(jnp.int32)
    # flat bucket id per (sample, feature); invalid samples -> overflow slot
    base = seg[:, None] * (f * n_bins) + jnp.arange(f)[None, :] * n_bins + xb
    flat = jnp.where(seg[:, None] >= 0, base, n_level * f * n_bins)
    vals = jnp.broadcast_to(stats[:, None, :], (n, f, c)).astype(jnp.float32)
    out = jnp.zeros((n_level * f * n_bins + 1, c), jnp.float32)
    out = out.at[flat.reshape(-1)].add(vals.reshape(-1, c))
    return out[:-1].reshape(n_level, f, n_bins, c)


@functools.partial(jax.jit, static_argnames=("n_level", "n_bins", "impl"))
def histogram(xb: jnp.ndarray, seg: jnp.ndarray, stats: jnp.ndarray,
              n_level: int, n_bins: int, impl: str = "scatter") -> jnp.ndarray:
    """Split-statistics histogram: (n_level, F, n_bins, C) float32."""
    if impl == "scatter":
        return _histogram_scatter(xb, seg, stats, n_level, n_bins)
    if impl == "pallas":
        return _hist_kernel.histogram_pallas(xb, seg, stats, n_level, n_bins,
                                             interpret=True)
    if impl == "ref":
        return _ref.histogram_ref(xb, seg, stats, n_level, n_bins)
    raise ValueError(f"unknown impl {impl!r}")
