"""Jit'd public wrappers for the kernels, with a histogram backend registry.

``histogram`` dispatches through ``BACKENDS``, a name -> callable registry:
  * ``pallas``            — the TPU kernel, compiled when the host really is
                            a TPU and interpret-mode elsewhere;
  * ``pallas_interpret``  — the TPU kernel forced through the interpreter
                            (correctness path on any host);
  * ``scatter``           — index-add formulation, fastest on CPU/GPU hosts
                            (used by the single-host simulation path of the
                            federated protocol);
  * ``segment_sum``       — GPU-oriented ``jax.ops.segment_sum`` over flat
                            (node, feature, bin) ids; correctness-equivalent
                            to ``scatter`` on every host;
  * ``ref``               — the einsum oracle.
  * ``auto``              — resolves per host: compiled Pallas on TPU,
                            segment_sum on GPU, scatter on CPU.
All agree to float32 tolerance (tests/test_kernels.py sweeps them).  New
backends register with :func:`register_backend` and become selectable through
``ForestParams.hist_impl`` without touching the builder.
"""
from __future__ import annotations

import functools
from typing import Callable, Protocol

import jax
import jax.numpy as jnp

from repro.kernels import histogram as _hist_kernel
from repro.kernels import ref as _ref


class HistogramFn(Protocol):
    def __call__(self, xb: jnp.ndarray, seg: jnp.ndarray, stats: jnp.ndarray,
                 n_level: int, n_bins: int) -> jnp.ndarray: ...


BACKENDS: dict[str, HistogramFn] = {}


def register_backend(name: str) -> Callable[[HistogramFn], HistogramFn]:
    """Register a histogram implementation under ``name``.

    Implementations take ``(xb, seg, stats, n_level, n_bins)`` and return the
    ``(n_level, F, n_bins, C)`` float32 split-statistics tensor; samples with
    ``seg < 0`` must contribute nothing.
    """
    def deco(fn: HistogramFn) -> HistogramFn:
        BACKENDS[name] = fn
        return fn
    return deco


def detected_platform() -> str:
    """The accelerator platform ``auto`` resolves against — a seam so tests
    can cover cpu/gpu/tpu resolution without the hardware (monkeypatch this,
    not jax.default_backend)."""
    return jax.default_backend()


def resolve_backend(impl: str) -> str:
    """Map ``"auto"`` onto a concrete registry key for this host: compiled
    Pallas on TPU, ``segment_sum`` on GPU (XLA's tuned unsorted-segment
    reduction beats the generic scatter-add lowering there), ``scatter``
    on CPU."""
    if impl != "auto":
        if impl not in BACKENDS:
            raise ValueError(
                f"unknown impl {impl!r} (have {sorted(BACKENDS)})")
        return impl
    platform = detected_platform()
    if platform == "tpu":
        return "pallas"
    if platform in ("gpu", "cuda", "rocm"):
        return "segment_sum"
    return "scatter"


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(BACKENDS))


def _flat_buckets(xb, seg, stats, n_level: int, n_bins: int):
    """Shared flattening of the scatter-family backends.

    Returns ``(flat, vals)``: a flat (node, feature, bin) bucket id per
    (sample, feature) — invalid samples (seg < 0) route to the single
    overflow slot ``n_level * F * n_bins`` — and the matching f32 stat rows.
    Any backend that reduces by bucket id must use this exact layout so the
    seg<0 convention stays in one place."""
    n, f = xb.shape
    c = stats.shape[-1]
    xb = xb.astype(jnp.int32)
    base = seg[:, None] * (f * n_bins) + jnp.arange(f)[None, :] * n_bins + xb
    flat = jnp.where(seg[:, None] >= 0, base, n_level * f * n_bins)
    vals = jnp.broadcast_to(stats[:, None, :], (n, f, c)).astype(jnp.float32)
    return flat.reshape(-1), vals.reshape(-1, c)


@register_backend("scatter")
def _histogram_scatter(xb, seg, stats, n_level: int, n_bins: int):
    f, c = xb.shape[1], stats.shape[-1]
    flat, vals = _flat_buckets(xb, seg, stats, n_level, n_bins)
    out = jnp.zeros((n_level * f * n_bins + 1, c), jnp.float32)
    out = out.at[flat].add(vals)
    return out[:-1].reshape(n_level, f, n_bins, c)


@register_backend("segment_sum")
def _histogram_segment_sum(xb, seg, stats, n_level: int, n_bins: int):
    """GPU-oriented formulation: one ``jax.ops.segment_sum`` over the same
    flat bucket ids as the scatter backend.

    On GPU, XLA lowers segment_sum to its tuned unsorted-segment-reduction
    path (atomics over f32), which beats the generic scatter-add lowering at
    large N x F; on CPU it lowers to the same scatter loop, so it is a
    correctness-equivalent drop-in everywhere (tests sweep it against the
    scatter backend)."""
    f, c = xb.shape[1], stats.shape[-1]
    flat, vals = _flat_buckets(xb, seg, stats, n_level, n_bins)
    out = jax.ops.segment_sum(vals, flat,
                              num_segments=n_level * f * n_bins + 1)
    return out[:-1].reshape(n_level, f, n_bins, c)


@register_backend("pallas")
def _histogram_pallas(xb, seg, stats, n_level: int, n_bins: int):
    # interpret=None: compiled on a real TPU, interpreter elsewhere (CPU
    # "pallas" runs have always meant interpret=True here — correctness path)
    return _hist_kernel.histogram_pallas(xb, seg, stats, n_level, n_bins,
                                         interpret=None)


@register_backend("pallas_interpret")
def _histogram_pallas_interpret(xb, seg, stats, n_level: int, n_bins: int):
    return _hist_kernel.histogram_pallas(xb, seg, stats, n_level, n_bins,
                                         interpret=True)


@register_backend("ref")
def _histogram_ref(xb, seg, stats, n_level: int, n_bins: int):
    return _ref.histogram_ref(xb, seg, stats, n_level, n_bins)


@functools.partial(jax.jit, static_argnames=("n_level", "n_bins", "fn"))
def _histogram_call(xb, seg, stats, n_level: int, n_bins: int, fn: HistogramFn):
    return fn(xb, seg, stats, n_level, n_bins)


def histogram(xb: jnp.ndarray, seg: jnp.ndarray, stats: jnp.ndarray,
              n_level: int, n_bins: int, impl: str = "auto") -> jnp.ndarray:
    """Split-statistics histogram: (n_level, F, n_bins, C) float32.

    The registry lookup happens OUTSIDE the jit boundary (the resolved
    callable is the static cache key), so re-registering a backend under an
    existing name takes effect immediately instead of being shadowed by
    cached traces of the old callable.
    """
    fn = BACKENDS[resolve_backend(impl)]
    return _histogram_call(xb, seg, stats, n_level=n_level, n_bins=n_bins,
                           fn=fn)
