"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every kernel must match its oracle to
float tolerance across the shape/dtype sweep in tests/test_kernels.py.
"""
from __future__ import annotations

import jax.numpy as jnp


def histogram_ref(xb: jnp.ndarray, seg: jnp.ndarray, stats: jnp.ndarray,
                  n_level: int, n_bins: int) -> jnp.ndarray:
    """Split-statistics histogram — the Federated Forest compute hot spot.

    hist[l, f, b, c] = sum_s 1[seg[s] == l] * 1[xb[s, f] == b] * stats[s, c]

    Args:
      xb:    (N, F) integer bin ids.
      seg:   (N,) node slot within the current tree level; -1 drops the sample.
      stats: (N, C) per-sample (already weight-multiplied) label statistics.
    Returns:
      (n_level, F, n_bins, C) float32.
    """
    node1h = (seg[:, None] == jnp.arange(n_level)[None, :]).astype(jnp.float32)
    bin1h = (xb[:, :, None] == jnp.arange(n_bins)[None, None, :]).astype(jnp.float32)
    return jnp.einsum("sl,sfb,sc->lfbc", node1h, bin1h,
                      stats.astype(jnp.float32), optimize=True)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int | None = None,
                        scale: float | None = None) -> jnp.ndarray:
    """Reference attention. q,k,v: (B, H, S, D) — GQA head-repeat done by caller."""
    f32 = jnp.float32
    sq, sk = q.shape[2], k.shape[2]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(f32), k.astype(f32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align last q with last k
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.nan_to_num(jnp.exp(logits - logits.max(-1, keepdims=True)))
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(f32)).astype(q.dtype)
