"""Pallas TPU kernel: split-statistics histogram for Federated Forest.

The paper's hot loop is "for every (tree-node, feature, bin): accumulate label
statistics" — on CPU/GPU this is a scatter-add.  TPUs have no fast scatter, so
we reformulate the accumulation as dense one-hot contractions that run on the
128x128 MXU:

    Z[s, l*C + c]  = 1[seg[s] == l] * stats[s, c]          (VPU, cheap)
    hist[f]        = onehot_bins(x[:, f]).T @ Z             (MXU matmul)

Tiling: grid over (feature tiles, sample chunks).  Each kernel invocation
holds one (feat_tile, n_level, n_bins, C) output block in VMEM and accumulates
one sample chunk into it; the sample-chunk grid axis revisits the same output
block, so we zero-init on the first chunk with ``pl.when``.

VMEM budget per invocation (defaults F_TILE=8, CHUNK=512, L<=128, B<=64, C<=8):
  x tile   512*8*4           =  16 KiB
  Z        512*L*C*4         <= 2 MiB
  out      8*L*B*C*4         <= 2 MiB
comfortably inside the ~16 MiB VMEM of a v5e core.  The matmul contraction
dim is the sample chunk (512) and output dims are (B, L*C) — padding B and
L*C to multiples of 128 keeps the MXU fully fed; we document rather than
force this, since the semantics are shape-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F_TILE = 8      # features per output block
CHUNK = 512     # samples per accumulation step


def _hist_kernel(xb_ref, seg_ref, stats_ref, out_ref, *, n_level: int,
                 n_bins: int, f_tile: int):
    """One (feature-tile, sample-chunk) grid step."""
    chunk_idx = pl.program_id(1)

    @pl.when(chunk_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]          # (CHUNK,)
    stats = stats_ref[...]      # (CHUNK, C)
    c = stats.shape[-1]

    # Z[s, l*C + c] = node-onehot * stats  — built once per chunk, reused for
    # every feature in the tile (this is the data reuse that justifies tiling
    # features innermost).
    node1h = (seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_level), 1)
              ).astype(jnp.float32)                       # (S, L)
    z = (node1h[:, :, None] * stats[:, None, :]).reshape(seg.shape[0], n_level * c)

    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1)
    for j in range(f_tile):  # static unroll over the feature tile
        bins = xb_ref[:, j]                               # (S,)
        bin1h = (bins[:, None] == bin_iota).astype(jnp.float32)  # (S, B)
        # (B, S) @ (S, L*C) on the MXU
        contrib = jax.lax.dot_general(
            bin1h, z, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (B, L*C)
        contrib = contrib.reshape(n_bins, n_level, c).transpose(1, 0, 2)
        out_ref[j] += contrib                             # (L, B, C)


@functools.partial(jax.jit, static_argnames=("n_level", "n_bins", "interpret"))
def histogram_pallas(xb: jnp.ndarray, seg: jnp.ndarray, stats: jnp.ndarray,
                     n_level: int, n_bins: int, *,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Pallas histogram. Returns (n_level, F, n_bins, C) float32.

    Sample count is padded to CHUNK and features to F_TILE; padded samples get
    seg = -1 (dropped by the node one-hot), padded features are sliced off.

    ``interpret=None`` resolves per host: compiled on a real TPU, the Pallas
    interpreter (a correctness path, not a perf path) everywhere else.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f = xb.shape
    c = stats.shape[-1]
    n_pad = -n % CHUNK
    f_pad = -f % F_TILE
    xb_p = jnp.pad(xb.astype(jnp.int32), ((0, n_pad), (0, f_pad)))
    seg_p = jnp.pad(seg.astype(jnp.int32), (0, n_pad), constant_values=-1)
    stats_p = jnp.pad(stats.astype(jnp.float32), ((0, n_pad), (0, 0)))
    np_, fp_ = xb_p.shape

    grid = (fp_ // F_TILE, np_ // CHUNK)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, n_level=n_level, n_bins=n_bins,
                          f_tile=F_TILE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((CHUNK, F_TILE), lambda i, s: (s, i)),   # xb
            pl.BlockSpec((CHUNK,), lambda i, s: (s,)),            # seg
            pl.BlockSpec((CHUNK, c), lambda i, s: (s, 0)),        # stats
        ],
        out_specs=pl.BlockSpec((F_TILE, n_level, n_bins, c),
                               lambda i, s: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((fp_, n_level, n_bins, c), jnp.float32),
        interpret=interpret,
    )(xb_p, seg_p, stats_p)
    return out[:f].transpose(1, 0, 2, 3)  # (L, F, B, C)
