"""Pallas TPU kernel: flash attention (online softmax) for prefill.

Classic blocked formulation adapted to TPU VMEM/MXU:

  * grid (batch*heads, q_blocks, k_blocks); the k axis is the sequential
    minor grid dimension, accumulating into VMEM scratch (acc, m, l);
  * (block_q x d) @ (d x block_k) runs on the MXU; the online-softmax
    rescale is VPU work on (block_q,) vectors;
  * causal and sliding-window masks are applied via position iota, so the
    same kernel serves full-causal prefill and the SWA long-context variant
    (DESIGN.md §5) — window=None means unbounded lookback.

Defaults (block 128 x 128, d<=128) keep the working set << VMEM:
q/k/v/acc blocks ~ 4 * 128*128*4B = 256 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *, scale: float,
                 causal: bool, window: int | None, sq: int, sk: int,
                 bq: int, bk: int, nk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32)                      # (bq, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = (pl.program_id(1) * bq
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq))
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk                                       # k padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG)

    m_prev, l_prev = m_s[...], l_s[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                            # kill _NEG rows
    alpha = jnp.exp(m_prev - m_new)
    l_s[...] = alpha * l_prev + p.sum(-1)
    m_s[...] = m_new
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _finalize():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K, interpret: bool = True
                    ) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) (GQA repeat done by the caller).

    The last q position is aligned with the last k position (decode-friendly).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    bq, bk = min(block_q, sq), min(block_k, sk)

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    q_pad, k_pad = -sq % bq, -sk % bk
    qf = jnp.pad(qf, ((0, 0), (0, q_pad), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, k_pad), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, k_pad), (0, 0)))
    nq, nk = qf.shape[1] // bq, kf.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, sq=sq, sk=sk, bq=bq, bk=bk, nk=nk),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, qf.shape[1], d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :sq].reshape(b, h, sq, d)
