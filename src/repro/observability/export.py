"""Span export: JSONL, Chrome trace-event JSON, critical-path summary.

``export_jsonl``/``read_jsonl`` round-trip the tracer's span dicts one
JSON object per line.  ``chrome_trace`` converts them to the Chrome
trace-event format (open in ``chrome://tracing`` or
https://ui.perfetto.dev): one complete ("X") event per span, with
process-name metadata events so the coordinator and each party show as
separate tracks.  ``critical_path`` attributes wall-clock to
comm / compute / host by *self time* (a span's duration minus its
children's), so nested spans never double count, and breaks the fit
down per level and per process.

``jax_profile(logdir)`` is the opt-in ``jax.profiler`` hook: a context
manager that starts a profiler trace when a directory is given and is a
no-op otherwise (jax is imported lazily so this module stays
stdlib-only on the disabled path).
"""
from __future__ import annotations

import contextlib
import json

__all__ = ["export_jsonl", "read_jsonl", "chrome_trace",
           "write_chrome_trace", "critical_path", "format_report",
           "jax_profile"]


def export_jsonl(spans, path):
    """Write span dicts to ``path``, one JSON object per line."""
    with open(path, "w", encoding="utf-8") as f:
        for s in spans:
            f.write(json.dumps(s, sort_keys=True) + "\n")
    return len(list(spans))


def read_jsonl(path):
    out = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def chrome_trace(spans) -> dict:
    """Chrome trace-event JSON object for ``chrome://tracing``/Perfetto."""
    procs: dict[str, int] = {}
    threads: dict[tuple, int] = {}
    events = []
    for s in spans:
        proc = str(s.get("proc", "?"))
        pid = procs.setdefault(proc, len(procs) + 1)
        tkey = (proc, str(s.get("thread", "main")))
        tid = threads.setdefault(tkey, len(threads) + 1)
        events.append({
            "name": s["name"], "cat": s.get("cat", "host"), "ph": "X",
            "pid": pid, "tid": tid,
            "ts": s.get("t0", 0.0) * 1e6,
            "dur": max(s.get("dur", 0.0), 0.0) * 1e6,
            "args": dict(s.get("attrs") or {},
                         sid=s.get("sid"), parent=s.get("parent")),
        })
    meta = []
    for proc, pid in procs.items():
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "args": {"name": proc}})
    for (proc, tname), tid in threads.items():
        meta.append({"name": "thread_name", "ph": "M",
                     "pid": procs[proc], "tid": tid,
                     "args": {"name": tname}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans, path):
    with open(path, "w", encoding="utf-8") as f:
        json.dump(chrome_trace(spans), f)


def _self_times(spans):
    """Per-span self time: duration minus the sum of direct children.

    Concurrent children (several parties inside one coordinator span)
    can sum past the parent's duration; self time clamps at zero.
    """
    child_sum: dict[str, float] = {}
    for s in spans:
        p = s.get("parent")
        if p is not None:
            child_sum[p] = child_sum.get(p, 0.0) + s.get("dur", 0.0)
    return {s["sid"]: max(0.0, s.get("dur", 0.0) - child_sum.get(s["sid"], 0.0))
            for s in spans}


def critical_path(spans) -> dict:
    """Attribute wall-clock to categories / processes / fit levels."""
    spans = list(spans)
    self_t = _self_times(spans)
    by_cat: dict[str, float] = {}
    by_proc: dict[str, float] = {}
    for s in spans:
        st = self_t.get(s["sid"], 0.0)
        by_cat[s.get("cat", "host")] = by_cat.get(s.get("cat", "host"), 0.0) + st
        proc = str(s.get("proc", "?"))
        by_proc[proc] = by_proc.get(proc, 0.0) + st

    # Per-level breakdown: spans tagged with a ``level`` attribute are
    # worker compute levels; comm time inside a level is the sum of its
    # comm descendants (direct children suffice: collectives open
    # directly under the level span).
    children: dict[str, list] = {}
    for s in spans:
        p = s.get("parent")
        if p is not None:
            children.setdefault(p, []).append(s)
    levels: dict[int, dict] = {}
    for s in spans:
        lvl = (s.get("attrs") or {}).get("level")
        if lvl is None:
            continue
        lv = levels.setdefault(int(lvl), {"compute_s": 0.0, "comm_s": 0.0,
                                          "spans": 0})
        comm = sum(c.get("dur", 0.0) for c in children.get(s["sid"], ())
                   if c.get("cat") == "comm")
        lv["comm_s"] += comm
        lv["compute_s"] += max(0.0, s.get("dur", 0.0) - comm)
        lv["spans"] += 1

    roots = [s for s in spans if s.get("parent") is None]
    wall = max((s.get("dur", 0.0) for s in roots), default=0.0)
    if spans and not wall:
        t0 = min(s.get("t0", 0.0) for s in spans)
        t1 = max(s.get("t0", 0.0) + s.get("dur", 0.0) for s in spans)
        wall = t1 - t0
    accounted = sum(by_cat.values())
    slowest = sorted(spans, key=lambda s: s.get("dur", 0.0), reverse=True)
    return {
        "n_spans": len(spans),
        "n_traces": len({s.get("tid") for s in spans}),
        "wall_s": wall,
        "by_category_s": dict(sorted(by_cat.items())),
        "by_process_s": dict(sorted(by_proc.items())),
        "levels": {k: levels[k] for k in sorted(levels)},
        "host_idle_s": max(0.0, wall - accounted),
        "slowest": [{"name": s["name"], "proc": str(s.get("proc", "?")),
                     "cat": s.get("cat", "host"),
                     "dur_s": s.get("dur", 0.0),
                     "attrs": dict(s.get("attrs") or {})}
                    for s in slowest[:10]],
    }


def format_report(spans, top: int = 10) -> str:
    """Human-readable critical-path summary for the ``repro-trace`` CLI."""
    cp = critical_path(spans)
    lines = []
    lines.append(f"spans: {cp['n_spans']}   traces: {cp['n_traces']}   "
                 f"wall: {cp['wall_s'] * 1e3:.1f} ms")
    lines.append("")
    lines.append("self-time by category (comm vs compute vs host):")
    for cat, t in cp["by_category_s"].items():
        pct = 100.0 * t / cp["wall_s"] if cp["wall_s"] else 0.0
        lines.append(f"  {cat:<10} {t * 1e3:10.1f} ms  {pct:5.1f}%")
    lines.append(f"  {'(idle)':<10} {cp['host_idle_s'] * 1e3:10.1f} ms")
    lines.append("")
    lines.append("self-time by process:")
    for proc, t in cp["by_process_s"].items():
        lines.append(f"  {proc:<14} {t * 1e3:10.1f} ms")
    if cp["levels"]:
        lines.append("")
        lines.append("per-level (summed across parties/trees):")
        lines.append(f"  {'level':>5}  {'compute ms':>10}  {'comm ms':>10}"
                     f"  {'spans':>5}")
        for lvl, d in cp["levels"].items():
            lines.append(f"  {lvl:>5}  {d['compute_s'] * 1e3:>10.1f}"
                         f"  {d['comm_s'] * 1e3:>10.1f}  {d['spans']:>5}")
    lines.append("")
    lines.append(f"slowest spans (top {min(top, len(cp['slowest']))}):")
    for s in cp["slowest"][:top]:
        attrs = " ".join(f"{k}={v}" for k, v in s["attrs"].items())
        lines.append(f"  {s['dur_s'] * 1e3:9.1f} ms  {s['proc']:<12} "
                     f"[{s['cat']}] {s['name']}" + (f"  {attrs}" if attrs else ""))
    return "\n".join(lines)


@contextlib.contextmanager
def jax_profile(logdir):
    """Opt-in ``jax.profiler`` trace around a block; no-op if logdir falsy."""
    if not logdir:
        yield
        return
    import jax
    with jax.profiler.trace(str(logdir)):
        yield
