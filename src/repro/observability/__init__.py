"""Federated observability: cross-process tracing + telemetry registry.

Three pieces, all stdlib-only on the hot path:

- :mod:`repro.observability.trace` — hierarchical spans whose context
  (``{"tid", "sid"}``) rides the transport frames, so one distributed
  fit is one connected trace across the coordinator and every party
  process.  No-op (and wire-byte-identical) when disabled; enable with
  ``REPRO_TRACE=1`` or ``TRACER.enable()``.
- :mod:`repro.observability.registry` — counters / gauges / bounded
  histograms with pooled quantiles; party snapshots roll up to the
  coordinator through the worker ``telemetry`` op.
- :mod:`repro.observability.export` — JSONL + Chrome-trace export and
  the critical-path report behind the ``repro-trace`` CLI, plus the
  opt-in ``jax.profiler`` hook.
"""
from repro.observability.registry import (Counter, Gauge, Histogram,
                                          Registry, REGISTRY)
from repro.observability.trace import TRACER, Tracer, current_context
from repro.observability.export import (chrome_trace, critical_path,
                                        export_jsonl, format_report,
                                        jax_profile, read_jsonl,
                                        write_chrome_trace)

__all__ = [
    "TRACER", "Tracer", "current_context",
    "REGISTRY", "Registry", "Counter", "Gauge", "Histogram",
    "export_jsonl", "read_jsonl", "chrome_trace", "write_chrome_trace",
    "critical_path", "format_report", "jax_profile",
]
