"""Hierarchical spans with explicit cross-process context propagation.

Span model
----------
A *span* is a named, timed interval with a trace id, a span id, and an
optional parent span id.  Spans nest through a thread-local stack: the
innermost open span on the current thread is the parent of the next one
opened.  A *trace* is the set of spans sharing one trace id — one
distributed fit yields one trace covering the coordinator's per-level
rounds, each party worker's op execution, retry/backoff sleeps, and
circuit-breaker flips.

Cross-process propagation is explicit: ``current_context()`` returns the
``{"tid", "sid"}`` pair of the innermost open span (or ``None``), the
transport attaches it to outgoing frames under the ``_trace`` key, and a
worker wraps message handling in ``TRACER.attach(ctx)`` so its spans
parent under the coordinator's span even though they live in another OS
process.  Span start times are wall-clock epoch seconds (comparable
across processes); durations come from ``perf_counter`` deltas.

Zero cost when disabled: ``span()`` returns a shared no-op singleton and
``current_context()`` returns ``None``, so no allocation happens, no
span ids are minted, and — critically — no ``_trace`` key is ever added
to wire messages (disabled-path traffic is byte-identical to
uninstrumented code).

Privacy: span names/attributes are metadata only.  Attribute values are
restricted to scalars (str/int/float/bool/None) and short tuples of
scalars; anything array-like raises ``TypeError``.  The static twin is
the egress linter: ``span``/``event``/``observe`` and the exporters are
registered wire-sensitive sinks in ``analysis/policy.py``, so a tainted
``.x``/``.ids``/``.y`` value reaching a span is a lint failure.

This module imports only the stdlib (no jax, no repro packages) so the
transport layer can depend on it.
"""
from __future__ import annotations

import collections
import itertools
import os
import threading
import time

__all__ = ["Tracer", "TRACER", "current_context"]

_MAX_SPANS = 65536
_MAX_ATTR_TUPLE = 32
_SCALARS = (str, int, float, bool, type(None))


def _check_attrs(attrs):
    """Validate that every attribute value is plain metadata.

    Raises TypeError on arrays / dicts / arbitrary objects so raw data
    cannot ride along a span even if the linter is bypassed at runtime.
    """
    for k, v in attrs.items():
        if isinstance(v, _SCALARS):
            continue
        if isinstance(v, (tuple, list)) and len(v) <= _MAX_ATTR_TUPLE and all(
                isinstance(e, _SCALARS) for e in v):
            attrs[k] = tuple(v)
            continue
        raise TypeError(
            f"span attribute {k!r} must be a scalar or short tuple of "
            f"scalars, got {type(v).__name__} (metadata-only payloads)")
    return attrs


class _NoopSpan:
    """Shared do-nothing span handle returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP = _NoopSpan()


class _SpanHandle:
    """An open span; context manager that records itself on exit."""

    __slots__ = ("_tracer", "name", "category", "tid", "sid", "parent",
                 "t0", "_pc0", "attrs", "_thread")

    def __init__(self, tracer, name, category, tid, sid, parent, attrs):
        self._tracer = tracer
        self.name = name
        self.category = category
        self.tid = tid
        self.sid = sid
        self.parent = parent
        self.attrs = attrs
        self.t0 = time.time()
        self._pc0 = time.perf_counter()
        self._thread = threading.current_thread().name

    def set(self, **attrs):
        self.attrs.update(_check_attrs(attrs))
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tracer._finish(self)
        return False


class Tracer:
    """Process-local span recorder with a bounded buffer.

    Enabled via the ``REPRO_TRACE=1`` environment variable or
    ``enable()``.  Even when disabled, ``attach(ctx)`` with a non-None
    remote context arms recording on that thread — a worker process that
    never saw the env var still records spans for traced coordinator
    messages.
    """

    def __init__(self, enabled: bool | None = None, process: str | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_TRACE", "") == "1"
        self._enabled = bool(enabled)
        self.process = process if process is not None else f"pid{os.getpid()}"
        self._ids = itertools.count(1)
        self._buf = collections.deque(maxlen=_MAX_SPANS)
        self._local = threading.local()

    # ------------------------------------------------------------ state
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self):
        self._enabled = True

    def disable(self):
        self._enabled = False

    def reset(self):
        """Drop buffered spans and this thread's context (for tests)."""
        self._buf.clear()
        self._local.stack = []
        self._local.remote = 0

    def _stack(self):
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _active(self) -> bool:
        return self._enabled or getattr(self._local, "remote", 0) > 0

    def _next_sid(self) -> str:
        return f"{self.process}/{next(self._ids)}"

    # ---------------------------------------------------------- context
    def current_context(self):
        """``{"tid", "sid"}`` of the innermost open span, or ``None``."""
        st = getattr(self._local, "stack", None)
        if not st:
            return None
        tid, sid = st[-1]
        return {"tid": tid, "sid": sid}

    def attach(self, ctx):
        """Context manager parenting this thread's spans under a remote
        context dict (``{"tid", "sid"}``).  ``ctx=None`` is a no-op."""
        return _Attach(self, ctx)

    # ------------------------------------------------------------ spans
    def span(self, name: str, category: str = "host", **attrs):
        """Open a span as a context manager; no-op singleton when off."""
        if not self._active():
            return _NOOP
        return self._begin(name, category, attrs)

    def begin(self, name: str, category: str = "host", **attrs):
        """Manually open a span (pair with ``finish``); None when off.

        For spans whose open/close straddle function boundaries, e.g. a
        serving wave opened at dispatch and closed at collect.
        """
        if not self._active():
            return None
        return self._begin(name, category, attrs)

    def finish(self, handle):
        if handle is not None and handle is not _NOOP:
            self._finish(handle)

    def event(self, name: str, category: str = "host", **attrs):
        """Record a zero-duration instant span."""
        if not self._active():
            return
        h = self._begin(name, category, attrs)
        self._finish(h)

    def _begin(self, name, category, attrs):
        st = self._stack()
        if st:
            tid, parent = st[-1]
        else:
            tid, parent = f"t{self._next_sid()}", None
        sid = self._next_sid()
        h = _SpanHandle(self, name, category, tid, sid, parent,
                        _check_attrs(attrs))
        st.append((tid, sid))
        return h

    def _finish(self, h):
        dur = time.perf_counter() - h._pc0
        st = self._stack()
        # Pop back to (and including) this span; tolerates overlapping
        # manual begin/finish by searching instead of asserting order.
        for i in range(len(st) - 1, -1, -1):
            if st[i][1] == h.sid:
                del st[i:]
                break
        self._buf.append({
            "name": h.name, "cat": h.category, "tid": h.tid, "sid": h.sid,
            "parent": h.parent, "t0": h.t0, "dur": dur,
            "proc": self.process, "thread": h._thread,
            "attrs": dict(h.attrs),
        })

    # ----------------------------------------------------------- export
    def adopt(self, span_dict: dict):
        """Append a span recorded by another process (telemetry rollup)."""
        if isinstance(span_dict, dict) and "name" in span_dict:
            self._buf.append(dict(span_dict))

    def spans(self) -> list[dict]:
        """Snapshot of buffered spans (oldest first), without clearing."""
        return list(self._buf)

    def drain(self) -> list[dict]:
        """Pop and return all buffered spans (oldest first)."""
        out = []
        while True:
            try:
                out.append(self._buf.popleft())
            except IndexError:
                return out


class _Attach:
    __slots__ = ("_tracer", "_ctx", "_pushed")

    def __init__(self, tracer, ctx):
        self._tracer = tracer
        self._ctx = ctx
        self._pushed = False

    def __enter__(self):
        ctx = self._ctx
        if ctx and "tid" in ctx and "sid" in ctx:
            self._tracer._stack().append((str(ctx["tid"]), str(ctx["sid"])))
            self._tracer._local.remote = getattr(
                self._tracer._local, "remote", 0) + 1
            self._pushed = True
        return self

    def __exit__(self, *exc):
        if self._pushed:
            st = self._tracer._stack()
            if st:
                st.pop()
            self._tracer._local.remote = max(
                0, getattr(self._tracer._local, "remote", 1) - 1)
        return False


#: Process-wide tracer.  Workers re-tag ``TRACER.process`` on startup.
TRACER = Tracer()


def current_context():
    """Module-level convenience for the transport layer."""
    return TRACER.current_context()
