"""Process-local telemetry registry: counters, gauges, bounded histograms.

One home for the numbers previously scattered across the system
(`wave_stats` summaries, `FleetMetrics` counters, transport retry
sleeps, queue depths, autotune epochs, streaming chunk/sketch stats).
Metrics are named with dotted paths (``serving.wave_latency_s``); the
worker→coordinator telemetry rollup ships each party's ``snapshot()``
(plain numbers and bounded float sample lists — never arrays of data)
and the coordinator ``merge()``s them under a ``party<i>.`` prefix, so
quantiles can be pooled across parties without new wire types.

Thread-safe (one registry-wide lock; update paths are a few dict/list
ops) and import-light: stdlib only, so the transport layer can use it.
"""
from __future__ import annotations

import collections
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
           "quantile"]

_DEFAULT_SAMPLES = 2048


class Counter:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name, lock):
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n=1):
        with self._lock:
            self.value += n

    def snapshot(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name, lock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v):
        with self._lock:
            self.value = float(v)

    def snapshot(self):
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Counts/total plus a bounded reservoir of recent observations.

    The reservoir (a maxlen deque) is what makes quantiles *poolable*:
    snapshots carry the samples, and merged registries re-observe them,
    so cross-party percentiles are computed over the union rather than
    averaging per-party percentiles (which is not a percentile).
    """

    __slots__ = ("name", "count", "total", "max", "_samples", "_lock")

    def __init__(self, name, lock, max_samples=_DEFAULT_SAMPLES):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self._samples = collections.deque(maxlen=max_samples)
        self._lock = lock

    def observe(self, v):
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v > self.max:
                self.max = v
            self._samples.append(v)

    def quantile(self, q):
        with self._lock:
            samples = sorted(self._samples)
        return quantile(samples, q)

    def snapshot(self):
        with self._lock:
            return {"type": "histogram", "count": self.count,
                    "total": self.total, "max": self.max,
                    "samples": list(self._samples)}


def quantile(sorted_samples, q):
    """Nearest-rank quantile of an already-sorted list (None if empty)."""
    if not sorted_samples:
        return None
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q must be in [0, 1], got {q}")
    idx = min(len(sorted_samples) - 1,
              max(0, math.ceil(q * len(sorted_samples)) - 1))
    return sorted_samples[idx]


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, self._lock, **kw)
        if not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {type(m).__name__}")
        return m

    def counter(self, name) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name, max_samples=_DEFAULT_SAMPLES) -> Histogram:
        return self._get(name, Histogram, max_samples=max_samples)

    def get(self, name):
        with self._lock:
            return self._metrics.get(name)

    def names(self):
        with self._lock:
            return sorted(self._metrics)

    def clear(self):
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> dict:
        """``{name: metric-snapshot-dict}`` — plain numbers only."""
        with self._lock:
            metrics = dict(self._metrics)
        return {name: m.snapshot() for name, m in sorted(metrics.items())}

    def merge(self, snap: dict, prefix: str = ""):
        """Fold a remote ``snapshot()`` into this registry under a prefix.

        Counters add, gauges overwrite, histogram samples re-observe (so
        pooled quantiles see the union of party reservoirs).
        """
        for name, s in (snap or {}).items():
            if not isinstance(s, dict):
                continue
            kind = s.get("type")
            full = prefix + name
            if kind == "counter":
                self.counter(full).inc(s.get("value", 0))
            elif kind == "gauge":
                self.gauge(full).set(s.get("value", 0.0))
            elif kind == "histogram":
                h = self.histogram(full)
                for v in s.get("samples") or ():
                    h.observe(v)
                # count/total reflect all observations, not just the
                # bounded reservoir the snapshot could carry
                extra = s.get("count", 0) - len(s.get("samples") or ())
                if extra > 0:
                    with h._lock:
                        h.count += extra
                        sample_total = sum(s.get("samples") or ())
                        h.total += s.get("total", sample_total) - sample_total


#: Process-wide registry.
REGISTRY = Registry()
