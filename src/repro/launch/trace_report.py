"""repro-trace: critical-path summary of an exported span file.

Usage::

    repro-trace TRACE.jsonl [--top N] [--chrome OUT.json]

Reads spans exported by ``repro.observability.export.export_jsonl``
(e.g. from ``repro.launch.distributed_demo --trace-out DIR``), prints
the comm / compute / host-idle breakdown, per-process totals, per-level
fit costs, and the slowest-span table.  ``--chrome`` additionally
writes a Chrome trace-event file for ``chrome://tracing`` / Perfetto.

Exits 1 if the span file is missing, unreadable, or empty, so CI can
gate on a trace actually being produced.
"""
from __future__ import annotations

import argparse
import sys

from repro.observability.export import (format_report, read_jsonl,
                                        write_chrome_trace)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-trace",
        description="Critical-path summary of an exported trace (JSONL spans)")
    ap.add_argument("spans", help="span file written by export_jsonl")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the slowest-span table (default 10)")
    ap.add_argument("--chrome", default=None, metavar="OUT.json",
                    help="also write a Chrome trace-event file")
    args = ap.parse_args(argv)

    try:
        spans = read_jsonl(args.spans)
    except OSError as e:
        print(f"repro-trace: cannot read {args.spans}: {e}", file=sys.stderr)
        return 1
    except ValueError as e:
        print(f"repro-trace: invalid span file {args.spans}: {e}",
              file=sys.stderr)
        return 1
    if not spans:
        print(f"repro-trace: no spans in {args.spans}", file=sys.stderr)
        return 1

    print(format_report(spans, top=args.top))
    if args.chrome:
        write_chrome_trace(spans, args.chrome)
        print(f"\nchrome trace written to {args.chrome} "
              "(open in chrome://tracing or https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
