"""Serving driver for the federated forest: batched one-round prediction.

One Federation session owns the whole lifecycle: ingest -> fit ->
(checkpoint round-trip) -> serve.  The server comes out of ``fed.serve``
pre-bound to the session's substrate; traffic goes through the RequestQueue
— the forest counterpart of launch/serve.py's transformer decode driver.
Reports per-wave latency, aggregate rows/s, psum payload bytes, and the
compile count (which must stop growing after warmup: the
bucket/pad/compile-once contract).

Training data arrives either as a synthetic pre-aligned matrix (default) or
party-first: per-party CSV extracts (``--party-csv name=path``, repeated)
aligned on hashed IDs at ingest.  On the party-first path, traffic is also
party-first: each request round submits per-party blocks with shuffled rows
and party-local superset rows, re-aligned by the queue before dispatch.

Examples:
  PYTHONPATH=src python -m repro.launch.serve_forest --parties 4 --depth 8
  PYTHONPATH=src python -m repro.launch.serve_forest --dense   # no LeafTable
  PYTHONPATH=src python -m repro.launch.serve_forest --async-waves 4 \
      --autotune   # async wave ring + traffic-autotuned buckets
  PYTHONPATH=src python -m repro.launch.serve_forest --ckpt-dir /tmp/ff \
      --save-ckpt   # round-trip through fed.save / fed.load first
  PYTHONPATH=src python -m repro.launch.serve_forest \
      --party-csv bank=/data/bank.csv --party-csv ecom=/data/ecom.csv
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import ForestParams, PartyBlock
from repro.data import make_classification
from repro.federation import Federation
from repro.serving import RequestQueue, ServeConfig


def party_request(part, x_rows: np.ndarray, ids: np.ndarray,
                  rng: np.random.Generator) -> list[PartyBlock]:
    """Shape dense rows into per-party request blocks the way real traffic
    arrives: each party's rows independently shuffled, plus a few rows only
    that party holds (dropped at alignment)."""
    blocks = []
    for i, name in enumerate(part.party_names):
        gid = part.feat_gid[i][part.feat_gid[i] >= 0]
        order = rng.permutation(len(ids))
        extra = rng.normal(size=(int(rng.integers(1, 4)), len(gid)))
        blocks.append(PartyBlock(
            name=name, x=np.concatenate([x_rows[order][:, gid], extra]),
            ids=np.concatenate([ids[order],
                                [f"{name}-x{j}" for j in range(len(extra))]])))
    return blocks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--trees", type=int, default=8)
    ap.add_argument("--depth", type=int, default=8)
    ap.add_argument("--train-rows", type=int, default=2000)
    ap.add_argument("--features", type=int, default=24)
    ap.add_argument("--buckets", default="32,256,2048")
    ap.add_argument("--requests", type=int, default=12,
                    help="random requests per traffic round")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--dense", action="store_true",
                    help="disable leaf compaction (baseline mask)")
    ap.add_argument("--async-waves", type=int, default=1, metavar="K",
                    help="in-flight wave ring depth (1 = synchronous; >1 "
                         "overlaps host binning/padding with device "
                         "execution)")
    ap.add_argument("--autotune", action="store_true",
                    help="after the first traffic round, retune the bucket "
                         "set from the observed request-size distribution")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore the PartyTree stack from this checkpoint "
                         "directory instead of using the in-memory fit")
    ap.add_argument("--save-ckpt", action="store_true",
                    help="save the fitted forest to --ckpt-dir first")
    ap.add_argument("--party-csv", action="append", default=None,
                    metavar="NAME=PATH",
                    help="per-party CSV extract (repeat once per party): "
                         "party-first ingest + party-block request traffic")
    ap.add_argument("--id-column", default="id")
    ap.add_argument("--label-column", default="label")
    args = ap.parse_args()
    buckets = tuple(int(b) for b in args.buckets.split(","))

    p = ForestParams(n_estimators=args.trees, max_depth=args.depth,
                     n_bins=16, seed=0)
    fed: Federation
    if args.party_csv:
        from repro.launch.train import parse_party_csvs
        sources = parse_party_csvs(args.party_csv, args.id_column,
                                   args.label_column)
        fed = Federation(parties=len(sources), n_bins=p.n_bins)
        part = fed.ingest(sources)
        x = part.dense_raw()
        print(f"aligned {part.n_samples} common samples across "
              f"{part.n_parties} parties {list(part.party_names)}")
    else:
        x, y = make_classification(args.train_rows, args.features, 2, seed=0)
        fed = Federation(parties=args.parties, n_bins=p.n_bins)
        part = fed.ingest(x, y)
    t0 = time.time()
    model = fed.fit(p)
    print(f"fit: {args.trees} trees x depth {args.depth} over "
          f"{part.n_parties} parties in {time.time() - t0:.1f}s")

    if args.ckpt_dir and args.save_ckpt:
        fed.save(model, args.ckpt_dir, step=args.trees)
    if args.ckpt_dir:
        model = fed.load(args.ckpt_dir, p)
        print(f"restored PartyTree stack from {args.ckpt_dir}")

    server = fed.serve(model, ServeConfig(buckets=buckets,
                                          compact=not args.dense,
                                          max_inflight=args.async_waves))
    if server.leaf_table is not None:
        from repro.serving.plan import compaction_ratio
        print(f"leaf table: {server.leaf_table.capacity} slots vs "
              f"{p.n_nodes} heap nodes "
              f"({compaction_ratio(server.leaf_table, p):.1f}x compaction)")

    t0 = time.time()
    server.warmup()
    print(f"warmup: compiled {server.compile_count} bucket executables "
          f"{buckets} in {time.time() - t0:.1f}s")

    rng = np.random.default_rng(1)
    queue = RequestQueue(server)
    for rnd in range(args.rounds):
        sizes = rng.integers(1, buckets[-1] // 2, size=args.requests)
        for k, s in enumerate(sizes):
            rows = x[rng.integers(0, len(x), size=s)]
            if args.party_csv:      # party-first traffic: per-party blocks,
                queue.submit_parties(party_request(   # re-aligned in-queue
                    part, rows, np.array([f"r{rnd}-{k}-{j}"
                                          for j in range(s)]), rng))
            else:
                queue.submit(rows)
        t0 = time.time()
        results = queue.drain()
        dt = time.time() - t0
        rows = int(sizes.sum())
        print(f"round {rnd}: {len(results)} requests / {rows} rows in "
              f"{dt:.3f}s ({rows / max(dt, 1e-9):.0f} rows/s, "
              f"inflight<={server.max_inflight})")
        if args.autotune and rnd == 0:
            server = fed.serve(model, ServeConfig(
                buckets=buckets, compact=not args.dense,
                max_inflight=args.async_waves, autotune_buckets=True),
                traffic=queue.request_stats)
            server.warmup()
            queue = RequestQueue(server)
            print(f"autotune: buckets {buckets} -> {server.buckets} "
                  f"(compiles now {server.compile_count})")
    s = server.stats_summary()
    if s["waves"]:
        print(f"summary: waves={s['waves']} p50={s['p50_ms']:.2f}ms "
              f"p95={s['p95_ms']:.2f}ms rows/s={s['rows_per_s']:.0f} "
              f"psum_bytes_total={s['comm_bytes_total']} "
              f"compiles={s['compile_count']}")
    else:   # --autotune --rounds 1: the retuned server saw no traffic yet
        print(f"summary: no waves served since the bucket retune "
              f"(compiles={server.compile_count})")
    # the compile-once contract, per autotune epoch: compile_count must not
    # have grown past the last warmup's bucket set
    assert server.compile_count == len(server.buckets), \
        "recompiled after warmup!"


if __name__ == "__main__":
    main()
