"""Serving driver for the federated forest: batched one-round prediction.

One Federation session owns the whole lifecycle: ingest -> fit ->
(checkpoint round-trip) -> serve.  The server comes out of ``fed.serve``
pre-bound to the session's substrate; traffic goes through the RequestQueue
— the forest counterpart of launch/serve.py's transformer decode driver.
Reports per-wave latency, aggregate rows/s, psum payload bytes, and the
compile count (which must stop growing after warmup: the
bucket/pad/compile-once contract).

Examples:
  PYTHONPATH=src python -m repro.launch.serve_forest --parties 4 --depth 8
  PYTHONPATH=src python -m repro.launch.serve_forest --dense   # no LeafTable
  PYTHONPATH=src python -m repro.launch.serve_forest --async-waves 4 \
      --autotune   # async wave ring + traffic-autotuned buckets
  PYTHONPATH=src python -m repro.launch.serve_forest --ckpt-dir /tmp/ff \
      --save-ckpt   # round-trip through fed.save / fed.load first
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import ForestParams
from repro.data import make_classification
from repro.federation import Federation
from repro.serving import RequestQueue


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--trees", type=int, default=8)
    ap.add_argument("--depth", type=int, default=8)
    ap.add_argument("--train-rows", type=int, default=2000)
    ap.add_argument("--features", type=int, default=24)
    ap.add_argument("--buckets", default="32,256,2048")
    ap.add_argument("--requests", type=int, default=12,
                    help="random requests per traffic round")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--dense", action="store_true",
                    help="disable leaf compaction (baseline mask)")
    ap.add_argument("--async-waves", type=int, default=1, metavar="K",
                    help="in-flight wave ring depth (1 = synchronous; >1 "
                         "overlaps host binning/padding with device "
                         "execution)")
    ap.add_argument("--autotune", action="store_true",
                    help="after the first traffic round, retune the bucket "
                         "set from the observed request-size distribution")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore the PartyTree stack from this checkpoint "
                         "directory instead of using the in-memory fit")
    ap.add_argument("--save-ckpt", action="store_true",
                    help="save the fitted forest to --ckpt-dir first")
    args = ap.parse_args()
    buckets = tuple(int(b) for b in args.buckets.split(","))

    p = ForestParams(n_estimators=args.trees, max_depth=args.depth,
                     n_bins=16, seed=0)
    x, y = make_classification(args.train_rows, args.features, 2, seed=0)

    fed = Federation(parties=args.parties, n_bins=p.n_bins)
    fed.ingest(x, y)
    t0 = time.time()
    model = fed.fit(p)
    print(f"fit: {args.trees} trees x depth {args.depth} over "
          f"{args.parties} parties in {time.time() - t0:.1f}s")

    if args.ckpt_dir and args.save_ckpt:
        fed.save(model, args.ckpt_dir, step=args.trees)
    if args.ckpt_dir:
        model = fed.load(args.ckpt_dir, p)
        print(f"restored PartyTree stack from {args.ckpt_dir}")

    server = fed.serve(model, compact=not args.dense, buckets=buckets,
                       max_inflight=args.async_waves)
    if server.leaf_table is not None:
        from repro.serving.plan import compaction_ratio
        print(f"leaf table: {server.leaf_table.capacity} slots vs "
              f"{p.n_nodes} heap nodes "
              f"({compaction_ratio(server.leaf_table, p):.1f}x compaction)")

    t0 = time.time()
    server.warmup()
    print(f"warmup: compiled {server.compile_count} bucket executables "
          f"{buckets} in {time.time() - t0:.1f}s")

    rng = np.random.default_rng(1)
    queue = RequestQueue(server)
    for rnd in range(args.rounds):
        sizes = rng.integers(1, buckets[-1] // 2, size=args.requests)
        for s in sizes:
            queue.submit(x[rng.integers(0, len(x), size=s)])
        t0 = time.time()
        results = queue.drain()
        dt = time.time() - t0
        rows = int(sizes.sum())
        print(f"round {rnd}: {len(results)} requests / {rows} rows in "
              f"{dt:.3f}s ({rows / max(dt, 1e-9):.0f} rows/s, "
              f"inflight<={server.max_inflight})")
        if args.autotune and rnd == 0:
            server = fed.serve(model, compact=not args.dense,
                               buckets=buckets, autotune_buckets=True,
                               max_inflight=args.async_waves,
                               traffic=queue.request_stats)
            server.warmup()
            queue = RequestQueue(server)
            print(f"autotune: buckets {buckets} -> {server.buckets} "
                  f"(compiles now {server.compile_count})")
    s = server.stats_summary()
    if s:
        print(f"summary: waves={s['waves']} p50={s['p50_ms']:.2f}ms "
              f"p95={s['p95_ms']:.2f}ms rows/s={s['rows_per_s']:.0f} "
              f"psum_bytes_total={s['comm_bytes_total']} "
              f"compiles={s['compile_count']}")
    else:   # --autotune --rounds 1: the retuned server saw no traffic yet
        print(f"summary: no waves served since the bucket retune "
              f"(compiles={server.compile_count})")
    # the compile-once contract, per autotune epoch: compile_count must not
    # have grown past the last warmup's bucket set
    assert server.compile_count == len(server.buckets), \
        "recompiled after warmup!"


if __name__ == "__main__":
    main()
