"""Training driver: end-to-end training on the local mesh.

Two arms share one CLI:
  * transformer archs (default): reduced-config LM training
    (examples/train_transformer.py drives it for the ~100M-param example);
  * ``--arch federated-forest``: tabular federated training through the
    Federation session API (ingest -> fit -> one-round predict), with an
    optional ``--ckpt-dir`` break-point-recoverable fit (paper §4.1).

Production launch is the same code against make_production_mesh().
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ArchConfig, reduced
from repro.data.lm import synthetic_lm_batches
from repro.models import transformer
from repro.train import optim
from repro.train.step import make_train_step


def train_loop(cfg: ArchConfig, *, steps: int, batch: int, seq: int,
               lr: float = 1e-3, micro_batch: int = 0, seed: int = 0,
               log_every: int = 10):
    params = transformer.init_params(jax.random.key(seed), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M")
    opt = optim.adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, micro_batch=micro_batch, lr=lr))

    losses = []
    t0 = time.time()
    for i, b in enumerate(synthetic_lm_batches(cfg, batch, seq, seed=seed)):
        if i >= steps:
            break
        params, opt, metrics = step_fn(params, opt, b)
        if i % log_every == 0 or i == steps - 1:
            ce = float(metrics["ce"])
            losses.append(ce)
            tok_s = batch * seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d}  ce={ce:.4f}  tok/s={tok_s:,.0f}")
    return params, losses


def parse_party_csvs(specs, id_column: str, label_column: str) -> list:
    """``NAME=PATH`` (or bare PATH) CLI specs -> CSVSource list.

    Split at the FIRST ``=`` — party names cannot contain one, but paths
    can (``bank=/data/run=3/bank.csv``).  A spec whose pre-``=`` part
    contains a path separator is a bare path (``/data/run=3/bank.csv``);
    a bare *relative* path with ``=`` before any separator needs an
    explicit ``NAME=``."""
    import os as _os
    from repro.core.partyblock import CSVSource
    sources = []
    for spec in specs:
        name, sep, path = spec.partition("=")
        if not sep or "/" in name or _os.sep in name:
            name, path = None, spec
        sources.append(CSVSource(path, name=name or None,
                                 id_column=id_column,
                                 label_column=label_column))
    return sources


def forest_train(args) -> None:
    """Federated-forest training through the Federation session API.

    Two ingest shapes: synthetic raw-matrix data (default), or party-first
    per-party CSV extracts (``--party-csv name=path``, repeated) — rows
    keyed by ``--id-column``, aligned on hashed IDs, labels taken from
    whichever party's CSV carries ``--label-column``."""
    from repro.core import ForestParams
    from repro.data import make_classification
    from repro.data.metrics import accuracy
    from repro.data.tabular import train_test_split
    from repro.federation import Federation

    p = ForestParams(n_estimators=args.trees, max_depth=args.depth,
                     n_bins=16, seed=args.seed)
    if args.party_csv:
        sources = parse_party_csvs(args.party_csv, args.id_column,
                                   args.label_column)
        fed = Federation(parties=len(sources), n_bins=p.n_bins)
        part = fed.ingest(sources)
        print(f"aligned {part.n_samples} common samples across "
              f"{part.n_parties} parties {list(part.party_names)}")
        t0 = time.time()
        model = fed.fit_resumable(p, args.ckpt_dir) if args.ckpt_dir \
            else fed.fit(p)
        t_fit = time.time() - t0
        acc = accuracy(fed.labels_, fed.predict(model, part.dense_raw()))
        print(f"federated-forest: {args.trees} trees x depth {args.depth} "
              f"over {part.n_parties} parties in {t_fit:.1f}s  "
              f"train-acc={acc:.3f}")
        return
    x, y = make_classification(args.rows, args.features, 2,
                               n_informative=max(4, args.features // 3),
                               seed=args.seed)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=args.seed)

    fed = Federation(parties=args.parties, n_bins=p.n_bins)
    fed.ingest(xtr, ytr)
    t0 = time.time()
    if args.ckpt_dir:
        model = fed.fit_resumable(p, args.ckpt_dir)
    else:
        model = fed.fit(p)
    t_fit = time.time() - t0
    acc = accuracy(yte, fed.predict(model, xte))
    print(f"federated-forest: {args.trees} trees x depth {args.depth} over "
          f"{args.parties} parties in {t_fit:.1f}s  acc={acc:.3f}")
    assert acc > 0.5, "federated fit degenerated"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    # federated-forest arm
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--trees", type=int, default=8)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--rows", type=int, default=2000)
    ap.add_argument("--features", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="forest arm: break-point-recoverable fit directory")
    ap.add_argument("--party-csv", action="append", default=None,
                    metavar="NAME=PATH",
                    help="forest arm: per-party CSV extract (repeat once "
                         "per party); rows are aligned on hashed "
                         "--id-column values, the one CSV carrying "
                         "--label-column holds the labels")
    ap.add_argument("--id-column", default="id")
    ap.add_argument("--label-column", default="label")
    args = ap.parse_args()
    if args.arch == "federated-forest":
        forest_train(args)
        return
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    _, losses = train_loop(cfg, steps=args.steps, batch=args.batch,
                           seq=args.seq, lr=args.lr)
    assert losses[-1] < losses[0], "training diverged"
    print(f"done: ce {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
