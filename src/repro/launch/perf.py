import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ must precede jax init, same contract as dryrun.py

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import roofline as rl                  # noqa: E402
from repro.launch import cases, mesh as mesh_mod  # noqa: E402

"""§Perf hillclimb driver: lower named variants of a case and report the
delta on the three roofline terms vs the recorded baseline.

Variants are explicit named experiments (hypothesis encoded in code), so the
EXPERIMENTS.md log can cite exactly what changed:

  qwen3-32b × train_4k        mb32 | probs_bf16 | remat_dots | combos
  qwen2-moe-a2.7b × prefill   moe_shard | moe_shard+probs_bf16
  federated-forest × ff_predict  mask_u8
"""

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "perf"

# variant name -> (cfg overrides, extra kwargs)
NN_VARIANTS: dict[str, dict] = {
    "baseline":      dict(),
    "mb2":           dict(micro_batch=2),
    "mb4":           dict(micro_batch=4),
    "mb16":          dict(micro_batch=16),
    "mb32":          dict(micro_batch=32),
    "probs_bf16":    dict(overrides={"attn_probs_bf16": True}),
    "remat_dots":    dict(overrides={"remat": "dots"}),
    "remat_none":    dict(overrides={"remat": "none"}),
    "moe_shard":     dict(overrides={"moe_shard_acts": True}),
    "mb32+probs":    dict(micro_batch=32, overrides={"attn_probs_bf16": True}),
    "mb32+probs+dots": dict(micro_batch=32,
                            overrides={"attn_probs_bf16": True,
                                       "remat": "dots"}),
    "moe_shard+probs": dict(overrides={"moe_shard_acts": True,
                                       "attn_probs_bf16": True}),
    "scores_bf16":     dict(overrides={"attn_scores_bf16": True}),
    "remat_attn_out":  dict(overrides={"remat": "attn_out"}),
    "scores+attn_out": dict(overrides={"attn_scores_bf16": True,
                                       "remat": "attn_out"}),
    "moe_shard+scores": dict(overrides={"moe_shard_acts": True,
                                        "attn_scores_bf16": True}),
    "fsdp_layout":     dict(serve_layout=False),   # serving baseline layout
    "serve_layout":    dict(serve_layout=True),    # tensor-parallel weights
    "expert_data":     dict(expert_data=True),     # experts over data axis
    "pad_experts":     dict(overrides={"pad_experts": True}),  # E->64, model-EP
    "pad_experts+data": dict(overrides={"pad_experts": True}, expert_data=True),
}


def run_nn_variant(arch: str, shape: str, variant: str, force=False) -> dict:
    out = OUT_DIR / f"{arch}__{shape}__{variant}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    kw = NN_VARIANTS[variant]
    mesh = mesh_mod.make_production_mesh()
    t0 = time.time()
    case = cases.input_specs(arch, shape, mesh,
                             overrides=kw.get("overrides"),
                             micro_batch=kw.get("micro_batch"),
                             serve_layout=kw.get("serve_layout"),
                             expert_data=kw.get("expert_data", False))
    compiled = case.lower(mesh).compile()
    r = rl.analyze(compiled)
    sh = cases.SHAPES[shape]
    mf = rl.model_flops(case.cfg, sh.kind, sh.batch, sh.seq)
    rec = {"arch": arch, "shape": shape, "variant": variant,
           "wall_s": round(time.time() - t0, 1),
           "roofline": r.summary(model_flops_global=mf, n_chips=256),
           "collectives": r.coll_detail}
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2, default=float))
    return rec


# ff_train variant name -> (histogram backend, subtraction trick).  The
# registry key goes through the Federation session (cases.forest_case builds
# its programs from a sharded-substrate session), so any backend registered
# in kernels.ops (including the GPU segment_sum one) is exercisable from the
# dry-run hillclimb without touching the builder.
FF_TRAIN_VARIANTS: dict[str, dict] = {
    "baseline":          dict(hist_impl="ref"),      # einsum (MXU fidelity)
    "hist_sub":          dict(hist_impl="ref", hist_subtraction=True),
    "scatter":           dict(hist_impl="scatter"),
    "segment_sum":       dict(hist_impl="segment_sum"),
    "pallas_interpret":  dict(hist_impl="pallas_interpret"),
    "hist_sub+scatter":  dict(hist_impl="scatter", hist_subtraction=True),
    "hist_sub+segment_sum": dict(hist_impl="segment_sum",
                                 hist_subtraction=True),
}


def run_ff_train_variant(variant: str, force=False) -> dict:
    """ff_train variants: einsum (MXU-fidelity) histogram baseline vs the
    beyond-paper histogram-subtraction trick, across histogram backends."""
    from repro.core.types import ForestParams
    out = OUT_DIR / f"federated-forest__ff_train__{variant}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    kw = FF_TRAIN_VARIANTS[variant]
    fs = cases.FOREST_SHAPES["ff_train"]
    p = ForestParams(task="classification", n_classes=2,
                     n_estimators=fs.n_trees_per_shard, max_depth=8,
                     n_bins=32,
                     hist_subtraction=kw.get("hist_subtraction", False))
    mesh = mesh_mod.make_forest_mesh()
    fn, args, _ = cases.forest_case("ff_train", mesh, params=p,
                                    hist_impl=kw["hist_impl"])
    t0 = time.time()
    compiled = jax.jit(fn).lower(*args).compile()
    r = rl.analyze(compiled)
    rec = {"arch": "federated-forest", "shape": "ff_train",
           "variant": variant, "wall_s": round(time.time() - t0, 1),
           "roofline": r.summary(), "collectives": r.coll_detail}
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def run_ff_variant(variant: str, force=False) -> dict:
    """federated-forest × ff_predict: int32 vs uint8 membership psum.

    Every variant is the Federation session's predict program (the exact
    closure ForestServer compiles) with the knobs turned."""
    out = OUT_DIR / f"federated-forest__ff_predict__{variant}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    mask_dtype = {"baseline": jnp.int32, "mask_u8": jnp.uint8,
                  "mask_u8+argmax": jnp.uint8,
                  "mask_u8+compact": jnp.uint8}[variant]
    vote_impl = "argmax" if variant.endswith("argmax") else "einsum"
    compact = variant.endswith("compact")
    mesh = mesh_mod.make_forest_mesh()
    fn, args, p = cases.forest_case("ff_predict", mesh, compact=compact,
                                    mask_dtype=mask_dtype,
                                    vote_impl=vote_impl)
    t0 = time.time()
    compiled = jax.jit(fn).lower(*args).compile()
    r = rl.analyze(compiled)
    rec = {"arch": "federated-forest", "shape": "ff_predict",
           "variant": variant, "wall_s": round(time.time() - t0, 1),
           "roofline": r.summary(), "collectives": r.coll_detail}
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def _report(rec: dict) -> None:
    ro = rec["roofline"]
    print(f"{rec['arch']} × {rec['shape']} × {rec['variant']}: "
          f"t=({ro['t_compute_s']:.3e}, {ro['t_memory_s']:.3e}, "
          f"{ro['t_collective_s']:.3e})s bound={ro['bottleneck']} "
          f"mem={ro['mem_per_dev_gib']:.2f}GiB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", required=True,
                    help="arch:shape (or federated-forest:ff_predict)")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    arch, shape = args.case.split(":")
    if arch == "federated-forest" and shape == "ff_train":
        rec = run_ff_train_variant(args.variant, force=args.force)
    elif arch == "federated-forest":
        rec = run_ff_variant(args.variant, force=args.force)
    else:
        rec = run_nn_variant(arch, shape, args.variant, force=args.force)
    _report(rec)


if __name__ == "__main__":
    main()
