import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count on first init.
# This module is only imported by the dry-run entry point — tests/benches see
# the single real CPU device (never import this from library code).

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import roofline as rl                 # noqa: E402
from repro.configs import registry               # noqa: E402
from repro.launch import cases, mesh as mesh_mod  # noqa: E402

"""Multi-pod dry-run (deliverable (e)).

For every (architecture × input shape) the production step function is
``.lower().compile()``d against the single-pod (16×16) and multi-pod
(2×16×16 = 512 chips) meshes.  ``memory_analysis()`` proves the program fits
HBM; ``cost_analysis()`` + HLO collective parsing feed §Roofline.

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and are
aggregated into EXPERIMENTS.md by benchmarks/roofline_report.py.
"""

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_case(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path = OUT_DIR, force: bool = False) -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    t0 = time.time()
    try:
        if arch == "federated-forest":
            mesh = mesh_mod.make_forest_mesh(multi_pod=multi_pod)
            fn, args, _ = cases.forest_case(shape_name, mesh)
            lowered = jax.jit(fn).lower(*args)
            cfg = None
        else:
            mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
            case = cases.input_specs(arch, shape_name, mesh)
            cfg = case.cfg
            lowered = case.lower(mesh)
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")}
        r = rl.analyze(compiled)
        n_chips = 512 if multi_pod else 256
        mf = 0.0
        if cfg is not None:
            sh = cases.SHAPES[shape_name]
            mf = rl.model_flops(cfg, sh.kind, sh.batch, sh.seq)
        record["roofline"] = r.summary(model_flops_global=mf, n_chips=n_chips)
        record["collectives"] = r.coll_detail
        record["status"] = "ok"
    except cases.Skip as e:
        record["status"] = "skip"
        record["reason"] = str(e)
    except Exception as e:  # a failure here is a sharding bug — record it
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-3000:]

    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2, default=float))
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id, 'federated-forest', or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = (list(registry.ARCH_IDS) + ["federated-forest"]
             if args.arch == "all" else [args.arch])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        shape_names = (list(cases.FOREST_SHAPES) if arch == "federated-forest"
                       else list(cases.SHAPES))
        if args.shape != "all":
            shape_names = [args.shape]
        for shape in shape_names:
            for mp in meshes:
                rec = run_case(arch, shape, mp, force=args.force)
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                if rec["status"] == "ok":
                    ro = rec["roofline"]
                    print(f"OK   {tag}: mem/dev={ro['mem_per_dev_gib']:.2f}GiB "
                          f"bottleneck={ro['bottleneck']} "
                          f"t=({ro['t_compute_s']:.3e},{ro['t_memory_s']:.3e},"
                          f"{ro['t_collective_s']:.3e})s "
                          f"[lower {rec.get('lower_s')}s compile {rec.get('compile_s')}s]")
                elif rec["status"] == "skip":
                    print(f"SKIP {tag}: {rec['reason']}")
                else:
                    n_fail += 1
                    print(f"FAIL {tag}: {rec['error']}")
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run case(s) failed")


if __name__ == "__main__":
    main()
