"""Dry-run case builder: (arch × input-shape × mesh) -> lowerable closure.

Everything is ShapeDtypeStruct-based (jax.eval_shape) — no device memory is
allocated; ``lower().compile()`` is the proof that the distribution config is
coherent (deliverable (e)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.models import sharding, transformer
from repro.serve import step as serve_step_mod
from repro.train import optim
from repro.train.step import make_train_step


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int
    subquadratic: bool = False   # long-context: require sub-quadratic path


SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k":   InputShape("long_500k", "decode", 524_288, 1, True),
}

# principled skips (DESIGN.md §5)
SKIPS: dict[tuple[str, str], str] = {
    ("whisper-large-v3", "long_500k"):
        "enc-dec audio: decoder caps at 448 tokens; no faithful "
        "sub-quadratic variant of cross+self attention at 500k",
}

SWA_WINDOW = 4_096
TRAIN_MICRO_BATCH = 8


class Skip(Exception):
    pass


def arch_for_shape(arch: str, shape: InputShape) -> ArchConfig:
    """Resolve the per-shape config variant (e.g. SWA for long_500k)."""
    if (arch, shape.name) in SKIPS:
        raise Skip(SKIPS[(arch, shape.name)])
    cfg = registry.get(arch)
    if shape.subquadratic and not cfg.is_subquadratic:
        # sliding-window variant for the attention blocks (hybrid archs keep
        # full recurrent state in their SSM blocks)
        cfg = cfg.with_(sliding_window=SWA_WINDOW)
    return cfg


@dataclasses.dataclass
class Case:
    arch: str
    shape: InputShape
    cfg: ArchConfig
    fn: Callable
    args: tuple              # ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    donate: tuple = ()

    def lower(self, mesh: Mesh):
        ins = sharding.named(mesh, self.in_shardings)
        outs = sharding.named(mesh, self.out_shardings)
        jitted = jax.jit(self.fn, in_shardings=ins, out_shardings=outs,
                         donate_argnums=self.donate)
        with compat.set_mesh(mesh):  # resolves in-model sharding constraints
            return jitted.lower(*self.args)


def _batch_struct(cfg: ArchConfig, shape: InputShape) -> dict:
    b, s = shape.batch, shape.seq
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.enc_layers:
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.d_model),
                                             jnp.dtype(cfg.dtype))
    if cfg.n_patches:
        out["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model),
                                              jnp.dtype(cfg.dtype))
    return out


def _batch_specs(batch: dict, shape: InputShape, mesh: Mesh):
    return {k: sharding.batch_spec(shape.batch, mesh, extra_dims=v.ndim - 1)
            for k, v in batch.items()}


def input_specs(arch: str, shape_name: str, mesh: Mesh,
                overrides: Optional[dict] = None,
                micro_batch: Optional[int] = None,
                serve_layout: Optional[bool] = None,
                expert_data: bool = False) -> Case:
    """ShapeDtypeStruct stand-ins + shardings for every model input.

    ``overrides``: ArchConfig field overrides (§Perf variants).
    ``micro_batch``: grad-accumulation microbatch override for train.
    ``serve_layout``: tensor-parallel-only param shardings. §Perf tested and
    REFUTED this as a default: it removes the serving all-reduces but
    replicates weights (dense: +10 GiB/dev) and, for MoE, de-shards the
    dispatch tensors (back to 88 GiB/dev) — the FSDP layout's data-dim
    propagation was load-bearing. Kept as an experiment flag.
    """
    shape = SHAPES[shape_name]
    cfg = arch_for_shape(arch, shape)
    if overrides:
        cfg = cfg.with_(**overrides)

    if serve_layout is None:
        serve_layout = False
    pshapes = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg), jax.random.key(0))
    pspecs = sharding.param_specs(
        pshapes, mesh, mode="serve" if serve_layout else "train",
        expert_data=expert_data)

    if shape.kind == "train":
        oshapes = jax.eval_shape(optim.adamw_init, pshapes)
        ospecs = sharding.opt_specs(oshapes, pspecs)
        batch = _batch_struct(cfg, shape)
        bspecs = _batch_specs(batch, shape, mesh)
        mb = micro_batch or min(TRAIN_MICRO_BATCH, shape.batch)
        fn = make_train_step(cfg, micro_batch=mb)
        return Case(arch, shape, cfg, fn, (pshapes, oshapes, batch),
                    in_shardings=(pspecs, ospecs, bspecs),
                    out_shardings=(pspecs, ospecs, P()),
                    donate=(0, 1))

    if shape.kind == "prefill":
        batch = _batch_struct(cfg, shape)
        bspecs = _batch_specs(batch, shape, mesh)
        fn = serve_step_mod.make_prefill_step(cfg)
        cshapes = jax.eval_shape(
            lambda: transformer.make_cache(cfg, shape.batch, shape.seq))
        cspecs = sharding.cache_specs(cshapes, shape.batch, mesh)
        lspec = sharding.batch_spec(shape.batch, mesh, extra_dims=1)
        return Case(arch, shape, cfg, fn, (pshapes, batch),
                    in_shardings=(pspecs, bspecs),
                    out_shardings=(lspec, cspecs))

    # decode
    cshapes = jax.eval_shape(
        lambda: transformer.make_cache(cfg, shape.batch, shape.seq))
    cspecs = sharding.cache_specs(cshapes, shape.batch, mesh)
    token = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tspec = sharding.batch_spec(shape.batch, mesh, extra_dims=1)
    fn = serve_step_mod.make_serve_step(cfg)
    return Case(arch, shape, cfg, fn, (pshapes, cshapes, token, pos),
                in_shardings=(pspecs, cspecs, tspec, P()),
                out_shardings=(tspec, cspecs),
                donate=(1,))


# --------------------------------------------------- federated forest case
@dataclasses.dataclass(frozen=True)
class ForestShape:
    name: str
    n_samples: int
    n_feat_per_party: int
    n_trees_per_shard: int
    n_test: int = 0


FOREST_SHAPES = {
    "ff_train": ForestShape("ff_train", 262_144, 16, 4),
    "ff_predict": ForestShape("ff_predict", 262_144, 16, 4, n_test=65_536),
}


def forest_case(shape_name: str, mesh: Mesh, params=None, *,
                hist_impl: str = "scatter", **predict_kw):
    """Lowerable federated-forest protocol on the (trees, parties) mesh.

    Layout: the 'parties' axis carries the vertical feature partition (the
    paper's clients); the 'trees' axis carries bagging tree-parallelism; a
    'pod' axis (if present) replicates.  Party-private outputs keep a
    leading parties dim; tree-sharded inputs/outputs use their leading
    T dim.  The programs come from a sharded-substrate Federation session —
    the same code path production serving compiles.  Returns
    (fn, args, forest_params); ``predict_kw`` (compact / mask_dtype /
    vote_impl) goes to Federation.predict_program, with ``compact=True``
    appending the LeafTable leaf_idx ShapeDtypeStruct to args.
    """
    from repro.core.types import ForestParams
    from repro.federation import Federation

    fs = FOREST_SHAPES[shape_name]
    p = params or ForestParams(task="classification", n_classes=2,
                               n_estimators=fs.n_trees_per_shard, max_depth=8,
                               n_bins=32)
    m = mesh.shape["parties"]
    t_global = fs.n_trees_per_shard * mesh.shape["trees"]
    n, fp = fs.n_samples, fs.n_feat_per_party
    f_total = m * fp
    fed = Federation(parties=m, substrate="sharded", mesh=mesh)

    fit_args = (
        jax.ShapeDtypeStruct((m, n, fp), jnp.uint8),             # xb (by party)
        jax.ShapeDtypeStruct((m, fp), jnp.int32),                # feat_gid
        jax.ShapeDtypeStruct((t_global, f_total), jnp.bool_),    # feat_sel
        jax.ShapeDtypeStruct((t_global, n), jnp.float32),        # weights
        jax.ShapeDtypeStruct((n, p.n_stat_channels), jnp.float32),  # y_stats
    )
    fit_sharded = fed.fit_program(p, hist_impl=hist_impl)

    if shape_name == "ff_train":
        return fit_sharded, fit_args, p

    trees_shape = jax.eval_shape(fit_sharded, *fit_args)
    predict = fed.predict_program(p, **predict_kw)
    xb_test = jax.ShapeDtypeStruct((m, fs.n_test, fp), jnp.uint8)
    args = (trees_shape, xb_test)
    if predict_kw.get("compact"):
        # serving-engine leaf table at full bottom-level capacity — the
        # worst-case compact lowering (2^depth slots vs 2^(depth+1)-1)
        args += (jax.ShapeDtypeStruct((t_global, 2 ** p.max_depth),
                                      jnp.int32),)
    return predict, args, p
