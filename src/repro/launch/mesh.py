"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked on first jax init, and the 512-
device dry-run must set XLA_FLAGS before that happens).

Two mesh families:
  * NN substrate mesh:    (data=16, model=16)  /  (pod=2, data=16, model=16)
  * Federated Forest mesh: the 'model' axis is renamed to the protocol's
    'parties' axis and 'data' to 'trees' (tree-parallel bagging) — same
    chips, the axis names bind the paper's roles (DESIGN.md §4).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_forest_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "trees", "parties") if multi_pod else ("trees", "parties")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int = 1, axes=("data", "model"),
                   shape=None) -> jax.sharding.Mesh:
    """Small in-process mesh for tests (uses however many devices exist)."""
    n = n or len(jax.devices())
    shape = shape or (1, n)
    return jax.make_mesh(shape, axes)
