"""Serving-fleet smoke: mixed traffic, overload shedding, and a cell kill.

Stands up a 4-cell :class:`ServingFleet` over one fitted forest via
``Federation.serve_fleet`` and drives it through the failure modes the front
door exists for:

  1. mixed small-request traffic routed by consistent hashing, drained
     concurrently across cells — every request's predictions asserted
     bit-identical to a single ModelServer serving the same rows;
  2. forced overload — a starved token bucket and tiny bulkheads — with
     both typed ``FleetOverloadError`` shed paths observed and counted;
  3. an injected cell kill with requests pending: the dead cell's keyspace
     redistributes to the survivors and ZERO accepted requests are lost
     (every accepted rid resolves or dead-letters, asserted).

This is the CI gate for the fleet subsystem::

    PYTHONPATH=src python -m repro.launch.fleet_demo

Exit code 0 means: routing bit-identity held, both shed paths tripped
typed, the kill lost nothing, and the FleetMetrics/alert surface saw it all.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import ForestParams
from repro.data import make_classification
from repro.federation import Federation
from repro.serving import (AlertThresholds, FleetOverloadError, ServeConfig,
                           ServingFleet, alerts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=4)
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--trees", type=int, default=4)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--rows", type=int, default=900)
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    p = ForestParams(n_estimators=args.trees, max_depth=args.depth,
                     n_bins=16, seed=0)
    x, y = make_classification(args.rows, 18, 2, seed=0)
    fed = Federation(parties=args.parties, n_bins=p.n_bins)
    fed.ingest(x[:args.rows - 200], y[:args.rows - 200])
    model = fed.fit(p)
    xt = x[args.rows - 200:]

    t0 = time.time()
    cfg = ServeConfig(buckets=(32, 128))
    snapshots: list = []
    fleet = fed.serve_fleet(model, cfg, n_cells=args.cells,
                            snapshot_hook=snapshots.append).warmup()
    single = fed.serve(model, cfg)
    print(f"fleet: {args.cells} cells x {len(cfg.buckets)} bucket "
          f"executables compiled in {time.time() - t0:.1f}s")

    # ---- 1. mixed traffic, bit-identity against the single server
    rng = np.random.default_rng(1)
    rids = {}
    for i in range(args.requests):
        chunk = xt[rng.integers(0, len(xt), size=int(rng.integers(1, 64)))]
        rids[fleet.submit(chunk, key=f"req-{i}")] = chunk
    results = fleet.drain()
    assert set(results) == set(rids), "drain lost requests"
    for rid, chunk in rids.items():
        assert np.array_equal(results[rid], single.serve(chunk)), \
            f"request {rid} diverged from the single-server oracle"
    spread = {name: cell.server.stats()["rows"]
              for name, cell in fleet.cells.items()}
    print(f"traffic: {len(rids)} requests bit-identical; "
          f"rows per cell {spread}")

    # ---- 2. forced overload: both typed shed paths
    servers = [cell.server for cell in fleet.cells.values()]
    limited = ServingFleet({f"r{i}": s for i, s in enumerate(servers)},
                           rate_limit_rows_per_s=1.0, rate_burst=80.0)
    shed = {"rate_limit": 0, "queue_depth": 0}
    for i in range(12):
        try:
            limited.submit(xt[:40], key=f"ovl-{i}")
        except FleetOverloadError as err:
            assert err.reason == "rate_limit"
            shed["rate_limit"] += 1
    limited.drain()
    bulk = ServingFleet({f"q{i}": s for i, s in enumerate(servers)},
                        max_queue_rows=64)
    for i in range(8 * args.cells):
        try:
            bulk.submit(xt[:60], key=f"jam-{i}")
        except FleetOverloadError as err:
            assert err.reason == "queue_depth" and err.cell
            shed["queue_depth"] += 1
    bulk.drain()
    assert shed["rate_limit"] > 0 and shed["queue_depth"] > 0, shed
    assert limited.metrics().shed["rate_limit"] == shed["rate_limit"]
    print(f"overload: shed {shed['rate_limit']} on rate limit, "
          f"{shed['queue_depth']} on queue depth — typed, counted")

    # ---- 3. cell kill with pending traffic: zero lost accepted requests
    before = fleet.accepted_count
    rids2 = {}
    for i in range(args.requests):
        chunk = xt[rng.integers(0, len(xt), size=int(rng.integers(1, 64)))]
        rids2[fleet.submit(chunk, key=f"phase2-{i}")] = chunk
    victim = max(fleet.cells_up(),
                 key=lambda n: fleet.cells[n].queue.pending_requests())
    moved = fleet.kill_cell(victim)
    results2 = fleet.drain()
    accepted = fleet.accepted_count - before
    resolved = set(results2)
    dead = {d.rid for d in fleet.dead_letters}
    assert resolved | dead == set(rids2), "accepted requests were lost!"
    assert len(resolved) + len(dead) == accepted == len(rids2)
    for rid, chunk in rids2.items():
        assert np.array_equal(results2[rid], single.serve(chunk)), \
            f"post-kill request {rid} diverged"
    m = fleet.metrics()
    fired = alerts(m, AlertThresholds(cells_down=1))
    assert m.cells_down == 1 and m.rerouted == moved and fired
    print(f"kill: cell {victim} down with {moved} requests pending -> "
          f"re-routed, {len(resolved)}/{accepted} resolved, "
          f"{len(dead)} dead-lettered, zero lost")
    print(f"metrics: rows={m.rows} p50={m.p50_ms:.2f}ms p99={m.p99_ms:.2f}ms "
          f"accepted={m.accepted} shed={m.shed_total} cells_up={m.cells_up}")
    print(f"alerts: {'; '.join(fired)}")
    assert snapshots, "snapshot hook never fired"
    print("ALL OK")


if __name__ == "__main__":
    main()
