"""Serving driver: batched prefill + decode loop against the local devices.

The production path is the same `serve_step` the decode_32k / long_500k
dry-runs lower; this driver runs it end-to-end at reduced scale with simple
continuous batching (fixed batch slots, prompts join as slots free).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import reduced
from repro.models import transformer


def serve_batch(cfg, params, prompts: np.ndarray, max_new: int,
                cache_len: int):
    """One serving wave: prefill the batch, decode max_new tokens."""
    b, s = prompts.shape
    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, t: transformer.prefill(p, t, cfg, {}, cache_len=cache_len)
    )(params, jnp.asarray(prompts))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(
        lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg))
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    return (np.asarray(jnp.concatenate(out, 1)),
            {"prefill_s": t_prefill, "decode_s": t_decode,
             "decode_tok_s": b * (max_new - 1) / max(t_decode, 1e-9)})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(registry.get(args.arch))
    params = transformer.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    for wave in range(2):
        prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
        toks, stats = serve_batch(cfg, params, prompts, args.max_new,
                                  cache_len=args.prompt_len + args.max_new)
        print(f"wave {wave}: decoded {toks.shape}, "
              f"prefill {stats['prefill_s']:.2f}s, "
              f"decode {stats['decode_tok_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
