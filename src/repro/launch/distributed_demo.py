"""Distributed federation smoke: party-per-process fit + serve + a fault.

Launches a real 3-party localhost deployment (one OS process per party,
message-passing collectives over sockets — federation/distributed.py),
trains a small forest through it, checks the result bit-identically against
the vmap simulation, serves a few waves, then kills one party mid-traffic
and shows the degraded-serving path answering from the trees whose split
paths avoid the dead party's features.

This is the CI gate for the distributed substrate::

    PYTHONPATH=src python -m repro.launch.distributed_demo

Exit code 0 means: fit bit-identity held, serving worked, the injected
failure was detected, and degraded serving produced exact predictions from
the surviving trees.
"""
from __future__ import annotations

import argparse
import os
import time

# Arm the privacy egress guard before any repro import: the demo runs the
# whole flow with raw-array sends blocked at the wire (spawned party
# workers inherit the env and enforce the same policy on their side).
os.environ.setdefault("REPRO_EGRESS_GUARD", "1")

import numpy as np

from repro.core import ForestParams
from repro.data import make_classification
from repro.federation import Federation
from repro.federation.distributed import surviving_trees
from repro.federation.transport import RetryPolicy
from repro.serving import ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--trees", type=int, default=12)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--rows", type=int, default=300)
    ap.add_argument("--features", type=int, default=9)
    ap.add_argument("--round-timeout", type=float, default=60.0)
    ap.add_argument("--trace-out", default=None, metavar="DIR",
                    help="enable tracing and write spans.jsonl + trace.json "
                         "(Chrome trace) for the whole fit/serve run")
    args = ap.parse_args()

    if args.trace_out:
        # before the Federation spawns workers, so they inherit the env
        os.environ["REPRO_TRACE"] = "1"
        from repro.observability import TRACER
        TRACER.enable()

    # feature subsampling so some trees' split paths avoid some party
    # entirely — those are the trees degraded serving can answer from
    p = ForestParams(n_estimators=args.trees, max_depth=args.depth,
                     n_bins=16, max_features=0.34, seed=0)
    x, y = make_classification(args.rows, args.features, 2, seed=0)

    # reference: the same fit on the vmap simulation
    sim = Federation(parties=args.parties, n_bins=p.n_bins)
    sim.ingest(x, y)
    ref = sim.fit(p)

    t0 = time.time()
    fed = Federation(parties=args.parties, substrate="distributed",
                     n_bins=p.n_bins, round_timeout=args.round_timeout,
                     retry=RetryPolicy(attempts=3, base=0.05, seed=0))
    try:
        fed.ingest(x, y)
        model = fed.fit(p)
        assert all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(ref.trees_, model.trees_)), \
            "distributed fit diverged from the simulated reference"
        print(f"fit: {args.trees} trees over {args.parties} party processes "
              f"in {time.time() - t0:.1f}s — bit-identical to simulation")
        health = fed.substrate.health()
        print(f"health: " + ", ".join(
            f"party {k}={v * 1e3:.1f}ms" if v is not None
            else f"party {k}=DOWN" for k, v in sorted(health.items())))

        server = fed.serve(model, ServeConfig(buckets=(64,),
                                              allow_degraded=True))
        xt = x[:50]
        want = np.asarray(sim.predict(ref, xt))
        got = server.serve(xt)
        assert np.array_equal(got, want), "served predictions diverged"
        print(f"serve: {len(xt)} rows, bit-identical to simulation")

        if args.trace_out:
            # pull worker spans now, while all parties are still alive —
            # the chaos kill below takes the victim's buffer with it
            fed.collect_telemetry()

        # ---- injected failure: kill the party whose features the most
        # trees avoid (those trees keep answering exactly)
        survivors = {pi: surviving_trees(model.trees_, [pi]).size
                     for pi in range(args.parties)}
        victim = max(survivors, key=survivors.get)
        if survivors[victim] == 0:
            raise SystemExit("every tree splits on every party — raise "
                             "--trees or lower max_features")
        fed.substrate.chaos(victim, "die")
        got = server.serve(xt)        # wave rides the degraded path
        stats = server.wave_stats[-1]
        assert stats.get("degraded"), "expected a degraded wave"
        assert victim in stats["dead_parties"], stats
        sel = surviving_trees(model.trees_, [victim])
        import jax
        ref_deg = jax.tree.map(lambda a: np.asarray(a)[:, sel], ref.trees_)
        deg_model = type(ref)(p)
        deg_model.trees_ = jax.tree.map(np.asarray, ref_deg)
        deg_model.partition_ = ref.partition_
        deg_model._decode = ref._decode
        want_deg = np.asarray(deg_model.predict(xt))
        assert np.array_equal(got, want_deg), \
            "degraded predictions diverged from the surviving-tree forest"
        print(f"fault: party {victim} killed -> degraded serving from "
              f"{stats['n_trees']}/{args.trees} surviving trees, exact")

        if args.trace_out:
            import json
            os.makedirs(args.trace_out, exist_ok=True)
            jsonl = os.path.join(args.trace_out, "spans.jsonl")
            chrome = os.path.join(args.trace_out, "trace.json")
            n = fed.export_trace(jsonl, chrome)
            with open(chrome) as f:
                doc = json.load(f)
            events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
            procs = {s["proc"] for s in fed.trace_spans()}
            assert n > 0 and len(events) == n, (n, len(events))
            assert any(p.startswith("party") for p in procs), \
                f"no worker spans crossed the wire: {sorted(procs)}"
            print(f"trace: {n} spans from {len(procs)} processes -> "
                  f"{jsonl} + {chrome}")
        print("ALL OK")
    finally:
        fed.close()


if __name__ == "__main__":
    main()
