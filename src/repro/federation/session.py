"""Federation — the session object that owns the federated lifecycle.

The paper's system is one coordinated protocol: regional clients join a
session, train jointly (Alg. 2), and answer predictions with one round of
communication (Alg. 5/6).  This class is that session: it resolves the
execution substrate exactly once and exposes the whole lifecycle as methods,
instead of each entrypoint re-wiring vmap/shard_map/mesh/hist-backend by
hand::

    fed = Federation(parties=4)                 # or substrate="sharded", mesh=...
    part = fed.ingest(x_train, y_train)         # VerticalPartition
    model = fed.fit(ForestParams(...))          # FittedModel (Estimator)
    preds = fed.predict(model, x_test)          # one-round, leaf-compacted
    server = fed.serve(model, buckets=(32, 256))  # ForestServer on the session mesh
    fed.save(model, ckpt_dir); model = fed.load(ckpt_dir, params)

``fit`` dispatches on the spec type — ForestParams, BoostParams, or
LinearParams — and every fitted handle conforms to the shared Estimator
protocol.  ``predict``/``serve`` cache the LeafTable compaction plan per
model and rebuild it whenever the model's ``trees_`` changes (e.g. a
``fit_resumable`` continuation extended the forest), so serving state can
never go stale against a refreshed model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core.party import VerticalPartition, make_vertical_partition
from repro.core.types import ForestParams
from repro.federation import programs
from repro.federation.estimator import Estimator
from repro.federation.substrate import Substrate, resolve_substrate


class Federation:
    """A federated-learning session: participants + substrate + lifecycle.

    Args:
      parties: number of participating parties M (the vertical split width).
      substrate: "simulated" (vmap, single host — default), "sharded"
        (shard_map over ``mesh``), or a pre-built Substrate.
      mesh: jax Mesh with a "parties" axis (required for "sharded"); also
        pre-binds servers built by :meth:`serve`.
      hist_impl: session-level histogram backend override — the single
        source of truth, folded into every spec this session fits (None
        defers to each spec's own ``hist_impl``).
      n_bins: default quantile-bin count for :meth:`ingest`.
      seed: default partitioning seed for :meth:`ingest`.
    """

    def __init__(self, parties: int = 2, substrate: str | Substrate = "simulated",
                 mesh=None, hist_impl: str | None = None, n_bins: int = 32,
                 seed: int = 0):
        self.parties = int(parties)
        self.mesh = mesh
        self.hist_impl = hist_impl
        self.n_bins = int(n_bins)
        self.seed = int(seed)
        self.substrate = resolve_substrate(substrate, mesh,
                                           parties=self.parties)
        self._partition: VerticalPartition | None = None
        self._y: np.ndarray | None = None
        # id(model) -> (model, trees_ ref, LeafTable): the plan is valid
        # exactly while the model still holds that PartyTree stack.  The
        # strong model ref keeps the id stable (no reuse after gc); sessions
        # cache one entry per model they've predicted/served, which is the
        # session's working set by construction.
        self._plans: dict[int, tuple[Any, Any, Any]] = {}
        # (id(model), buckets, compact, cls) -> (model, server, trees_ ref)
        self._servers: dict[tuple, tuple[Any, Any, Any]] = {}

    # ------------------------------------------------------------------ data
    def ingest(self, x: np.ndarray, y: np.ndarray | None = None, *,
               n_bins: int | None = None, contiguous: bool = True,
               seed: int | None = None) -> VerticalPartition:
        """Vertically partition + bin a raw (N, F) matrix across the
        session's M parties; remembers (partition, y) as the session's
        training set so ``fit(spec)`` needs no further arguments."""
        part = make_vertical_partition(
            np.asarray(x), self.parties, n_bins or self.n_bins,
            contiguous=contiguous, seed=self.seed if seed is None else seed)
        self._partition = part
        self._y = None if y is None else np.asarray(y)
        return part

    # ------------------------------------------------------------------- fit
    def fit(self, spec, partition: VerticalPartition | None = None,
            y: np.ndarray | None = None, **model_kw) -> Estimator:
        """Train a model of the family ``spec`` describes on this session's
        substrate.  ``spec`` is a ForestParams, BoostParams, or LinearParams;
        the fitted handle conforms to the Estimator protocol."""
        partition, y = self._training_set(partition, y)
        self._check_binning(spec, partition)
        model = self._model_for(self._apply_session(spec), **model_kw)
        return model.fit(partition, y)

    def fit_resumable(self, spec: ForestParams, ckpt_dir: str, *,
                      trees_per_chunk: int = 2,
                      partition: VerticalPartition | None = None,
                      y: np.ndarray | None = None, **model_kw) -> Estimator:
        """Break-point-recoverable forest fit (paper §4.1) through the
        session substrate; chunk checkpoints land in ``ckpt_dir``."""
        if not isinstance(spec, ForestParams):
            raise TypeError("fit_resumable is forest-only")
        partition, y = self._training_set(partition, y)
        self._check_binning(spec, partition)
        model = self._model_for(self._apply_session(spec), **model_kw)
        return model.fit_resumable(partition, y, ckpt_dir,
                                   trees_per_chunk=trees_per_chunk)

    def _training_set(self, partition, y):
        partition = partition if partition is not None else self._partition
        y = y if y is not None else self._y
        if partition is None or y is None:
            raise ValueError("no training data: call ingest(x, y) first or "
                             "pass (partition, y) explicitly")
        if partition.n_parties != self.parties:
            raise ValueError(f"partition has {partition.n_parties} parties, "
                             f"session declares {self.parties}")
        return partition, y

    @staticmethod
    def _check_binning(spec, partition):
        """A spec binned differently from the partition would histogram
        truncated bin ids and silently train a wrong model — reject it."""
        spec_bins = getattr(spec, "n_bins", None)
        if spec_bins is not None and spec_bins != partition.n_bins:
            raise ValueError(
                f"spec.n_bins={spec_bins} but the partition was ingested "
                f"with n_bins={partition.n_bins}; re-ingest with matching "
                f"bins (Federation(n_bins=...) or ingest(n_bins=...))")

    def _apply_session(self, spec):
        """Fold session-level settings into a spec (hist_impl is owned here)."""
        if self.hist_impl is not None and hasattr(spec, "hist_impl") \
                and dataclasses.is_dataclass(spec):
            spec = dataclasses.replace(spec, hist_impl=self.hist_impl)
        return spec

    def _model_for(self, spec, **model_kw) -> Estimator:
        from repro.core.boosting import BoostParams, FederatedBoosting
        from repro.core.fedlinear import FederatedLinear, LinearParams
        from repro.core.forest import FederatedForest
        if isinstance(spec, ForestParams):
            return FederatedForest(spec, substrate=self.substrate, **model_kw)
        if isinstance(spec, BoostParams):
            return FederatedBoosting(spec, substrate=self.substrate,
                                     **model_kw)
        if isinstance(spec, LinearParams):
            return FederatedLinear.from_params(spec, substrate=self.substrate,
                                               **model_kw)
        raise TypeError(f"unknown model spec {type(spec).__name__} "
                        "(expected ForestParams | BoostParams | LinearParams)")

    # --------------------------------------------------------------- predict
    def predict(self, model: Estimator, x_test: np.ndarray) -> np.ndarray:
        """One-round prediction through the session.

        Forests go through the leaf-compacted kernel with a per-model cached
        LeafTable plan, rebuilt automatically when ``model.trees_`` changed
        since the plan was made (fit_resumable continuations, refits)."""
        from repro.core.forest import FederatedForest
        if isinstance(model, FederatedForest):
            return model.predict_compact(x_test,
                                         leaf_table=self._plan_for(model))
        return model.predict(x_test)

    def _plan_for(self, model):
        """The model's LeafTable — cached until its trees_ is swapped out."""
        cached = self._plans.get(id(model))
        if cached is not None and cached[0] is model \
                and cached[1] is model.trees_:
            return cached[2]
        table = model.leaf_table()
        self._plans[id(model)] = (model, model.trees_, table)
        return table

    # ----------------------------------------------------------------- serve
    def serve(self, model: Estimator, *, buckets=None, compact: bool = True,
              server_cls=None, **server_kw):
        """Stand up a ForestServer for ``model``, pre-bound to the session's
        mesh (sharded substrate -> shard_map serving; simulated -> vmap).

        Repeated calls with the same (model, buckets, compact) return the
        same server — compiled bucket executables are reused — unless the
        model's ``trees_`` changed, in which case the server is refreshed
        in place (LeafTable plan rebuilt, stale executables dropped)."""
        from repro.serving import engine
        cls = server_cls or engine.ForestServer
        buckets = tuple(buckets) if buckets is not None \
            else engine.DEFAULT_BUCKETS
        # only the knob-free path is cached: extra server_kw (vote_impl,
        # mask_dtype, ...) isn't part of the key, and silently returning a
        # server built with different knobs would drop the request
        cacheable = not server_kw
        key = (id(model), buckets, compact, cls)
        cached = self._servers.get(key) if cacheable else None
        if cached is not None and cached[0] is model:
            server, trees_ref = cached[1], cached[2]
            if trees_ref is not model.trees_:
                server.refresh(model.trees_)
                self._servers[key] = (model, server, model.trees_)
            return server
        server_kw.setdefault("mesh", self.substrate.mesh)
        server = cls.from_forest(model, buckets=buckets, compact=compact,
                                 **server_kw)
        if cacheable:
            self._servers[key] = (model, server, model.trees_)
        return server

    # ------------------------------------------------------------ checkpoint
    def save(self, model: Estimator, ckpt_dir: str,
             step: int | None = None) -> str:
        """Checkpoint a fitted forest's PartyTree stack (ckpt/checkpoint.py).
        Default step = the stack's tree count."""
        from repro import ckpt
        trees = getattr(model, "trees_", None)
        if trees is None or not hasattr(trees, "is_leaf"):
            raise TypeError("save() expects a fitted forest model")
        step = int(trees.is_leaf.shape[1]) if step is None else int(step)
        return ckpt.save_checkpoint(ckpt_dir, step, trees)

    def load(self, ckpt_dir: str, params: ForestParams, *,
             step: int | None = None,
             partition: VerticalPartition | None = None,
             decode: Callable | None = None, trees=None,
             **model_kw) -> Estimator:
        """Rehydrate a fitted forest handle from a checkpoint.

        The label decode is reconstructed from (n_classes, seed) for
        encrypted-classification forests (crypto.label_decoder), so a loaded
        model predicts true labels without the original fit in memory.
        CAVEAT: checkpoints store only the PartyTree stack, not the
        fit-time privacy flags — a forest trained with the non-default
        ``encrypt_labels=False`` (or ``mask_regression=True``) MUST be
        loaded with the same flags in ``model_kw`` (or an explicit
        ``decode``), exactly as it was constructed for fit; otherwise the
        reconstructed permutation decode scrambles its labels.
        ``trees`` accepts an already-loaded stack to avoid a second read."""
        from repro.core import crypto
        from repro.core.forest import FederatedForest
        from repro.serving.engine import load_forest_trees
        model = FederatedForest(self._apply_session(params),
                                substrate=self.substrate, **model_kw)
        model.trees_ = trees if trees is not None \
            else load_forest_trees(ckpt_dir, step)
        model.partition_ = partition if partition is not None \
            else self._partition
        stack_parties = int(model.trees_.is_leaf.shape[0])
        if model.partition_ is not None \
                and model.partition_.n_parties != stack_parties:
            raise ValueError(
                f"checkpointed stack has {stack_parties} parties but the "
                f"attached partition has {model.partition_.n_parties}; pass "
                f"the partition this forest was fitted with (or none)")
        if decode is None and params.task == "classification" \
                and model.encrypt_labels:
            decode = crypto.label_decoder(params.n_classes, params.seed)
        elif decode is None and params.task == "regression" \
                and model.mask_regression:
            decode = crypto.regression_unmasker(params.seed)
        model._decode = decode if decode is not None \
            else (lambda v: np.asarray(v))
        return model

    # ------------------------------------------- lowerable programs (dry-run)
    def fit_program(self, spec: ForestParams,
                    hist_impl: str | None = None) -> Callable:
        """The substrate-wrapped forest fit closure — jit/lower it against
        ShapeDtypeStructs for dry-run roofline work (launch/perf.py)."""
        return programs.forest_fit_program(
            self.substrate, self._apply_session(spec), hist_impl)

    def predict_program(self, spec: ForestParams, **kw) -> Callable:
        """The substrate-wrapped one-round predict closure (see
        programs.forest_predict_program for the knobs)."""
        return programs.forest_predict_program(
            self.substrate, self._apply_session(spec), **kw)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"Federation(parties={self.parties}, "
                f"substrate={self.substrate.name!r}, "
                f"hist_impl={self.hist_impl!r})")
