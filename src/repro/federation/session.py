"""Federation — the session object that owns the federated lifecycle.

The paper's system is one coordinated protocol: regional clients join a
session, train jointly (Alg. 2), and answer predictions with one round of
communication (Alg. 5/6).  This class is that session: it resolves the
execution substrate exactly once and exposes the whole lifecycle as methods,
instead of each entrypoint re-wiring vmap/shard_map/mesh/hist-backend by
hand::

    fed = Federation(parties=4)                 # or substrate="sharded", mesh=...
    part = fed.ingest(party_blocks)             # party-first: align + bin
    part = fed.ingest(x_train, y_train)         # or the raw-matrix adapter
    model = fed.fit(ForestParams(...))          # FittedModel (Estimator)
    preds = fed.predict(model, x_test)          # one-round, leaf-compacted
    server = fed.serve(model, buckets=(32, 256))  # ForestServer on the session mesh
    fed.save(model, ckpt_dir); model = fed.load(ckpt_dir, params)

``fit`` dispatches on the spec type — ForestParams, BoostParams, or
LinearParams — and every fitted handle conforms to the shared Estimator
protocol.  ``predict``/``serve`` cache the LeafTable compaction plan per
model and rebuild it whenever the model's ``trees_`` changes (e.g. a
``fit_resumable`` continuation extended the forest), so serving state can
never go stale against a refreshed model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core import crypto
from repro.core.party import (VerticalPartition, make_vertical_partition,
                              partition_from_blocks)
from repro.core.partyblock import DataSource, PartyBlock, is_block_sequence
from repro.core.types import ForestParams
from repro.federation import programs
from repro.federation.estimator import Estimator
from repro.federation.substrate import Substrate, resolve_substrate
from repro.observability import trace as tracing


def _token_matches(old: tuple, new: tuple) -> bool:
    """Compare engine model tokens: object entries by identity (the stored
    token pins them, so ids can't be reused), value entries by equality."""
    prim = (int, float, str, bool, type(None))
    return len(old) == len(new) and all(
        (o == n) if isinstance(o, prim) else (o is n)
        for o, n in zip(old, new))


class Federation:
    """A federated-learning session: participants + substrate + lifecycle.

    Args:
      parties: number of participating parties M (the vertical split width).
      substrate: "simulated" (vmap, single host — default), "sharded"
        (shard_map over ``mesh``), or a pre-built Substrate.
      mesh: jax Mesh with a "parties" axis (required for "sharded"); also
        pre-binds servers built by :meth:`serve`.
      hist_impl: session-level histogram backend override — the single
        source of truth, folded into every spec this session fits (None
        defers to each spec's own ``hist_impl``).
      n_bins: default quantile-bin count for :meth:`ingest`.
      seed: default partitioning seed for :meth:`ingest`.
    """

    def __init__(self, parties: int = 2, substrate: str | Substrate = "simulated",
                 mesh=None, hist_impl: str | None = None, n_bins: int = 32,
                 seed: int = 0, **substrate_opts):
        self.parties = int(parties)
        self.mesh = mesh
        self.hist_impl = hist_impl
        self.n_bins = int(n_bins)
        self.seed = int(seed)
        self.substrate = resolve_substrate(substrate, mesh,
                                           parties=self.parties,
                                           **substrate_opts)
        self._partition: VerticalPartition | None = None
        self._y: np.ndarray | None = None
        # streaming-ingest state (repro.streaming): the per-party
        # PartyStreams of a local streamed ingest, or the bookkeeping of a
        # distributed one (workers hold their own streams process-side) —
        # what ingest_append extends
        self._stream: dict | None = None
        # sample IDs of the ingested training set in aligned (row) order —
        # the canonical common ordering for party-block ingest, arange for
        # the pre-aligned raw-matrix path
        self.aligned_ids_: np.ndarray | None = None
        # id(model) -> (model, trees_ ref, LeafTable): the plan is valid
        # exactly while the model still holds that PartyTree stack.  The
        # strong model ref keeps the id stable (no reuse after gc); sessions
        # cache one entry per model they've predicted/served, which is the
        # session's working set by construction.
        self._plans: dict[int, tuple[Any, Any, Any]] = {}
        # (id(model), buckets|"autotune", compact, max_inflight, cls) ->
        # (model, server, model_token): the token (engine.model_token) pins
        # the state objects it references, so staleness checks stay exact
        self._servers: dict[tuple, tuple[Any, Any, tuple]] = {}

    # ------------------------------------------------------------------ data
    def ingest(self, data, y: np.ndarray | None = None, *,
               n_bins: int | None = None, contiguous: bool = True,
               seed: int | None = None, salt: str = crypto.DEFAULT_SALT,
               validate: bool = False, chunk_rows: int | None = None,
               sketch_capacity: int | None = None) -> VerticalPartition:
        """Ingest the session's training set; remembers (partition, y) so
        ``fit(spec)`` needs no further arguments.

        The canonical, party-first shape (paper §3.1/§4.3): ``data`` is a
        sequence of per-party :class:`PartyBlock`s (or DataSources loading
        them — e.g. ``CSVSource`` per regional file), each holding raw
        features keyed by that party's own sample IDs, with exactly one
        party holding the labels.  The session aligns the blocks on hashed
        IDs (iterated M-party intersection; superset/out-of-order rows
        collapse onto the canonical common ordering), bins each block
        party-locally (per-feature, hence lossless — ``validate=True``
        asserts bit-equality with central binning), and assembles the
        stacked VerticalPartition everything downstream consumes unchanged.
        The aligned sample IDs land on ``self.aligned_ids_``.

        Raises ValueError on an empty ID intersection, on duplicate IDs
        within a party, and on labels held by more than one party.

        Compat shape: a centrally held, pre-aligned raw (N, F) matrix plus
        ``y`` — adapted into implicit pre-aligned PartyBlocks split across
        the session's M parties (``contiguous``/``seed`` steer the feature
        assignment exactly as before).

        Streaming shape: hand any party's entry as a chunked source
        (:mod:`repro.streaming` — ``ChunkedCSVSource``, ``ArraySource``, a
        ``DataProduct``) and ingest runs out-of-core: every source is
        scanned chunk-wise (hashed IDs + mergeable quantile sketches),
        aligned, and binned in a second chunked pass — the raw features are
        never held densely, and the result is bit-identical to the
        in-memory build while the sketches stay exact (within their tracked
        rank-error bound past that).  ``chunk_rows`` bounds the pass
        working set, ``sketch_capacity`` the sketch memory/accuracy
        trade-off.  ``ingest_append`` can then land new rows.
        """
        from repro.streaming import is_chunked_sequence
        if is_chunked_sequence(data):
            if y is not None or not contiguous or seed is not None:
                raise ValueError(
                    "streamed ingest: labels ride on the label-holding "
                    "party's chunks, and feature assignment is owned by "
                    "the sources (feature_ids) — y/contiguous/seed do not "
                    "apply")
            if len(data) != self.parties:
                raise ValueError(f"got {len(data)} party sources but the "
                                 f"session declares {self.parties} parties")
            return self._ingest_stream(data, n_bins=n_bins or self.n_bins,
                                       salt=salt, validate=validate,
                                       chunk_rows=chunk_rows,
                                       sketch_capacity=sketch_capacity)
        if chunk_rows is not None or sketch_capacity is not None:
            raise ValueError("chunk_rows/sketch_capacity apply to streamed "
                             "ingest (chunked sources) only")
        if is_block_sequence(data):
            if y is not None:
                raise ValueError(
                    "party-first ingest: labels ride on their owning "
                    "PartyBlock (y=...), not as a separate argument")
            if not contiguous or seed is not None:
                raise ValueError(
                    "contiguous/seed steer the raw-matrix adapter's feature "
                    "assignment; party blocks own theirs (feature_ids, or "
                    "contiguous ids in canonical name order)")
            if len(data) != self.parties:
                raise ValueError(f"got {len(data)} party blocks but the "
                                 f"session declares {self.parties} parties")
            # a transport-backed substrate ingests party-side: blocks load,
            # hash and bin inside each party's own process, and only hashes
            # + binned values cross the wire
            ingest_blocks = getattr(self.substrate, "ingest_blocks", None)
            if ingest_blocks is not None:
                part, y_aligned, ids = ingest_blocks(
                    data, n_bins or self.n_bins, salt=salt, validate=validate)
            else:
                part, y_aligned, ids = partition_from_blocks(
                    data, n_bins or self.n_bins, salt=salt, validate=validate)
            self._partition, self._y = part, y_aligned
            self.aligned_ids_ = ids
            self._stream = None
            return part
        if isinstance(data, (PartyBlock, DataSource)):
            raise TypeError("pass PartyBlocks as a sequence: "
                            "ingest([block_a, block_b, ...])")
        part = make_vertical_partition(
            np.asarray(data), self.parties, n_bins or self.n_bins,
            contiguous=contiguous, seed=self.seed if seed is None else seed,
            validate=validate)
        self._partition = part
        self._y = None if y is None else np.asarray(y)
        self.aligned_ids_ = np.arange(part.n_samples)
        self._stream = None
        return part

    def _ingest_stream(self, sources, *, n_bins: int, salt: str,
                       validate: bool, chunk_rows: int | None,
                       sketch_capacity: int | None,
                       append: bool = False) -> VerticalPartition:
        from repro import streaming
        chunk_rows = chunk_rows if chunk_rows is not None \
            else streaming.DEFAULT_CHUNK_ROWS
        capacity = sketch_capacity if sketch_capacity is not None \
            else streaming.DEFAULT_CAPACITY
        # a transport-backed substrate streams party-side: each worker scans
        # and bins its own chunks; only hashes, sketch-derived boundaries,
        # binned values and the aligned labels cross the wire
        ingest_stream = getattr(self.substrate, "ingest_stream", None)
        if ingest_stream is not None:
            part, y, ids = ingest_stream(
                sources, n_bins, salt=salt, validate=validate,
                chunk_rows=chunk_rows, capacity=capacity, append=append)
            self._stream = {"mode": "distributed", "n_bins": n_bins,
                            "salt": salt, "chunk_rows": chunk_rows,
                            "capacity": capacity}
        else:
            if append:
                streams = self._stream["streams"]
                streaming.append_streams(streams, sources)
                part, y, ids = streaming.assemble_streams(streams, n_bins)
            else:
                part, y, ids, streams = streaming.streaming_ingest(
                    sources, n_bins, chunk_rows=chunk_rows,
                    capacity=capacity, salt=salt, validate=validate)
            self._stream = {"mode": "local", "streams": streams,
                            "n_bins": n_bins, "salt": salt,
                            "chunk_rows": chunk_rows, "capacity": capacity}
        self._partition, self._y = part, y
        self.aligned_ids_ = ids
        return part

    def ingest_append(self, sources) -> VerticalPartition:
        """Land newly published party data onto a streamed ingest.

        ``sources`` are chunked sources (or blocks/products) whose chunks
        name existing parties: each is scanned once and appended to that
        party's stream — product versions must strictly advance — and the
        partition is re-assembled over old + new rows (bin edges move when
        rows land, so every row re-bins; hashing and sketching of already-
        scanned sources is never repeated).  On a distributed substrate the
        append ships one source per party to its worker, which extends its
        process-side stream.

        Rows join the training set once every party holds them: a party
        whose rows lack counterparts simply stays out of the intersection
        until the other silos publish matching extracts.

        The re-assembled partition replaces the session training set; a
        following ``fit``/``fit_resumable`` trains on the concatenated data
        (bit-identical to a from-scratch ingest of the union), and cached
        plans/servers refresh exactly as after any refit — plan caches key
        on the model's tree stack, server caches on (trees, partition), so
        the next ``predict``/``serve`` against the refitted model rebuilds
        what staleness invalidated.
        """
        if self._stream is None:
            raise ValueError(
                "ingest_append extends a streamed ingest: call "
                "ingest([...chunked sources...]) first (in-memory ingests "
                "re-ingest the full block set instead)")
        st = self._stream
        return self._ingest_stream(
            sources, n_bins=st["n_bins"], salt=st["salt"], validate=False,
            chunk_rows=st["chunk_rows"], sketch_capacity=st["capacity"],
            append=True)

    @property
    def labels_(self) -> np.ndarray | None:
        """The ingested labels, gathered onto the aligned row ordering."""
        return self._y

    # ------------------------------------------------------------------- fit
    def fit(self, spec, partition: VerticalPartition | None = None,
            y: np.ndarray | None = None, **model_kw) -> Estimator:
        """Train a model of the family ``spec`` describes on this session's
        substrate.  ``spec`` is a ForestParams, BoostParams, or LinearParams;
        the fitted handle conforms to the Estimator protocol."""
        partition, y = self._training_set(partition, y)
        self._check_binning(spec, partition)
        model = self._model_for(self._apply_session(spec), **model_kw)
        with tracing.TRACER.span(f"fit.{type(spec).__name__}",
                                 category="host",
                                 substrate=self.substrate.name,
                                 parties=self.parties):
            return model.fit(partition, y)

    def fit_resumable(self, spec: ForestParams, ckpt_dir: str, *,
                      trees_per_chunk: int = 2,
                      partition: VerticalPartition | None = None,
                      y: np.ndarray | None = None,
                      model: Estimator | None = None,
                      **model_kw) -> Estimator:
        """Break-point-recoverable forest fit (paper §4.1) through the
        session substrate; chunk checkpoints land in ``ckpt_dir``.

        The incremental-fit entry point: rerun with a larger
        ``spec.n_estimators`` to extend a checkpointed forest (only the new
        trees build — bit-identical to a from-scratch fit at the larger
        count), or after ``ingest_append`` to retrain on the grown data
        (the checkpoint fingerprint detects the changed partition and the
        fit cleanly restarts).  Pass ``model=`` to continue an existing
        fitted handle in place: cached plans and servers keyed to that
        handle refresh automatically when its trees/partition change."""
        if not isinstance(spec, ForestParams):
            raise TypeError("fit_resumable is forest-only")
        partition, y = self._training_set(partition, y)
        self._check_binning(spec, partition)
        if model is not None:
            from repro.core.forest import FederatedForest
            if not isinstance(model, FederatedForest):
                raise TypeError("fit_resumable(model=...) continues a "
                                "FederatedForest handle")
            if model_kw:
                raise ValueError("model= continues an existing handle; "
                                 "constructor kwargs don't apply")
            model.params = self._apply_session(spec)
        else:
            model = self._model_for(self._apply_session(spec), **model_kw)
        return model.fit_resumable(partition, y, ckpt_dir,
                                   trees_per_chunk=trees_per_chunk)

    def _training_set(self, partition, y):
        partition = partition if partition is not None else self._partition
        y = y if y is not None else self._y
        if partition is None or y is None:
            raise ValueError("no training data: call ingest(x, y) first or "
                             "pass (partition, y) explicitly")
        if partition.n_parties != self.parties:
            raise ValueError(f"partition has {partition.n_parties} parties, "
                             f"session declares {self.parties}")
        return partition, y

    @staticmethod
    def _check_binning(spec, partition):
        """A spec binned differently from the partition would histogram
        truncated bin ids and silently train a wrong model — reject it."""
        spec_bins = getattr(spec, "n_bins", None)
        if spec_bins is not None and spec_bins != partition.n_bins:
            raise ValueError(
                f"spec.n_bins={spec_bins} but the partition was ingested "
                f"with n_bins={partition.n_bins}; re-ingest with matching "
                f"bins (Federation(n_bins=...) or ingest(n_bins=...))")

    def _apply_session(self, spec):
        """Fold session-level settings into a spec (hist_impl is owned here)."""
        if self.hist_impl is not None and hasattr(spec, "hist_impl") \
                and dataclasses.is_dataclass(spec):
            spec = dataclasses.replace(spec, hist_impl=self.hist_impl)
        return spec

    def _model_for(self, spec, **model_kw) -> Estimator:
        from repro.core.boosting import BoostParams, FederatedBoosting
        from repro.core.fedlinear import FederatedLinear, LinearParams
        from repro.core.forest import FederatedForest
        if isinstance(spec, ForestParams):
            return FederatedForest(spec, substrate=self.substrate, **model_kw)
        if isinstance(spec, BoostParams):
            return FederatedBoosting(spec, substrate=self.substrate,
                                     **model_kw)
        if isinstance(spec, LinearParams):
            return FederatedLinear.from_params(spec, substrate=self.substrate,
                                               **model_kw)
        raise TypeError(f"unknown model spec {type(spec).__name__} "
                        "(expected ForestParams | BoostParams | LinearParams)")

    # --------------------------------------------------------------- predict
    def predict(self, model: Estimator, x_test: np.ndarray) -> np.ndarray:
        """One-round prediction through the session.

        Forests go through the leaf-compacted kernel with a per-model cached
        LeafTable plan, rebuilt automatically when ``model.trees_`` changed
        since the plan was made (fit_resumable continuations, refits)."""
        from repro.core.forest import FederatedForest
        with tracing.TRACER.span("predict", category="host",
                                 family=type(model).__name__):
            if isinstance(model, FederatedForest):
                return model.predict_compact(x_test,
                                             leaf_table=self._plan_for(model))
            return model.predict(x_test)

    def _plan_for(self, model):
        """The model's LeafTable — cached until its trees_ is swapped out."""
        cached = self._plans.get(id(model))
        if cached is not None and cached[0] is model \
                and cached[1] is model.trees_:
            return cached[2]
        table = model.leaf_table()
        self._plans[id(model)] = (model, model.trees_, table)
        return table

    # ----------------------------------------------------------------- serve
    def serve(self, model: Estimator, config=None, *, traffic=None,
              server_cls=None, **server_kw):
        """Stand up a serving engine for ``model``, pre-bound to the
        session's substrate (sharded -> shard_map serving; simulated ->
        vmap; distributed -> waves dispatched to the party processes).
        The engine class is dispatched on the model family (forest ->
        ForestServer, boosting -> BoostingServer, F-LR -> LinearServer —
        serving/engine.server_for).

        ``config`` is a :class:`repro.serving.ServeConfig` — buckets,
        compact, max_inflight, autotune_buckets, allow_degraded in one
        hashable value object that doubles as the server-cache key.  The
        pre-config keywords (``serve(model, buckets=..., compact=...)``)
        still work through a one-shot adapter that emits a
        DeprecationWarning.

        ``config.autotune_buckets`` derives the bucket set from observed
        traffic instead of the warm-start guess: pass ``traffic``
        (wave_stats / request_stats records, or plain row counts) to tune a
        fresh server up front; on a cached server the engine's own
        ``wave_stats`` are used, and the bucket set is refreshed in place
        through ``set_buckets`` — the same way ``trees_`` changes refresh
        plans, with the compile-once contract holding per autotune epoch.

        Repeated calls with an equal (model, config) return the same server
        — compiled bucket executables are reused — unless the model's state
        changed, in which case the server is refreshed in place (plan
        rebuilt, stale executables dropped)."""
        from repro.serving import autotune, engine
        from repro.serving.config import adapt_legacy_kwargs
        config = adapt_legacy_kwargs(config, server_kw)
        cls = server_cls or engine.server_for(model)
        warm = config.resolved_buckets(engine.DEFAULT_BUCKETS)
        # only the knob-free path is cached: extra server_kw (vote_impl,
        # mask_dtype, ...) isn't part of the key, and silently returning a
        # server built with different knobs would drop the request
        cacheable = not server_kw
        key = (id(model), config, cls)
        cached = self._servers.get(key) if cacheable else None
        if cached is not None and cached[0] is model:
            server, token = cached[1], cached[2]
            if not _token_matches(token, cls.model_token(model)):
                server.refresh_from(model)
                self._servers[key] = (model, server, cls.model_token(model))
            if config.autotune_buckets:
                source = traffic if traffic is not None else server.wave_stats
                tuned = autotune.autotune_buckets(source, warm=server.buckets)
                if tuned != server.buckets:
                    server.set_buckets(tuned)
            return server
        if config.autotune_buckets and traffic is not None:
            warm = autotune.autotune_buckets(traffic, warm=warm)
        if "mesh" not in server_kw:
            server_kw.setdefault("substrate", self.substrate)
        if issubclass(cls, engine.ForestServer):
            server_kw.setdefault("allow_degraded", config.allow_degraded)
        server = cls.from_model(model, buckets=warm, compact=config.compact,
                                max_inflight=config.max_inflight, **server_kw)
        if cacheable:
            self._servers[key] = (model, server, cls.model_token(model))
        return server

    def serve_fleet(self, model: Estimator, config=None, *,
                    n_cells: int = 4, traffic=None, server_cls=None,
                    **fleet_kw):
        """Stand up a :class:`repro.serving.ServingFleet` for ``model``:
        ``n_cells`` replicated serving engines (each built exactly as
        ``serve`` would build one — same substrate, same ServeConfig
        semantics) behind consistent-hash routing and admission control
        (serving/fleet.py).  Extra keywords (``max_queue_rows``,
        ``rate_limit_rows_per_s``, ``max_poison_retries``,
        ``snapshot_hook``, ...) pass through to the fleet front door.

        Cache/refresh semantics match ``serve``: repeated calls with an
        equal (model, config, n_cells) return the same fleet — every cell's
        compiled bucket executables are reused — unless the model's state
        changed, in which case each cell refreshes in place.  With
        ``config.autotune_buckets`` a cached fleet re-derives buckets PER
        CELL from that cell's own observed traffic (its ``wave_stats``), so
        cells serving different row-size mixes tune independently; buckets
        that survive a retune keep their executables (compile-once per
        autotune epoch, per cell).  Only the knob-free path is cached, as
        with ``serve``."""
        from repro.serving import autotune, engine
        from repro.serving.config import ServeConfig
        from repro.serving.fleet import ServingFleet
        if int(n_cells) < 1:
            raise ValueError(f"n_cells must be >= 1, got {n_cells}")
        config = config if config is not None else ServeConfig()
        cls = server_cls or engine.server_for(model)
        cacheable = not fleet_kw
        key = (id(model), config, cls, ("fleet", int(n_cells)))
        cached = self._servers.get(key) if cacheable else None
        if cached is not None and cached[0] is model:
            fleet, token = cached[1], cached[2]
            if not _token_matches(token, cls.model_token(model)):
                for cell in fleet.cells.values():
                    cell.server.refresh_from(model)
                self._servers[key] = (model, fleet, cls.model_token(model))
            if config.autotune_buckets:
                for cell in fleet.cells.values():
                    tuned = autotune.autotune_buckets(
                        cell.server.wave_stats, warm=cell.server.buckets)
                    if tuned != cell.server.buckets:
                        cell.server.set_buckets(tuned)
            return fleet
        warm = config.resolved_buckets(engine.DEFAULT_BUCKETS)
        if config.autotune_buckets and traffic is not None:
            warm = autotune.autotune_buckets(traffic, warm=warm)
        server_kw: dict = {"substrate": self.substrate}
        if issubclass(cls, engine.ForestServer):
            server_kw["allow_degraded"] = config.allow_degraded
        servers = {
            f"cell{i}": cls.from_model(
                model, buckets=warm, compact=config.compact,
                max_inflight=config.max_inflight, **server_kw)
            for i in range(int(n_cells))}
        fleet = ServingFleet(servers, **fleet_kw)
        if cacheable:
            self._servers[key] = (model, fleet, cls.model_token(model))
        return fleet

    # ------------------------------------------------------------ checkpoint
    def save(self, model: Estimator, ckpt_dir: str,
             step: int | None = None) -> str:
        """Checkpoint a fitted tree model's PartyTree stack
        (ckpt/checkpoint.py), tagged with its model family so ``load``
        rehydrates the right estimator — a boosting stack silently reloaded
        as a forest would average leaf values instead of summing Newton
        steps and predict garbage.  Default step = the stack's tree/round
        count."""
        from repro import ckpt
        from repro.core.boosting import FederatedBoosting, stack_rounds
        if isinstance(model, FederatedBoosting):
            if not model.trees_:
                raise TypeError("save() expects a fitted model")
            stack = stack_rounds(model.trees_)
            step = len(model.trees_) if step is None else int(step)
            meta = {"family": "boosting", "task": model.params.task,
                    "n_rounds": len(model.trees_),
                    "learning_rate": float(model.params.learning_rate),
                    "base": float(model.base_)}
            return ckpt.save_checkpoint(ckpt_dir, step, stack, meta=meta)
        trees = getattr(model, "trees_", None)
        if trees is None or not hasattr(trees, "is_leaf"):
            raise TypeError("save() expects a fitted forest/boosting model")
        step = int(trees.is_leaf.shape[1]) if step is None else int(step)
        return ckpt.save_checkpoint(ckpt_dir, step, trees,
                                    meta={"family": "forest"})

    def load(self, ckpt_dir: str, params, *,
             step: int | None = None,
             partition: VerticalPartition | None = None,
             decode: Callable | None = None, trees=None,
             **model_kw) -> Estimator:
        """Rehydrate a fitted model handle from a checkpoint.

        ``load`` dispatches on the checkpoint's model-family tag (written by
        :meth:`save`): a ForestParams spec requires a forest (or untagged
        legacy) checkpoint, a BoostParams spec requires a boosting one —
        mismatches raise instead of silently rehydrating the wrong family.

        The label decode is reconstructed from (n_classes, seed) for
        encrypted-classification forests (crypto.label_decoder), so a loaded
        model predicts true labels without the original fit in memory.
        CAVEAT: checkpoints store only the PartyTree stack, not the
        fit-time privacy flags — a forest trained with the non-default
        ``encrypt_labels=False`` (or ``mask_regression=True``) MUST be
        loaded with the same flags in ``model_kw`` (or an explicit
        ``decode``), exactly as it was constructed for fit; otherwise the
        reconstructed permutation decode scrambles its labels.
        ``trees`` accepts an already-loaded stack to avoid a second read."""
        from repro import ckpt
        from repro.core import crypto
        from repro.core.boosting import BoostParams
        from repro.core.forest import FederatedForest
        from repro.serving.engine import load_forest_trees
        if step is None:
            step = ckpt.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        meta = ckpt.read_meta(ckpt_dir, step)
        family = meta.get("family")
        if isinstance(params, BoostParams):
            if family != "boosting":
                raise ValueError(
                    f"checkpoint at {ckpt_dir} step {step} holds a "
                    f"{family or 'forest (untagged legacy)'} model but "
                    f"load() was given BoostParams; load it with the spec "
                    f"of the family it was saved as")
            return self._load_boosting(ckpt_dir, params, step, meta,
                                       partition, trees, **model_kw)
        if family not in (None, "forest"):
            raise ValueError(
                f"checkpoint at {ckpt_dir} step {step} holds a {family!r} "
                f"model; rehydrating it as a forest would predict garbage — "
                f"load it with the matching spec (e.g. BoostParams)")
        if not isinstance(params, ForestParams):
            raise TypeError(f"load() dispatches on ForestParams | "
                            f"BoostParams, got {type(params).__name__}")
        model = FederatedForest(self._apply_session(params),
                                substrate=self.substrate, **model_kw)
        model.trees_ = trees if trees is not None \
            else load_forest_trees(ckpt_dir, step)
        model.partition_ = partition if partition is not None \
            else self._partition
        stack_parties = int(model.trees_.is_leaf.shape[0])
        if model.partition_ is not None \
                and model.partition_.n_parties != stack_parties:
            raise ValueError(
                f"checkpointed stack has {stack_parties} parties but the "
                f"attached partition has {model.partition_.n_parties}; pass "
                f"the partition this forest was fitted with (or none)")
        if decode is None and params.task == "classification" \
                and model.encrypt_labels:
            decode = crypto.label_decoder(params.n_classes, params.seed)
        elif decode is None and params.task == "regression" \
                and model.mask_regression:
            decode = crypto.regression_unmasker(params.seed)
        model._decode = decode if decode is not None \
            else (lambda v: np.asarray(v))
        return model

    def _load_boosting(self, ckpt_dir: str, params, step: int, meta: dict,
                       partition, trees, **model_kw) -> Estimator:
        """Rehydrate a FederatedBoosting handle from a family-tagged
        checkpoint: the concatenated round stack splits back into per-round
        trees; base / task / learning-rate come from the metadata."""
        from repro.core.boosting import FederatedBoosting, split_rounds
        from repro.serving.engine import load_forest_trees
        if params.task != meta.get("task"):
            raise ValueError(
                f"checkpointed boosting model was fitted with "
                f"task={meta.get('task')!r} but the spec says "
                f"{params.task!r}")
        if abs(float(params.learning_rate)
               - float(meta.get("learning_rate", params.learning_rate))) \
                > 1e-12:
            raise ValueError(
                f"checkpointed boosting model used "
                f"learning_rate={meta.get('learning_rate')} but the spec "
                f"says {params.learning_rate} — predictions would rescale "
                f"every round's step")
        stack = trees if trees is not None \
            else load_forest_trees(ckpt_dir, step)
        model = FederatedBoosting(self._apply_session(params),
                                  substrate=self.substrate, **model_kw)
        model.trees_ = split_rounds(stack)
        model.base_ = float(meta["base"])
        model._partition = partition if partition is not None \
            else self._partition
        stack_parties = int(stack.is_leaf.shape[0])
        if model._partition is not None \
                and model._partition.n_parties != stack_parties:
            raise ValueError(
                f"checkpointed stack has {stack_parties} parties but the "
                f"attached partition has {model._partition.n_parties}; pass "
                f"the partition this model was fitted with (or none)")
        return model

    # ------------------------------------------- lowerable programs (dry-run)
    def fit_program(self, spec: ForestParams,
                    hist_impl: str | None = None) -> Callable:
        """The substrate-wrapped forest fit closure — jit/lower it against
        ShapeDtypeStructs for dry-run roofline work (launch/perf.py)."""
        return programs.forest_fit_program(
            self.substrate, self._apply_session(spec), hist_impl)

    def predict_program(self, spec: ForestParams, **kw) -> Callable:
        """The substrate-wrapped one-round predict closure (see
        programs.forest_predict_program for the knobs)."""
        return programs.forest_predict_program(
            self.substrate, self._apply_session(spec), **kw)

    # ---------------------------------------------------------- observability
    def collect_telemetry(self) -> dict:
        """Roll party-side telemetry up into this process (distributed
        substrate: each live worker's trace spans join the session tracer
        and its metrics merge under a ``party<i>.`` prefix — metadata only,
        the rollup op carries no arrays).  In-process substrates have
        nothing to collect.  Returns ``{party: {"spans": n, "metrics": n}}``."""
        collect = getattr(self.substrate, "collect_telemetry", None)
        return collect() if collect is not None else {}

    def trace_spans(self) -> list[dict]:
        """Buffered trace spans (coordinator + any collected party spans)."""
        self.collect_telemetry()
        return tracing.TRACER.spans()

    def export_trace(self, jsonl_path: str,
                     chrome_path: str | None = None) -> int:
        """Collect + export the session trace; returns the span count.

        ``jsonl_path`` gets one span per line (the ``repro-trace`` CLI
        input); ``chrome_path`` optionally gets a Chrome trace-event file
        for chrome://tracing / Perfetto."""
        from repro.observability import export
        spans = self.trace_spans()
        export.export_jsonl(spans, jsonl_path)
        if chrome_path is not None:
            export.write_chrome_trace(spans, chrome_path)
        return len(spans)

    # -------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Tear down the session's substrate — a distributed session's party
        processes and sockets; in-process substrates have nothing to tear
        down (Substrate.shutdown is a no-op there)."""
        self.substrate.shutdown()

    def __enter__(self) -> "Federation":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"Federation(parties={self.parties}, "
                f"substrate={self.substrate.name!r}, "
                f"hist_impl={self.hist_impl!r})")
