"""Execution substrates: where the federated protocol runs.

The protocol bodies (core/tree.py, core/prediction.py, core/fedlinear.py)
are written once against the ``parties`` axis name; a Substrate decides how
that axis is realized:

  * ``SimulatedSubstrate``   — vmap on one host (core/protocol.run_simulated).
    The CPU test/benchmark path; collectives have identical semantics.
  * ``ShardedSubstrate``     — shard_map over a mesh whose "parties" axis is
    the protocol axis (core/protocol.run_sharded).  One party per shard,
    optional "trees" axis for bagging tree-parallelism.
  * ``DistributedSubstrate`` — one OS process per party, message-passing
    collectives over localhost sockets, production fault tolerance
    (federation/distributed.py).

Substrates register themselves by name (``register_substrate``, mirroring
the histogram-backend registry of kernels/ops.py), so a new implementation
plugs into every lifecycle surface — Federation.fit/predict/serve,
ForestServer, the launch CLIs — through ``resolve_substrate`` without
touching it.  The protocol also carries the lifecycle seams a real
transport needs — ``compile``/``aot_compile`` (how a program becomes an
executable: jax.jit for in-process substrates, identity/bind for the
message-passing one), ``exchange`` (out-of-band party requests), and
``shutdown`` — with no-op defaults in :class:`InProcessSubstrate`.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Protocol, runtime_checkable

import jax
from jax.sharding import Mesh

from repro.core import protocol
from repro.core.types import PARTY_AXIS


@runtime_checkable
class Substrate(Protocol):
    """Where SPMD party programs execute (duck-typed; see the three impls)."""

    name: str
    mesh: Mesh | None

    def program(self, fn: Callable, n_party: int, n_shared: int, *,
                shared_specs=None, out_specs=None, distributed=None,
                parties=None) -> Callable: ...

    def jit(self, fn: Callable, n_party: int, n_shared: int, **kw) -> Callable: ...

    def compile(self, program: Callable) -> Callable: ...

    def aot_compile(self, program: Callable, *args) -> Callable: ...

    def context(self): ...

    def exchange(self, op: str, payload=None, *, party=None, timeout=None): ...

    def shutdown(self) -> None: ...


class InProcessSubstrate:
    """Shared seams for substrates whose parties live in this process:
    compilation is jax.jit/AOT, there is no transport to exchange over,
    and shutdown has nothing to tear down."""

    def jit(self, fn: Callable, n_party: int, n_shared: int, **kw) -> Callable:
        return jax.jit(self.program(fn, n_party, n_shared, **kw))

    def compile(self, program: Callable) -> Callable:
        """Program -> executable (JIT-wrapped; traces on first call)."""
        return jax.jit(program)

    def aot_compile(self, program: Callable, *args) -> Callable:
        """Program -> ahead-of-time compiled executable for these operands
        (the serving engine's per-bucket warm path)."""
        return jax.jit(program).lower(*args).compile()

    def context(self):
        return contextlib.nullcontext()

    def exchange(self, op: str, payload=None, *, party=None, timeout=None):
        """Out-of-band party requests only exist over a transport."""
        return None

    def shutdown(self) -> None:
        pass


class SimulatedSubstrate(InProcessSubstrate):
    """M parties on one host under vmap — semantically the distributed run."""

    name = "simulated"
    mesh = None
    tree_axis = None

    def program(self, fn: Callable, n_party: int, n_shared: int, *,
                shared_specs=None, out_specs=None, distributed=None,
                parties=None) -> Callable:
        """Callable over (party_args..., shared_args...); sharding specs and
        the distributed protocol spec are accepted (and ignored) so callers
        can stay substrate-agnostic."""
        def run(*args):
            return protocol.run_simulated(
                fn, args[:n_party], args[n_party:n_party + n_shared])
        return run


class ShardedSubstrate(InProcessSubstrate):
    """shard_map over a mesh axis literally named "parties" (one party per
    shard).  A "trees" axis, if present, carries bagging tree-parallelism —
    forest programs shard their per-tree args/outputs over it."""

    name = "sharded"

    def __init__(self, mesh: Mesh):
        if PARTY_AXIS not in mesh.axis_names:
            raise ValueError(
                f"sharded substrate needs a '{PARTY_AXIS}' mesh axis, got "
                f"{mesh.axis_names}")
        self.mesh = mesh

    @property
    def n_parties(self) -> int:
        return int(self.mesh.shape[PARTY_AXIS])

    @property
    def tree_axis(self) -> str | None:
        return "trees" if "trees" in self.mesh.axis_names else None

    def program(self, fn: Callable, n_party: int, n_shared: int, *,
                shared_specs=None, out_specs=None, distributed=None,
                parties=None) -> Callable:
        return protocol.sharded_program(fn, self.mesh, n_party, n_shared,
                                        shared_specs=shared_specs,
                                        out_specs=out_specs)

    def context(self):
        """Mesh context for lowering (resolves in-program sharding names)."""
        from repro import compat
        return compat.set_mesh(self.mesh)


# ------------------------------------------------------------------- registry
SUBSTRATES: dict[str, Callable[..., Substrate]] = {}


def register_substrate(name: str, factory: Callable[..., Substrate] | None = None):
    """Register a substrate factory under ``name`` (the string accepted by
    ``resolve_substrate`` and every session/server entrypoint).  Factories
    receive ``mesh=``/``parties=`` plus any substrate-specific options.
    Usable as a decorator (``@register_substrate("x")``) or a call
    (``register_substrate("x", factory)``), like kernels/ops.py's backend
    registry."""
    def register(f):
        SUBSTRATES[name] = f
        return f
    return register(factory) if factory is not None else register


@register_substrate("simulated")
def _make_simulated(mesh=None, parties=None, **opts) -> Substrate:
    if opts:
        raise TypeError(f"substrate 'simulated' takes no options, got "
                        f"{sorted(opts)}")
    return SimulatedSubstrate()


@register_substrate("sharded")
def _make_sharded(mesh=None, parties=None, **opts) -> Substrate:
    if mesh is None:
        raise ValueError("substrate='sharded' requires a mesh")
    if opts:
        raise TypeError(f"substrate 'sharded' takes no options, got "
                        f"{sorted(opts)}")
    return ShardedSubstrate(mesh)


@register_substrate("distributed")
def _make_distributed(mesh=None, parties=None, **opts) -> Substrate:
    from repro.federation.distributed import DistributedSubstrate
    if parties is None:
        raise ValueError("substrate='distributed' needs the party count "
                         "(resolve_substrate(..., parties=M))")
    return DistributedSubstrate(parties, **opts)


def default_substrate(sub: Substrate | None = None) -> Substrate:
    """The substrate an estimator runs on when none was injected: vmap
    simulation.  Single owner of the estimators' fallback wiring."""
    return sub if sub is not None else SimulatedSubstrate()


def resolve_substrate(spec: str | Substrate | Any, mesh: Mesh | None = None,
                      parties: int | None = None, **opts) -> Substrate:
    """One-time substrate resolution for a session or server.

    ``spec`` is a registered substrate name (see ``SUBSTRATES``) or an
    already-built Substrate (passed through).  ``parties``, when given, is
    validated against the substrate's own party count (a sharded mesh's
    party-axis size, a distributed coordinator's worker count).  Extra
    keyword options flow to the named factory (e.g. the distributed
    substrate's timeout/retry knobs)."""
    if isinstance(spec, str):
        factory = SUBSTRATES.get(spec)
        if factory is None:
            raise ValueError(f"unknown substrate {spec!r} "
                             f"(registered: {sorted(SUBSTRATES)})")
        sub = factory(mesh=mesh, parties=parties, **opts)
    elif isinstance(spec, Substrate):   # any conforming implementation
        sub = spec
    else:
        raise ValueError(f"unknown substrate {spec!r} "
                         f"(registered: {sorted(SUBSTRATES)}, or pass a "
                         f"Substrate)")
    have = getattr(sub, "n_parties", None)
    if parties is not None and have is not None and int(have) != parties:
        raise ValueError(
            f"substrate {sub.name!r} executes {have} parties but the "
            f"session declares {parties}")
    return sub
