"""Execution substrates: where the federated protocol runs.

The protocol bodies (core/tree.py, core/prediction.py, core/fedlinear.py)
are written once against the ``parties`` axis name; a Substrate decides how
that axis is realized:

  * ``SimulatedSubstrate`` — vmap on one host (core/protocol.run_simulated).
    The CPU test/benchmark path; collectives have identical semantics.
  * ``ShardedSubstrate``   — shard_map over a mesh whose "parties" axis is
    the protocol axis (core/protocol.run_sharded).  The production / dry-run
    path: one party per shard, optional "trees" axis for bagging
    tree-parallelism.

Every lifecycle surface (Federation.fit/predict/serve, ForestServer, the
launch CLIs) resolves its substrate exactly once through
``resolve_substrate`` — this module is the single owner of the
vmap-vs-shard_map wiring that used to be re-implemented per entrypoint.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Protocol, runtime_checkable

import jax
from jax.sharding import Mesh

from repro.core import protocol
from repro.core.types import PARTY_AXIS


@runtime_checkable
class Substrate(Protocol):
    """Where SPMD party programs execute (duck-typed; see the two impls)."""

    name: str
    mesh: Mesh | None

    def program(self, fn: Callable, n_party: int, n_shared: int, *,
                shared_specs=None, out_specs=None) -> Callable: ...

    def jit(self, fn: Callable, n_party: int, n_shared: int, **kw) -> Callable: ...

    def context(self): ...


class SimulatedSubstrate:
    """M parties on one host under vmap — semantically the distributed run."""

    name = "simulated"
    mesh = None

    def program(self, fn: Callable, n_party: int, n_shared: int, *,
                shared_specs=None, out_specs=None) -> Callable:
        """Callable over (party_args..., shared_args...); sharding specs are
        accepted (and ignored) so callers can stay substrate-agnostic."""
        def run(*args):
            return protocol.run_simulated(
                fn, args[:n_party], args[n_party:n_party + n_shared])
        return run

    def jit(self, fn: Callable, n_party: int, n_shared: int, **kw) -> Callable:
        return jax.jit(self.program(fn, n_party, n_shared, **kw))

    def context(self):
        return contextlib.nullcontext()


class ShardedSubstrate:
    """shard_map over a mesh axis literally named "parties" (one party per
    shard).  A "trees" axis, if present, carries bagging tree-parallelism —
    forest programs shard their per-tree args/outputs over it."""

    name = "sharded"

    def __init__(self, mesh: Mesh):
        if PARTY_AXIS not in mesh.axis_names:
            raise ValueError(
                f"sharded substrate needs a '{PARTY_AXIS}' mesh axis, got "
                f"{mesh.axis_names}")
        self.mesh = mesh

    @property
    def n_parties(self) -> int:
        return int(self.mesh.shape[PARTY_AXIS])

    @property
    def tree_axis(self) -> str | None:
        return "trees" if "trees" in self.mesh.axis_names else None

    def program(self, fn: Callable, n_party: int, n_shared: int, *,
                shared_specs=None, out_specs=None) -> Callable:
        return protocol.sharded_program(fn, self.mesh, n_party, n_shared,
                                        shared_specs=shared_specs,
                                        out_specs=out_specs)

    def jit(self, fn: Callable, n_party: int, n_shared: int, **kw) -> Callable:
        return jax.jit(self.program(fn, n_party, n_shared, **kw))

    def context(self):
        """Mesh context for lowering (resolves in-program sharding names)."""
        from repro import compat
        return compat.set_mesh(self.mesh)


def default_substrate(sub: Substrate | None = None) -> Substrate:
    """The substrate an estimator runs on when none was injected: vmap
    simulation.  Single owner of the estimators' fallback wiring."""
    return sub if sub is not None else SimulatedSubstrate()


def resolve_substrate(spec: str | Substrate | Any, mesh: Mesh | None = None,
                      parties: int | None = None) -> Substrate:
    """One-time substrate resolution for a session or server.

    ``spec`` is "simulated", "sharded" (mesh required), or an already-built
    Substrate (passed through).  ``parties``, when given, is validated
    against a sharded mesh's party-axis size.
    """
    if isinstance(spec, str):
        if spec == "simulated":
            sub = SimulatedSubstrate()
        elif spec == "sharded":
            if mesh is None:
                raise ValueError("substrate='sharded' requires a mesh")
            sub = ShardedSubstrate(mesh)
        else:
            raise ValueError(f"unknown substrate {spec!r} "
                             "(expected 'simulated', 'sharded', or a "
                             "Substrate)")
    elif isinstance(spec, Substrate):   # any conforming implementation
        sub = spec
    else:
        raise ValueError(f"unknown substrate {spec!r} "
                         "(expected 'simulated', 'sharded', or a Substrate)")
    if parties is not None and sub.mesh is not None \
            and int(sub.mesh.shape[PARTY_AXIS]) != parties:
        raise ValueError(
            f"mesh has {sub.mesh.shape[PARTY_AXIS]} '{PARTY_AXIS}' shards "
            f"but the session declares {parties} parties")
    return sub
