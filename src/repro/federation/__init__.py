"""Unified Federation session API — one substrate-aware entrypoint for the
whole federated lifecycle (fit / predict / serve / checkpoint).

    from repro.federation import Federation
    fed = Federation(parties=4)
    fed.ingest(party_blocks)      # PartyBlocks: hashed-ID align + local bin
    fed.ingest(x_train, y_train)  # or the pre-aligned raw-matrix adapter
    model = fed.fit(ForestParams(n_estimators=20, max_depth=8))
    preds = fed.predict(model, x_test)
    server = fed.serve(model)

Layers:
  * ``substrate``  — Substrate protocol + registry (SimulatedSubstrate vmap /
    ShardedSubstrate shard_map / DistributedSubstrate party-per-process);
    resolved once per session through ``resolve_substrate``.
  * ``transport``  — length-prefixed msgpack wire protocol, retry/backoff,
    circuit breaker (the distributed substrate's fault-tolerance layer).
  * ``distributed`` / ``party_worker`` — coordinator + per-party worker
    processes speaking the transport protocol.
  * ``programs``   — substrate-specialized fit/predict closures shared by
    the session, the serving engine, and the dry-run hillclimb.
  * ``estimator``  — the Estimator protocol every model family conforms to
    (forest, boosting, F-LR).
  * ``session``    — the Federation object that owns all of the above.
"""
from repro.federation.estimator import Estimator, FittedModel  # noqa: F401
from repro.federation.session import Federation  # noqa: F401
from repro.federation.substrate import (Substrate, SimulatedSubstrate,  # noqa: F401
                                        ShardedSubstrate, SUBSTRATES,
                                        register_substrate, resolve_substrate)
