"""The shared Estimator protocol every federated model conforms to.

``Federation.fit`` returns a *fitted model handle* — the estimator instance
itself, carrying its learned state (``trees_`` for forests, per-round trees
for boosting, weight blocks for F-LR).  All of them speak the same minimal
surface, so session code (and user code) never branches on model family:

  * ``fit(partition, y)``  — train on a VerticalPartition (core/party.py);
    returns self.
  * ``predict(x_test)``    — predict raw feature rows (N_t, F); the model
    re-bins / re-splits through the partition it was fitted with.

Conformance is asserted in tests/test_federation.py for
FederatedForest, FederatedBoosting, and FederatedLinear.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Estimator(Protocol):
    """Minimal train/infer surface of a federated model."""

    def fit(self, partition: Any, y: np.ndarray) -> "Estimator": ...

    def predict(self, x_test: np.ndarray) -> np.ndarray: ...


# A fitted model handle IS the estimator instance with learned state attached
# (Federation.fit's return type).
FittedModel = Estimator
