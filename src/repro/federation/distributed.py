"""Party-per-process federation: the transport-backed DistributedSubstrate.

The third Substrate implementation.  Each party is its own OS process
(federation/party_worker.py) holding its own data; a coordinator in the
session process drives the protocol over framed msgpack sockets
(federation/transport.py):

  * **fit** — the per-level split exchange of core/tree.build_tree, run as
    real messages: every party computes its local best splits (the same
    jitted split-search kernels the in-process substrates run), the
    coordinator performs the paper's master reduce (core.tree.reduce_level)
    on the gathered bests, and one psum per level broadcasts the
    owner-computed partition bits.  Integer routing state advances in exact
    numpy arithmetic, so the built PartyTree is bit-identical to
    SimulatedSubstrate on the same seeds.
  * **predict/serve** — the one-round masked-leaf collective (Prop. 1): each
    worker emits its leaf-membership mask, a single psum intersects them,
    and every party votes locally.
  * **ingest** — the hashed-ID alignment handshake of align_party_blocks
    over the same channel: workers load their own blocks, ship salted
    SHA-256 hashes only, the coordinator intersects them, and parties bin
    locally.  Raw sample IDs and raw features never leave a party; only
    hashed IDs, binned values, and masked statistics cross the wire.

Fault tolerance rides on transport primitives: per-round-trip timeout
budgets (PartyTimeout), retry with jittered exponential backoff
(RetryPolicy), a per-party circuit breaker (CircuitOpenError after K
consecutive failures), health-check pings, and an injectable chaos hook
(drop/delay/kill one party's next run) that the fault tests use to prove
each behavior deterministically.  Serving degradation — answering from the
trees whose split paths avoid a dead party — is :func:`surviving_trees`
plus a predict program scoped to the live parties (serving/engine.py).

Collective semantics match the in-graph substrates exactly: gathers stack
party payloads in ascending party order (= ``lax.all_gather``); psums use
``np.add.reduce(stack, axis=0, dtype=payload.dtype)``, which preserves the
payload dtype like an XLA psum (a uint8 membership mask stays uint8).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import multiprocessing
import os
import socket
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis import runtime as egress_runtime
from repro.core import crypto, impurity, tree
from repro.core.party import VerticalPartition, _pad_groups
from repro.core.partyblock import (CSVSource, DataSource, PartyBlock,
                                   feature_groups)
from repro.core.tree import PartyTree
from repro.core.types import PARTY_AXIS, ForestParams
from repro.federation import transport
from repro.observability import trace as tracing
from repro.federation.transport import (CircuitBreaker, PartyDead,
                                        PartyTimeout, PartyUnavailableError,
                                        ProtocolError, RetryPolicy)

transport.register_namedtuple(PartyTree)


class RunAborted(Exception):
    """Coordinator superseded this run (timeout elsewhere, retry incoming)."""


# ------------------------------------------------------------------ worker comm
class Comm:
    """Worker-side collective endpoint for one run.

    The distributed twin of the SPMD axis primitives: ``all_gather`` /
    ``psum`` send one ``coll`` message and block for the coordinator's
    combined ``coll_result``.  Messages from superseded runs are skipped;
    an ``abort`` for the current run raises :class:`RunAborted`."""

    def __init__(self, channel, run_id, party_index: int, n_parties: int):
        self.channel = channel
        self.run_id = run_id
        self.party_index = int(party_index)
        self.n_parties = int(n_parties)
        self._seq = 0

    def _round(self, kind: str, arrays) -> list:
        arrays = [np.asarray(a) for a in arrays]
        with tracing.TRACER.span(f"coll.{kind}", category="comm",
                                 seq=self._seq):
            return self._round_inner(kind, arrays)

    def _round_inner(self, kind: str, arrays) -> list:
        self.channel.send({"op": "coll", "run": self.run_id,
                           "seq": self._seq, "kind": kind, "data": arrays})
        while True:
            msg = self.channel.recv(None)
            op = msg.get("op")
            if op in ("shutdown",):
                raise RunAborted
            if op == "abort":
                if msg.get("run") == self.run_id:
                    raise RunAborted
                continue
            if msg.get("run") != self.run_id:
                continue                      # superseded-run stragglers
            if op != "coll_result" or msg.get("seq") != self._seq:
                raise ProtocolError(
                    f"expected coll_result seq {self._seq}, got "
                    f"{op} seq {msg.get('seq')}")
            self._seq += 1
            return msg["data"]

    def all_gather(self, *arrays):
        """Stacked (M, ...) payloads in party order, like lax.all_gather."""
        out = self._round("gather", arrays)
        return out[0] if len(arrays) == 1 else out

    def psum(self, *arrays):
        """Dtype-preserving sum over parties, like lax.psum."""
        out = self._round("psum", arrays)
        return out[0] if len(arrays) == 1 else out


# ------------------------------------------------------------- program registry
DIST_PROGRAMS: dict[str, Callable] = {}


def register_program(name: str):
    """Register a worker-side protocol body: body(comm, payload, *args)."""
    def deco(fn):
        DIST_PROGRAMS[name] = fn
        return fn
    return deco


# --------------------------------------------------------- forest fit protocol
@functools.partial(jax.jit, static_argnames=("off", "width", "cap", "params",
                                             "hist_impl", "search"))
def _level_search(xb_i32, node, wstats, fmask, feat_gid, *, off, width, cap,
                  params: ForestParams, hist_impl, search):
    """One level's party-local compute — the same kernels build_tree jits."""
    nil = node - off
    in_lvl = (nil >= 0) & (nil < width)
    seg = jnp.where(in_lvl, nil, -1)
    dump = jnp.where(seg >= 0, seg, width)
    c = wstats.shape[-1]
    nstats = jnp.zeros((width + 1, c), jnp.float32).at[dump].add(wstats)[:width]
    cnt = impurity.count_of(nstats, params.task)
    if not search:
        return nstats, cnt
    if params.frontier_cap and cap < width:
        g, gid, bin_, floc = tree._split_search_frontier(
            xb_i32, seg, wstats, fmask, feat_gid, width, cap, params,
            hist_impl)
    else:
        (g, gid, bin_, floc), _ = tree._split_search_dense(
            xb_i32, seg, wstats, fmask, feat_gid, width, params, hist_impl,
            None)
    return nstats, cnt, g, gid, bin_, floc


def _fit_tree(comm: Comm, xb_np, xb_dev, feat_gid_dev, fmask, wstats,
              params: ForestParams, hist_impl: str) -> PartyTree:
    """Level-synchronous build of one tree over the wire.

    Mirrors core/tree.build_tree stage for stage: jitted local split search,
    gather -> reduce_level -> psum as coordinator round trips, and the
    shared integer routing state advanced in exact numpy arithmetic."""
    n = xb_np.shape[0]
    c = wstats.shape[-1]
    nn = params.n_nodes
    me = comm.party_index
    wstats_dev = jnp.asarray(wstats)
    fmask_dev = jnp.asarray(fmask)

    node = np.zeros((n,), np.int32)
    is_leaf = np.zeros((nn,), bool)
    leaf_stats = np.zeros((nn, c), np.float32)
    has_split = np.zeros((nn,), bool)
    split_floc = np.full((nn,), -1, np.int32)
    split_bin = np.full((nn,), -1, np.int32)
    owner = np.full((nn,), -1, np.int32)
    split_gid = np.full((nn,), -1, np.int32)

    for d in range(params.max_depth + 1):
        level_span = tracing.TRACER.begin("fit.level", category="compute",
                                          level=d)
        off, width = params.level_slice(d)
        cap = min(width, n, params.frontier_cap or width)
        last = d == params.max_depth
        res = _level_search(xb_dev, jnp.asarray(node), wstats_dev, fmask_dev,
                            feat_gid_dev, off=off, width=width, cap=cap,
                            params=params, hist_impl=hist_impl,
                            search=not last)
        if last:                    # bottom level: everything alive is a leaf
            nstats, cnt = (np.asarray(r) for r in res)
            leaf_stats[off:off + width] = nstats
            is_leaf[off:off + width] = cnt > 0
            tracing.TRACER.finish(level_span)
            break
        nstats, cnt, g_loc, gid_loc, bin_loc, floc_loc = (
            np.asarray(r) for r in res)
        leaf_stats[off:off + width] = nstats

        # the paper's master: gather -> reduce -> notify, as round trips
        g_all, gid_all, bin_all = comm.all_gather(g_loc, gid_loc, bin_loc)
        do_split, owner_lv, gid_best, bin_best = (
            np.asarray(a) for a in tree.reduce_level(
                jnp.asarray(g_all), jnp.asarray(gid_all),
                jnp.asarray(bin_all), jnp.asarray(cnt), params))
        is_leaf[off:off + width] = (cnt > 0) & ~do_split
        mine = do_split & (owner_lv == me)
        has_split[off:off + width] = mine
        split_floc[off:off + width] = np.where(mine, floc_loc, -1)
        split_bin[off:off + width] = np.where(mine, bin_loc, -1)
        owner[off:off + width] = np.where(do_split, owner_lv, -1)
        split_gid[off:off + width] = np.where(do_split, gid_best, -1)

        # owner computes the partition; one psum broadcasts it
        nil = node - off
        in_lvl = (nil >= 0) & (nil < width)
        nil_c = np.clip(nil, 0, width - 1)
        floc_lv = np.where(mine, floc_loc, 0)
        bin_lv = np.where(mine, bin_loc, 0)
        mine_s = in_lvl & mine[nil_c]
        vals = np.take_along_axis(xb_np, floc_lv[nil_c][:, None], axis=1)[:, 0]
        go_r_loc = np.where(mine_s, (vals > bin_lv[nil_c]).astype(np.int32),
                            np.int32(0))
        go_r = comm.psum(go_r_loc)
        advance = in_lvl & do_split[nil_c]
        node = np.where(advance, 2 * node + 1 + go_r, node).astype(np.int32)
        tracing.TRACER.finish(level_span)

    return PartyTree(is_leaf, leaf_stats, has_split, split_floc, split_bin,
                     owner, split_gid)


@register_program("forest_fit")
def _forest_fit_body(comm: Comm, payload, xb, feat_gid, feat_sels, weights,
                     y_stats):
    """Per-party fit body: one _fit_tree per bagging round, fields stacked."""
    params = ForestParams(**payload["params"])
    if params.hist_subtraction:
        raise NotImplementedError(
            "hist_subtraction threads parent histograms through the level "
            "loop — in-process substrates only")
    hist_impl = payload.get("hist_impl") or params.hist_impl
    xb_np = np.asarray(xb).astype(np.int32)
    feat_gid = np.asarray(feat_gid, np.int32)
    feat_sels = np.asarray(feat_sels)
    weights = np.asarray(weights, np.float32)
    y_stats = np.asarray(y_stats, np.float32)
    xb_dev = jnp.asarray(xb_np)
    feat_gid_dev = jnp.asarray(feat_gid)

    trees_out = []
    for t in range(feat_sels.shape[0]):
        fmask = (feat_gid >= 0) & feat_sels[t][np.clip(feat_gid, 0, None)]
        wstats = y_stats * weights[t][:, None]
        trees_out.append(_fit_tree(comm, xb_np, xb_dev, feat_gid_dev, fmask,
                                   wstats, params, hist_impl))
    return jax.tree.map(lambda *xs: np.stack(xs), *trees_out)


# ----------------------------------------------------- forest predict protocol
@functools.partial(jax.jit, static_argnames=("params", "mask_dtype"))
def _membership_dense(trees, xbt, *, params: ForestParams, mask_dtype):
    from repro.core import prediction
    mem = lax.map(lambda tr: prediction.tree_leaf_membership(tr, xbt, params),
                  trees)
    return mem.astype(mask_dtype), prediction.masked_leaf_stats(trees)


@functools.partial(jax.jit, static_argnames=("params", "mask_dtype"))
def _membership_compact(trees, xbt, leaf_idx, *, params: ForestParams,
                        mask_dtype):
    from repro.core import prediction

    def one(args):
        tr, idx = args
        return prediction.tree_leaf_membership_compact(tr, xbt, params, idx)

    mem = lax.map(one, (trees, leaf_idx))
    return mem.astype(mask_dtype), prediction.gather_leaf_stats(trees,
                                                               leaf_idx)


@functools.partial(jax.jit, static_argnames=("params", "vote_impl",
                                             "n_active"))
def _vote_local(m, leaf, *, params: ForestParams, vote_impl, n_active):
    from repro.core import prediction
    inter = m == jnp.asarray(n_active, m.dtype)     # Prop. 1 intersection
    return prediction._combine_votes(inter, leaf, params, True, vote_impl)


@register_program("forest_predict")
def _forest_predict_body(comm: Comm, payload, trees, xbt, leaf_idx=None):
    """The one-round protocol: local membership, ONE psum, local vote."""
    params = ForestParams(**payload["params"])
    mask_dtype = payload.get("mask_dtype") or "int32"
    vote_impl = payload.get("vote_impl", "einsum")
    trees = jax.tree.map(jnp.asarray, trees)
    xbt = jnp.asarray(np.asarray(xbt))
    if payload.get("compact") and leaf_idx is not None:
        mem, leaf = _membership_compact(trees, xbt, jnp.asarray(leaf_idx),
                                        params=params, mask_dtype=mask_dtype)
    else:
        mem, leaf = _membership_dense(trees, xbt, params=params,
                                      mask_dtype=mask_dtype)
    m = comm.psum(np.asarray(mem))
    out = _vote_local(jnp.asarray(m), leaf, params=params,
                      vote_impl=vote_impl, n_active=comm.n_parties)
    return np.asarray(out)


# ------------------------------------------------------- linear / toy protocol
@jax.jit
def _linear_dot(x_i, w_i):
    return x_i @ w_i


@register_program("linear_predict")
def _linear_predict_body(comm: Comm, payload, x_i, w_i, b):
    """F-LR joint logit: z = psum_i(X_i w_i) + b, thresholded per task."""
    z_loc = np.asarray(_linear_dot(jnp.asarray(np.asarray(x_i, np.float32)),
                                   jnp.asarray(np.asarray(w_i, np.float32))))
    z = comm.psum(z_loc) + np.float32(np.asarray(b))
    if payload["task"] == "classification":
        return (z > 0).astype(np.int32)
    return np.asarray(z, np.float32)


@register_program("toy_affine")
def _toy_affine_body(comm: Comm, payload, x, scale):
    """Conformance-suite protocol: exercises both collectives in int32."""
    x = np.asarray(x)
    g = comm.all_gather(x)
    s = comm.psum((x * scale).astype(x.dtype))
    return (g.sum(0, dtype=x.dtype) + s
            + np.asarray(comm.party_index, x.dtype))


def toy_affine_fn(x, scale):
    """The in-graph twin of the toy protocol, for vmap/shard_map substrates —
    the conformance suite asserts bit-identity of the two on every
    registered substrate."""
    g = lax.all_gather(x, PARTY_AXIS)
    s = lax.psum((x * scale).astype(x.dtype), PARTY_AXIS)
    return (g.sum(0, dtype=x.dtype) + s
            + lax.axis_index(PARTY_AXIS).astype(x.dtype))


# ----------------------------------------------------------------- spec builders
def forest_fit_spec(params: ForestParams, hist_impl: str | None = None):
    return {"name": "forest_fit",
            "payload": {"params": dataclasses.asdict(params),
                        "hist_impl": hist_impl},
            "bound": ()}


def forest_predict_spec(params: ForestParams, *, compact=False,
                        mask_dtype=jnp.int32, vote_impl="einsum"):
    # bound argnums: trees (0, party arg) and leaf_idx (2, shared) are the
    # model-side operands the serving engine ships once per executable.
    return {"name": "forest_predict",
            "payload": {"params": dataclasses.asdict(params),
                        "compact": bool(compact),
                        "mask_dtype": np.dtype(mask_dtype).name,
                        "vote_impl": vote_impl},
            "bound": (0, 2)}


def linear_predict_spec(task: str):
    return {"name": "linear_predict", "payload": {"task": task},
            "bound": (1, 2)}


def toy_affine_spec():
    return {"name": "toy_affine", "payload": {}, "bound": ()}


# ---------------------------------------------------------- degraded serving
def surviving_trees(trees, dead_parties) -> np.ndarray:
    """Indices of trees whose split paths avoid every dead party's features.

    A tree where a dead party owns no splits descends both branches at that
    party's (nonexistent) nodes, so its membership mask over the surviving
    parties intersects to exactly the full-federation leaf assignment —
    predictions from these trees are exact, not approximate."""
    owner = np.asarray(trees.owner)
    if owner.ndim == 3:                       # (M, T, nn) party stack
        owner = owner[0]                      # owner is the shared master view
    dead = np.asarray(sorted(set(int(p) for p in dead_parties)))
    if dead.size == 0:
        return np.arange(owner.shape[0])
    hit = np.isin(owner, dead) & (owner >= 0)
    return np.flatnonzero(~hit.any(axis=1))


# ------------------------------------------------------------------ coordinator
def _worker_entry(host, port, index, src_root):
    import sys
    if src_root and src_root not in sys.path:
        sys.path.insert(0, src_root)
    from repro.federation.party_worker import worker_main
    worker_main(host, port, index)


class Coordinator:
    """Session-side driver: spawns one worker process per party, relays the
    collectives, and owns the fault-tolerance state (retry policy, breaker,
    dead-party set)."""

    def __init__(self, parties: int, *, host: str = "127.0.0.1",
                 round_timeout: float = 120.0, connect_timeout: float = 30.0,
                 retry: RetryPolicy | None = None, breaker_threshold: int = 3):
        self.n_parties = int(parties)
        self.round_timeout = float(round_timeout)
        self.connect_timeout = float(connect_timeout)
        self.retry = retry or RetryPolicy()
        self.breaker = CircuitBreaker(breaker_threshold)
        self._host = host
        self.channels: dict[int, transport.Channel] = {}
        self._procs: list = []
        self._dead: set[int] = set()
        self._nonce = 0
        self._run_id = 0
        self._bind_id = 0
        self._started = False

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._started:
            return
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.bind((self._host, 0))
        srv.listen(self.n_parties)
        host, port = srv.getsockname()
        src_root = str(Path(__file__).resolve().parents[2])
        ctx = multiprocessing.get_context("spawn")
        old_pp = os.environ.get("PYTHONPATH")
        os.environ["PYTHONPATH"] = (src_root if not old_pp
                                    else src_root + os.pathsep + old_pp)
        try:
            for i in range(self.n_parties):
                p = ctx.Process(target=_worker_entry,
                                args=(host, port, i, src_root), daemon=True)
                p.start()
                self._procs.append(p)
        finally:
            if old_pp is None:
                os.environ.pop("PYTHONPATH", None)
            else:
                os.environ["PYTHONPATH"] = old_pp
        srv.settimeout(self.connect_timeout)
        try:
            for _ in range(self.n_parties):
                sock, _addr = srv.accept()
                ch = transport.Channel(sock)
                hello = ch.recv(timeout=self.connect_timeout)
                if hello.get("op") != "hello":
                    raise ProtocolError(f"expected hello, got {hello}")
                idx = int(hello["party"])
                ch.party = idx
                self.channels[idx] = ch
        except (socket.timeout, TimeoutError) as e:
            self.shutdown()
            raise PartyDead(
                f"not all {self.n_parties} party workers connected within "
                f"{self.connect_timeout:.0f}s") from e
        finally:
            srv.close()
        self._started = True

    def shutdown(self) -> None:
        for p, ch in list(self.channels.items()):
            if p not in self._dead:
                try:
                    ch.send({"op": "shutdown"})
                except transport.TransportError:
                    pass
            ch.close()
        self.channels.clear()
        for proc in self._procs:
            proc.join(timeout=5.0)
            if proc.is_alive():
                proc.terminate()
        self._procs.clear()
        self._started = False

    # ----------------------------------------------------------------- plumbing
    def next_run_id(self) -> int:
        self._run_id += 1
        return self._run_id

    def new_bind_id(self) -> int:
        self._bind_id += 1
        return self._bind_id

    def _mark_failure(self, p: int, e: Exception) -> None:
        if isinstance(e, PartyDead):
            self._dead.add(p)
            ch = self.channels.get(p)
            if ch is not None:
                ch.close()

    def _send(self, p: int, msg: dict) -> None:
        if p in self._dead:
            raise PartyDead(f"party {p}: process is gone", parties=(p,))
        try:
            self.channels[p].send(msg)
        except PartyUnavailableError as e:
            self._mark_failure(p, e)
            raise

    def _recv_run(self, p: int, rid) -> dict:
        ch = self.channels[p]
        deadline = time.monotonic() + self.round_timeout
        while True:
            left = deadline - time.monotonic()
            if left <= 0:
                raise PartyTimeout(
                    f"party {p}: no protocol message within the "
                    f"{self.round_timeout:.1f}s round budget", parties=(p,))
            try:
                msg = ch.recv(timeout=left)
            except PartyUnavailableError as e:
                self._mark_failure(p, e)
                raise
            if msg.get("run") == rid and msg.get("op") in ("coll", "result",
                                                           "error"):
                return msg
            # anything else is superseded-run traffic or a late ack: skip

    def _abort(self, rid, active) -> None:
        for p in active:
            if p in self._dead:
                continue
            try:
                self.channels[p].send({"op": "abort", "run": rid})
            except transport.TransportError:
                self._mark_failure(p, PartyDead(f"party {p}", parties=(p,)))

    # -------------------------------------------------------------- run loop
    def run_once(self, rid, msgs: dict[int, dict], active) -> dict[int, Any]:
        """Drive one protocol run to completion: relay every collective
        round, return per-party results.  Raises PartyTimeout/PartyDead with
        the failure attributed to a party (after aborting the others)."""
        try:
            for p in active:
                self._send(p, msgs[p])
            while True:
                with tracing.TRACER.span("round", category="comm",
                                         rid=rid) as rspan:
                    got = {p: self._recv_run(p, rid) for p in active}
                    ops = {m["op"] for m in got.values()}
                    if "error" in ops:
                        bad = next(p for p, m in got.items()
                                   if m["op"] == "error")
                        self._abort(rid, active)
                        m = got[bad]
                        raise RuntimeError(
                            f"party {bad} failed in {msgs[bad]['name']!r}: "
                            f"{m.get('message')}\n{m.get('traceback', '')}")
                    if ops == {"result"}:
                        rspan.set(kind="result")
                        return {p: m["data"] for p, m in got.items()}
                    if ops != {"coll"}:
                        self._abort(rid, active)
                        raise ProtocolError(
                            f"mixed protocol messages {ops}")
                    seqs = {m["seq"] for m in got.values()}
                    kinds = {m["kind"] for m in got.values()}
                    if len(seqs) != 1 or len(kinds) != 1:
                        self._abort(rid, active)
                        raise ProtocolError(
                            f"desynchronized collective (seq {seqs}, "
                            f"kind {kinds})")
                    kind, seq = kinds.pop(), seqs.pop()
                    rspan.set(kind=kind, seq=seq)
                    n_arr = len(got[active[0]]["data"])
                    combined = []
                    for j in range(n_arr):
                        stack = np.stack([np.asarray(got[p]["data"][j])
                                          for p in active])
                        combined.append(
                            stack if kind == "gather"
                            else np.add.reduce(stack, axis=0,
                                               dtype=stack.dtype))
                    reply = {"op": "coll_result", "run": rid, "seq": seq,
                             "data": combined}
                    for p in active:
                        self._send(p, reply)
        except PartyUnavailableError as e:
            # abort EVERY active party, including the one the failure is
            # attributed to: a slow-but-alive party must learn its run was
            # superseded, or it will block on a coll_result that never
            # comes and swallow the next run's message as stale traffic
            # (_abort already skips dead parties and eats transport errors)
            self._abort(rid, active)
            raise

    def run_retrying(self, build_msgs, active) -> dict[int, Any]:
        """run_once under the retry policy + circuit breaker.

        Transport failures (timeout/dead) are retried with jittered
        exponential backoff and charged to the breaker; protocol-body
        exceptions (RuntimeError from a worker traceback) are not — a bug
        does not become less buggy on retry."""
        active = list(active)
        last: PartyUnavailableError | None = None
        for attempt in range(self.retry.attempts):
            for p in active:
                self.breaker.allow(p)         # raises CircuitOpenError
            rid = self.next_run_id()
            msgs = build_msgs(rid)
            name = msgs[active[0]]["name"] if active else "?"
            try:
                with tracing.TRACER.span(f"run.{name}", category="host",
                                         rid=rid, attempt=attempt):
                    out = self.run_once(rid, msgs, active)
            except PartyUnavailableError as e:
                last = e
                for p in (e.parties or active):
                    self.breaker.record_failure(p)
                if attempt + 1 < self.retry.attempts:
                    self.retry.backoff(attempt)
                continue
            for p in active:
                self.breaker.record_success(p)
            return out
        raise last

    # ------------------------------------------------------ request/response
    def request(self, p: int, msg: dict, *, timeout: float | None = None) -> dict:
        """One out-of-band round trip (ping/chaos/bind/ingest ops), matched
        on an echoed nonce so stale run traffic cannot satisfy it."""
        if p in self._dead:
            raise PartyDead(f"party {p}: process is gone", parties=(p,))
        self._nonce += 1
        n = self._nonce
        ch = self.channels[p]
        try:
            ch.send(dict(msg, nonce=n))
            deadline = time.monotonic() + (timeout or self.round_timeout)
            while True:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise PartyTimeout(
                        f"party {p}: no reply to {msg.get('op')!r}",
                        parties=(p,))
                reply = ch.recv(timeout=left)
                if reply.get("nonce") != n:
                    continue
                if reply.get("op") == "error":
                    raise RuntimeError(
                        f"party {p}: {reply.get('message')}")
                return reply
        except PartyUnavailableError as e:
            self._mark_failure(p, e)
            raise

    def health(self, timeout: float = 2.0) -> dict[int, float | None]:
        """Ping every party; latency in seconds, None for the unreachable.
        Reads do not feed the circuit breaker — health is observation."""
        out: dict[int, float | None] = {}
        for p in range(self.n_parties):
            if p in self._dead or p not in self.channels:
                out[p] = None
                continue
            t0 = time.perf_counter()
            try:
                r = self.request(p, {"op": "ping"}, timeout=timeout)
                out[p] = (time.perf_counter() - t0
                          if r.get("op") == "pong" else None)
            except (PartyUnavailableError, RuntimeError):
                out[p] = None
        return out

    def chaos(self, party: int, mode: str, seconds: float = 0.0) -> None:
        """Arm a one-shot fault at a worker: its NEXT run message is dropped
        (``drop_run``), delayed (``delay_run``), or kills the process
        (``die``).  The fault-injection tests' entry point."""
        self.request(party, {"op": "chaos", "mode": mode, "seconds": seconds})

    def unavailable_parties(self) -> tuple[int, ...]:
        return tuple(sorted(self._dead | set(self.breaker.open_parties())))


# ------------------------------------------------------------------- ingest
def _source_spec(src) -> dict:
    if isinstance(src, CSVSource):
        return {"kind": "csv", **dataclasses.asdict(src)}
    if isinstance(src, PartyBlock):
        return {"kind": "block", "name": src.name, "x": src.x,
                "ids": src.ids, "y": src.y, "feature_ids": src.feature_ids,
                "feature_names": (list(src.feature_names)
                                  if src.feature_names else None)}
    if isinstance(src, DataSource):
        raise TypeError(
            f"cannot ship a {type(src).__name__} to a party worker — "
            f"distributed ingest takes CSVSource (loaded party-side) or a "
            f"materialized PartyBlock")
    raise TypeError(f"expected PartyBlock or CSVSource, got "
                    f"{type(src).__name__}")


def distributed_ingest(coord: Coordinator, sources, n_bins: int, *,
                       salt: str = crypto.DEFAULT_SALT,
                       validate: bool = False):
    """partition_from_blocks over the wire: load at the parties, align on
    hashed IDs only, bin party-locally, assemble the stacked partition.

    Mirrors the in-process path decision for decision (canonical sorted-name
    party order, pre-aligned fast path, sorted-hash common ordering,
    feature-id partition checks, exactly-one-label-holder), so the returned
    partition is bit-identical to central ingestion of the same blocks.
    ``common_ids`` holds the HASHED ids — raw IDs never reach the
    coordinator."""
    if validate:
        raise ValueError(
            "validate=True re-bins the assembled central matrix, which the "
            "distributed substrate never holds — validate on an in-process "
            "substrate instead")
    sources = list(sources)
    if len(sources) != coord.n_parties:
        raise ValueError(f"expected {coord.n_parties} party sources, got "
                         f"{len(sources)}")
    # Provisioning is the one sanctioned raw flow: each in-memory source is
    # shipped to ITS OWN party's worker process — the same trust domain, a
    # stand-in for the worker reading its silo's storage directly (CSV
    # sources ship as paths and are read worker-side).  The static
    # suppression below and the runtime allow_egress() are a deliberate
    # pair; see analysis/policy.py.
    with egress_runtime.allow_egress(
            "provisioning: a party's own block to its own worker"):
        metas = [coord.request(w, {"op": "load_block",  # egress: ok(provisioning — party's own raw block to its own worker process, same trust domain)
                                   "source": _source_spec(s)})
                 for w, s in enumerate(sources)]
    names = [m["name"] for m in metas]
    if len(set(names)) != len(names):
        raise ValueError(f"party names must be unique, got {names}")
    order = sorted(range(len(names)), key=lambda w: names[w])

    hashes = [np.asarray(coord.request(w, {"op": "hash_block_ids",
                                           "salt": salt})["hashes"])
              for w in order]
    # per-party uniqueness was validated worker-side (hash_block_ids names
    # the party); align_hashed owns the fast path + loud-error contract
    positions, common = crypto.align_hashed(
        hashes, [names[w] for w in order], check_unique=False)

    groups, n_features = feature_groups(
        [metas[w].get("feature_ids") for w in order],
        [int(metas[w]["n_features"]) for w in order])

    feat_gid = _pad_groups(groups)
    m, fp = feat_gid.shape
    xb = np.zeros((m, len(common), fp), dtype=np.uint8)
    boundaries = np.zeros((n_features, max(n_bins - 1, 0)), dtype=np.float64)
    y, holder = None, None
    for i, w in enumerate(order):
        r = coord.request(w, {"op": "bin_block", "positions": positions[i],
                              "n_bins": n_bins})
        xb_i = np.asarray(r["xb"])
        xb[i, :, : xb_i.shape[1]] = xb_i
        boundaries[groups[i]] = np.asarray(r["boundaries"])
        if r.get("y") is not None:
            if holder is not None:
                raise ValueError(
                    f"labels held by more than one party ({holder!r} and "
                    f"{names[w]!r}); exactly one party owns the labels")
            holder, y = names[w], np.asarray(r["y"])

    part = VerticalPartition(xb=xb, feat_gid=feat_gid,
                             n_features=n_features, boundaries=boundaries,
                             raw_parts=None,
                             party_names=tuple(names[w] for w in order))
    return part, y, common


# --------------------------------------------------------- streaming ingest
def _stream_source_spec(src) -> dict:
    """Wire spec for a chunked source — what ships to a party worker so the
    worker can stream the data *locally*.  CSVs ship as a path (the file
    lives with the party; its raw rows never cross the wire); in-memory
    blocks ship once as arrays (tests / small silos); products ship their
    schema + version around an inner source spec."""
    from repro import streaming
    if isinstance(src, streaming.DataProduct):
        s = src.schema
        return {"kind": "product", "name": src.name,
                "version": int(src.version),
                "schema": {"n_features": int(s.n_features),
                           "feature_ids": (list(s.feature_ids)
                                           if s.feature_ids is not None
                                           else None),
                           "feature_dtype": s.feature_dtype,
                           "id_kind": s.id_kind,
                           "has_labels": bool(s.has_labels)},
                "inner": _stream_source_spec(src.source)}
    if isinstance(src, streaming.ChunkedCSVSource):
        return {"kind": "csv_chunks", **dataclasses.asdict(src)}
    if isinstance(src, CSVSource):
        return {"kind": "csv_chunks", **dataclasses.asdict(src)}
    if isinstance(src, streaming.ArraySource):
        return dict(_source_spec(src.block), kind="block_chunks")
    if isinstance(src, PartyBlock):
        return dict(_source_spec(src), kind="block_chunks")
    if isinstance(src, DataSource):
        raise TypeError(
            f"cannot ship a {type(src).__name__} to a party worker — "
            f"distributed streaming takes chunked CSVs (streamed "
            f"party-side), blocks, or DataProducts over them")
    raise TypeError(f"expected a chunked source, PartyBlock or CSVSource, "
                    f"got {type(src).__name__}")


def stream_source_from_spec(spec: dict):
    """Worker-side inverse of :func:`_stream_source_spec`."""
    from repro import streaming
    kind = spec["kind"]
    if kind == "product":
        s = spec["schema"]
        return streaming.DataProduct(
            name=spec["name"], version=int(spec["version"]),
            source=stream_source_from_spec(spec["inner"]),
            schema=streaming.ProductSchema(
                n_features=int(s["n_features"]),
                feature_ids=(tuple(int(f) for f in s["feature_ids"])
                             if s["feature_ids"] is not None else None),
                feature_dtype=s["feature_dtype"], id_kind=s["id_kind"],
                has_labels=bool(s["has_labels"])))
    if kind == "csv_chunks":
        return streaming.ChunkedCSVSource(
            path=spec["path"], name=spec.get("name"),
            id_column=spec.get("id_column", "id"),
            label_column=spec.get("label_column", "label"),
            delimiter=spec.get("delimiter", ","))
    if kind == "block_chunks":
        names = spec.get("feature_names")
        return streaming.ArraySource(PartyBlock(
            name=spec["name"], x=spec["x"], ids=spec["ids"],
            y=spec.get("y"), feature_ids=spec.get("feature_ids"),
            feature_names=tuple(names) if names else None))
    raise transport.ProtocolError(f"unknown stream source kind {kind!r}")


def distributed_streaming_ingest(coord: Coordinator, sources, n_bins: int, *,
                                 chunk_rows: int, capacity: int,
                                 salt: str = crypto.DEFAULT_SALT,
                                 append: bool = False):
    """Streamed ingest over the wire: each party worker scans and bins its
    own chunks process-side (repro.streaming.PartyStream held at the
    worker); the coordinator sees hashed IDs, sketch-derived boundaries,
    binned values and the aligned labels — never raw features or raw IDs.

    ``append=True`` extends the streams the workers already hold (one new
    source per party, worker order matching the original ingest) and
    re-assembles over the union — the distributed twin of
    ``Federation.ingest_append``.  Returns ``(partition, y, common_hashed)``
    exactly like :func:`distributed_ingest`."""
    sources = list(sources)
    if len(sources) != coord.n_parties:
        raise ValueError(f"expected {coord.n_parties} party sources, got "
                         f"{len(sources)}")
    # provisioning: same sanctioned raw flow as distributed_ingest — each
    # party's own chunked source goes to its own worker (in-memory array
    # sources ship raw; CSV sources ship as paths, read worker-side)
    with egress_runtime.allow_egress(
            "provisioning: a party's own chunked source to its own worker"):
        metas = [coord.request(w, {"op": "stream_scan",  # egress: ok(provisioning — party's own raw chunk source to its own worker process, same trust domain)
                                   "source": _stream_source_spec(s),
                                   "chunk_rows": int(chunk_rows),
                                   "capacity": int(capacity), "salt": salt,
                                   "append": bool(append)})
                 for w, s in enumerate(sources)]
    names = [m["name"] for m in metas]
    if len(set(names)) != len(names):
        raise ValueError(f"party names must be unique, got {names}")
    order = sorted(range(len(names)), key=lambda w: names[w])

    # workers validated per-party ID uniqueness during the scan
    positions, common = crypto.align_hashed(
        [np.asarray(metas[w]["hashes"]) for w in order],
        [names[w] for w in order], check_unique=False)
    groups, n_features = feature_groups(
        [metas[w].get("feature_ids") for w in order],
        [int(metas[w]["n_features"]) for w in order])

    feat_gid = _pad_groups(groups)
    m, fp = feat_gid.shape
    xb = np.zeros((m, len(common), fp), dtype=np.uint8)
    boundaries = np.zeros((n_features, max(n_bins - 1, 0)), dtype=np.float64)
    y, holder = None, None
    for i, w in enumerate(order):
        r = coord.request(w, {"op": "stream_bin", "positions": positions[i],
                              "n_bins": int(n_bins)})
        xb_i = np.asarray(r["xb"])
        xb[i, :, : xb_i.shape[1]] = xb_i
        boundaries[groups[i]] = np.asarray(r["boundaries"])
        if r.get("y") is not None:
            if holder is not None:
                raise ValueError(
                    f"labels held by more than one party ({holder!r} and "
                    f"{names[w]!r}); exactly one party owns the labels")
            holder, y = names[w], np.asarray(r["y"])

    part = VerticalPartition(xb=xb, feat_gid=feat_gid,
                             n_features=n_features, boundaries=boundaries,
                             raw_parts=None,
                             party_names=tuple(names[w] for w in order))
    return part, y, common


# ------------------------------------------------------------------- substrate
class _DistCallable:
    """A distributed protocol program bound to a coordinator.

    Call convention matches the simulated substrate: the first ``n_party``
    args carry a leading (M, ...) party axis (sliced per party before the
    wire), the rest are shared; the output is the per-party result stack.
    ``bind`` ships chosen argnums to the workers once (the serving engine's
    AOT seam) — later calls send None at those positions."""

    def __init__(self, substrate: "DistributedSubstrate", spec: dict,
                 n_party: int, n_shared: int, active=None):
        self.substrate = substrate
        self.spec = dict(spec)
        self.n_party = int(n_party)
        self.n_shared = int(n_shared)
        self.active = (tuple(int(p) for p in active) if active is not None
                       else tuple(range(substrate.n_parties)))
        self._bind_id = None
        self._bound_set: set[int] = set()

    def _slot(self, a, p):
        return jax.tree.map(lambda x: np.asarray(x)[p], a)

    def bind(self, *args) -> "_DistCallable":
        coord = self.substrate.coordinator
        bid = coord.new_bind_id()
        bound = tuple(k for k in (self.spec.get("bound") or ())
                      if k < len(args) and args[k] is not None)
        for p in self.active:
            shipped = {}
            for k in bound:
                shipped[k] = (self._slot(args[k], p) if k < self.n_party
                              else jax.tree.map(np.asarray, args[k]))
            coord.request(p, {"op": "bind", "bind": bid, "args": shipped})
        new = _DistCallable(self.substrate, self.spec, self.n_party,
                            self.n_shared, self.active)
        new._bind_id = bid
        new._bound_set = set(bound)
        return new

    def __call__(self, *args):
        if len(args) > self.n_party + self.n_shared:
            raise TypeError(
                f"{self.spec['name']}: expected at most "
                f"{self.n_party + self.n_shared} args, got {len(args)}")
        coord = self.substrate.coordinator
        active = list(self.active)
        shared = [None if (i + self.n_party) in self._bound_set
                  else jax.tree.map(np.asarray, a)
                  for i, a in enumerate(args[self.n_party:])]

        def build(rid):
            msgs = {}
            for p in active:
                wire = []
                for k, a in enumerate(args):
                    if k in self._bound_set:
                        wire.append(None)
                    elif k < self.n_party:
                        wire.append(self._slot(a, p))
                    else:
                        wire.append(shared[k - self.n_party])
                msgs[p] = {"op": "run", "run": rid,
                           "name": self.spec["name"],
                           "payload": self.spec.get("payload") or {},
                           "args": wire, "bound": self._bind_id,
                           "party_index": p, "n_parties": len(active)}
            return msgs

        outs = coord.run_retrying(build, active)
        return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                            *[outs[p] for p in active])


class DistributedSubstrate:
    """Party-per-process execution: one OS process per party, message-passing
    collectives, production fault tolerance.  Registered as "distributed" in
    the substrate registry; workers spawn lazily on first use."""

    name = "distributed"
    mesh = None
    tree_axis = None

    def __init__(self, parties: int, *, host: str = "127.0.0.1",
                 round_timeout: float = 120.0, connect_timeout: float = 30.0,
                 retry: RetryPolicy | None = None,
                 breaker_threshold: int = 3):
        if parties < 1:
            raise ValueError(f"need at least 1 party, got {parties}")
        self.n_parties = int(parties)
        self._opts = dict(host=host, round_timeout=round_timeout,
                          connect_timeout=connect_timeout, retry=retry,
                          breaker_threshold=breaker_threshold)
        self._coord: Coordinator | None = None

    @property
    def coordinator(self) -> Coordinator:
        if self._coord is None:
            self._coord = Coordinator(self.n_parties, **self._opts)
            self._coord.start()
        return self._coord

    # ----------------------------------------------------- Substrate protocol
    def program(self, fn, n_party: int, n_shared: int, *, shared_specs=None,
                out_specs=None, distributed: dict | None = None,
                parties=None):
        if distributed is None:
            raise NotImplementedError(
                f"{getattr(fn, '__name__', fn)!r} has no distributed "
                f"protocol body — only forest fit/predict, F-LR predict and "
                f"the conformance toy protocol run party-per-process")
        return _DistCallable(self, distributed, n_party, n_shared,
                             active=parties)

    jit = program

    def compile(self, program):
        return program                         # already an executable protocol

    def aot_compile(self, program, *args):
        return program.bind(*args)

    def context(self):
        return contextlib.nullcontext()

    def exchange(self, op: str, payload: dict | None = None, *,
                 party: int | None = None, timeout: float | None = None):
        """Out-of-band request to one party (or all): the transport seam the
        Substrate protocol grew for this implementation."""
        coord = self.coordinator
        msg = dict(payload or {}, op=op)
        if party is not None:
            return coord.request(party, msg, timeout=timeout)
        return {p: coord.request(p, msg, timeout=timeout)
                for p in range(self.n_parties)
                if p not in coord._dead}

    def shutdown(self) -> None:
        if self._coord is not None:
            self._coord.shutdown()
            self._coord = None

    # ------------------------------------------------------------ operations
    def ingest_blocks(self, sources, n_bins: int, *,
                      salt: str = crypto.DEFAULT_SALT,
                      validate: bool = False):
        return distributed_ingest(self.coordinator, sources, n_bins,
                                  salt=salt, validate=validate)

    def ingest_stream(self, sources, n_bins: int, *,
                      salt: str = crypto.DEFAULT_SALT, validate: bool = False,
                      chunk_rows: int, capacity: int, append: bool = False):
        if validate:
            raise ValueError(
                "validate=True re-bins the assembled central matrix, which "
                "the distributed substrate never holds — validate on an "
                "in-process substrate instead")
        return distributed_streaming_ingest(
            self.coordinator, sources, n_bins, chunk_rows=chunk_rows,
            capacity=capacity, salt=salt, append=append)

    def health(self, timeout: float = 2.0):
        return self.coordinator.health(timeout=timeout)

    def collect_telemetry(self) -> dict[int, dict]:
        """Pull each live party's buffered spans + metric snapshot into this
        process: worker spans join the session tracer (so one export covers
        the whole federation) and party metrics merge under a ``party<i>.``
        prefix.  Returns the raw per-party replies.  No-op (empty dict) if
        the coordinator was never started."""
        from repro.observability import registry as _registry
        if self._coord is None:
            return {}
        coord = self._coord
        out: dict[int, dict] = {}
        for p in range(self.n_parties):
            if p in coord._dead or p not in coord.channels:
                continue
            try:
                r = coord.request(p, {"op": "telemetry"})
            except (PartyUnavailableError, RuntimeError):
                continue
            for s in r.get("spans") or ():
                tracing.TRACER.adopt(s)
            _registry.REGISTRY.merge(r.get("metrics") or {},
                                     prefix=f"party{p}.")
            out[p] = {"spans": len(r.get("spans") or ()),
                      "metrics": len(r.get("metrics") or ())}
        return out

    def chaos(self, party: int, mode: str, seconds: float = 0.0):
        self.coordinator.chaos(party, mode, seconds)

    def unavailable_parties(self) -> tuple[int, ...]:
        if self._coord is None:
            return ()
        return self._coord.unavailable_parties()

    def __repr__(self) -> str:
        state = "up" if self._coord is not None else "cold"
        return f"DistributedSubstrate(parties={self.n_parties}, {state})"
