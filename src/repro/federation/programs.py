"""Substrate-specialized forest programs (the fit/predict SPMD closures).

One place builds the runnable/lowerable protocol programs for both
substrates — previously core/forest.py, serving/engine.py, launch/cases.py
and launch/perf.py each hand-rolled this wiring:

  * fit:      party args (xb, feat_gid), shared (feat_sel, weights, y_stats).
    Under a sharded mesh the per-tree shared args and the PartyTree output
    shard over the "trees" axis (bagging tree-parallelism).
  * predict:  the paper's one-round protocol.  Simulated -> every party
    computes the aggregated forest output (vmap keeps the party stack, take
    row 0).  Sharded -> per-tree outputs (aggregate=False hook) with the
    forest vote as the caller-side cross-shard reduction, trees sharded over
    (parties, trees) — exactly the serving engine's production program.

``party0`` normalizes the two output conventions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import prediction, tree
from repro.core.types import PARTY_AXIS, ForestParams


def party0(out):
    """Master-side view of a program output: simulated programs return a
    per-party stack (row 0 = the shared result), sharded predict programs
    return the already-reduced global result."""
    out = np.asarray(out)
    return out[0] if out.ndim > 1 else out


def forest_fit_program(substrate, params: ForestParams,
                       hist_impl: str | None = None, *,
                       tree_sharded: bool = True):
    """fn(xb, feat_gid, feat_sel, weights, y_stats) -> PartyTree stack.

    ``tree_sharded=False`` keeps the per-tree args/outputs replicated across
    a mesh's "trees" axis — for callers whose tree count doesn't divide it
    (boosting fits one tree per round)."""
    if params.needs_resolution:
        raise ValueError(
            "frontier_cap/trees_per_batch='auto' resolve at fit time from "
            "the training set; pass params.resolved(n_samples) to build a "
            "program directly")
    fit_fn = tree.fit_spmd(params, hist_impl)
    if substrate.mesh is None:
        from repro.federation import distributed
        return substrate.program(
            fit_fn, 2, 3,
            distributed=distributed.forest_fit_spec(params, hist_impl))
    tree_ax = substrate.tree_axis if tree_sharded else None
    per_tree = P(tree_ax) if tree_ax else P()
    out = P(PARTY_AXIS, tree_ax) if tree_ax else P(PARTY_AXIS)
    return substrate.program(fit_fn, 2, 3,
                             shared_specs=(per_tree, per_tree, P()),
                             out_specs=out)


def forest_predict_program(substrate, params: ForestParams, *,
                           compact: bool = False, mask_dtype=jnp.int32,
                           vote_impl: str = "einsum",
                           tree_sharded: bool = True,
                           parties=None):
    """fn(trees, xb_test[, leaf_idx]) — the one-round forest prediction.

    ``compact=True`` adds the LeafTable's ``leaf_idx`` as a trailing shared
    arg (bit-identical outputs; psum/vote over live-leaf columns only).
    ``tree_sharded=False``: see forest_fit_program.  ``parties`` restricts
    the protocol to a subset of party indices — the distributed substrate's
    degraded-serving path (in-process substrates always run every party and
    ignore it).
    """
    p = params
    n_shared = 1 if compact else 0

    if substrate.mesh is None:
        def fn(trees, xbt, *shared):
            return prediction.forest_predict_oneround(
                trees, xbt, p, aggregate=True, mask_dtype=mask_dtype,
                vote_impl=vote_impl, leaf_idx=shared[0] if shared else None)
        from repro.federation import distributed
        return substrate.program(
            fn, 2, n_shared,
            distributed=distributed.forest_predict_spec(
                p, compact=compact, mask_dtype=mask_dtype,
                vote_impl=vote_impl),
            parties=parties)

    # Sharded: trees live sharded over (parties, trees); each shard emits its
    # local per-tree outputs and the forest vote reduces across tree shards.
    tree_ax = substrate.tree_axis if tree_sharded else None
    tree_spec = P(PARTY_AXIS, tree_ax) if tree_ax else P(PARTY_AXIS)
    shared_specs = ((P(tree_ax) if tree_ax else P(),) if compact else ())

    def predict_local(tr, xbt, *shared):
        tr = jax.tree.map(lambda a: a[0], tr)               # drop party dim
        out = prediction.forest_predict_oneround(
            tr, xbt[0], p, aggregate=False, mask_dtype=mask_dtype,
            vote_impl=vote_impl, leaf_idx=shared[0] if shared else None)
        return out[None]                                    # (1, T_loc, N)

    from repro import compat
    inner = compat.shard_map(
        predict_local, mesh=substrate.mesh,
        in_specs=(tree_spec, P(PARTY_AXIS)) + shared_specs,
        out_specs=tree_spec, check_vma=False)

    def fn(trees, xbt, *shared):
        per_tree = inner(trees, xbt, *shared)               # (m, T, N)
        if p.task == "classification":
            votes = (per_tree[0][..., None] ==
                     jnp.arange(p.n_classes)[None, None]).sum(0)
            return jnp.argmax(votes, -1)
        return per_tree[0].mean(0)
    return fn


def boosting_predict_program(substrate, params, *, compact: bool = False,
                             mask_dtype=jnp.uint8):
    """fn(trees, xbt, base[, leaf_idx]) — one-wave boosting prediction.

    ``trees`` is the per-round PartyTree stack (leading (M, R, ...) axes,
    core.boosting.stack_rounds); the one-round membership protocol runs with
    ``aggregate=False`` per-round outputs and the boosting reduction
    (base + lr * Σ rounds, thresholded for the binary task) is fused in the
    same program — ONE collective for the whole ensemble, like the forest.
    ``params`` is a BoostParams; ``base`` rides as a shared scalar arg so a
    refreshed model re-binds without recompiling the closure."""
    tp = params.tree_params()
    lr, task = params.learning_rate, params.task
    n_shared = 1 if compact else 0

    def fn(trees, xbt, base, *shared):
        per_round = prediction.forest_predict_oneround(
            trees, xbt, tp, aggregate=False, mask_dtype=mask_dtype,
            leaf_idx=shared[0] if shared else None)          # (R, N)
        f = base + lr * per_round.sum(0)
        if task == "binary":
            return (f > 0).astype(jnp.int32)
        return f

    return substrate.program(fn, 2, 1 + n_shared)


def linear_predict_program(substrate, task: str):
    """fn(x_i, w_i, b) — the F-LR joint-logit prediction (one psum).

    ``x_i`` and ``w_i`` are party args (each party's standardized feature
    block and its weight block); the bias ``b`` is shared (it is psum-trained
    and identical across parties)."""
    def fn(x_i, w_i, b):
        from repro.core.fedlinear import _spmd_predict
        return _spmd_predict(x_i, w_i, b, task=task)
    from repro.federation import distributed
    return substrate.program(fn, 2, 1,
                             distributed=distributed.linear_predict_spec(task))


def forest_predict_classical_program(substrate, params: ForestParams):
    """fn(trees, xb_test) — the multi-round baseline (paper Figs. 4-6)."""
    def fn(trees, xbt):
        return prediction.forest_predict_classical(trees, xbt, params=params)
    return substrate.program(fn, 2, 0)
