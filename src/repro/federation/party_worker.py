"""The party process: one region's service in the party-per-process substrate.

``worker_main`` dials the coordinator, announces its party index, and serves
protocol messages until shutdown:

  * ``run``      — execute a registered protocol body (federation/
    distributed.py: forest fit/predict, F-LR predict, toy conformance),
    exchanging collectives through :class:`~repro.federation.distributed.Comm`
    on the same channel.  Body exceptions are reported back with their
    traceback; an ``abort`` mid-collective drops the run silently.
  * ``load_block`` / ``hash_block_ids`` / ``bin_block`` — the ingest
    handshake: the block (raw features, raw IDs, maybe labels) is loaded and
    *kept here*; only salted hashes, party-locally binned values, and the
    aligned labels ever go back up the wire.
  * ``bind``     — cache large per-party operands (model trees, weight
    blocks) under a bind id so serving calls only ship the request rows.
  * ``ping``     — health check.
  * ``chaos``    — arm a one-shot injected fault for the NEXT run message:
    ``drop_run`` (swallow it), ``delay_run`` (sleep first), ``die``
    (hard process exit).  Exists for the fault-injection tests.
  * ``telemetry`` — the observability rollup: reply with this process's
    buffered trace spans and metric snapshot (plain metadata — numbers,
    names, ids — never raw arrays), so party-side telemetry aggregates at
    the coordinator without new wire types.

Run messages carry the coordinator's span context under ``_trace``; the
worker attaches it so its spans (op execution, per-level fit compute,
collective waits, injected chaos delays) parent under the coordinator's
span and the whole distributed fit is one connected trace.

Workers are daemon processes: if the coordinator dies, so do they.
"""
from __future__ import annotations

import os
import time
import traceback

import numpy as np

from repro.federation import transport
from repro.observability import registry as telemetry
from repro.observability import trace as tracing


def worker_main(host: str, port: int, index: int) -> None:
    tracing.TRACER.process = f"party{index}"
    ch = transport.connect(host, port)
    ch.send({"op": "hello", "party": index})
    binds: dict[int, dict] = {}
    chaos: dict | None = None
    block = None
    stream = None
    while True:
        try:
            msg = ch.recv(None)
        except transport.TransportError:
            return                                  # coordinator is gone
        op = msg.get("op")
        if op == "shutdown":
            return
        if op == "ping":
            ch.send({"op": "pong", "party": index,
                     "nonce": msg.get("nonce")})
        elif op == "chaos":
            chaos = {"mode": msg["mode"],
                     "seconds": float(msg.get("seconds") or 0.0)}
            ch.send({"op": "chaos_ack", "nonce": msg.get("nonce")})
        elif op == "bind":
            binds[msg["bind"]] = msg.get("args") or {}
            ch.send({"op": "bind_ack", "nonce": msg.get("nonce")})
        elif op == "run":
            with tracing.TRACER.attach(msg.get("_trace")):
                if chaos is not None:
                    mode, secs = chaos["mode"], chaos["seconds"]
                    chaos = None                    # one-shot
                    if mode == "drop_run":
                        continue
                    if mode == "die":
                        os._exit(1)
                    if mode == "delay_run":
                        with tracing.TRACER.span("chaos.delay",
                                                 category="host",
                                                 seconds=secs):
                            time.sleep(secs)
                _handle_run(ch, msg, index, binds)
        elif op == "telemetry":
            ch.send({"op": "telemetry", "party": index,
                     "nonce": msg.get("nonce"),
                     "spans": tracing.TRACER.drain(),
                     "metrics": telemetry.REGISTRY.snapshot()})
        elif op in ("load_block", "hash_block_ids", "bin_block"):
            block = _handle_ingest(ch, msg, block, index)
        elif op in ("stream_scan", "stream_bin"):
            stream = _handle_stream(ch, msg, stream, index)
        # anything else (stale abort/coll_result of a superseded run): skip


def _handle_run(ch, msg, index, binds) -> None:
    from repro.federation import distributed
    rid = msg["run"]
    try:
        body = distributed.DIST_PROGRAMS.get(msg["name"])
        if body is None:
            raise transport.ProtocolError(
                f"unknown protocol program {msg['name']!r} "
                f"(have {sorted(distributed.DIST_PROGRAMS)})")
        args = list(msg.get("args") or ())
        for pos, val in (binds.get(msg.get("bound")) or {}).items():
            args[int(pos)] = val
        comm = distributed.Comm(ch, rid, msg["party_index"],
                                msg["n_parties"])
        with tracing.TRACER.span(f"worker.{msg['name']}",
                                 category="compute", rid=rid, party=index):
            out = body(comm, msg.get("payload") or {}, *args)
        ch.send({"op": "result", "run": rid, "data": out})
    except distributed.RunAborted:
        pass                                        # superseded: back to idle
    except Exception as e:                          # report, don't die
        try:
            ch.send({"op": "error", "run": rid,
                     "message": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()})
        except transport.TransportError:
            pass


def _handle_ingest(ch, msg, block, index):
    """The party side of distributed_ingest; returns the (new) held block."""
    from repro.core import binning
    from repro.core.partyblock import CSVSource, PartyBlock
    op, nonce = msg["op"], msg.get("nonce")
    try:
        if op == "load_block":
            spec = msg["source"]
            if spec["kind"] == "csv":
                block = CSVSource(
                    path=spec["path"], name=spec.get("name"),
                    id_column=spec.get("id_column", "id"),
                    label_column=spec.get("label_column", "label"),
                    delimiter=spec.get("delimiter", ",")).load()
            else:
                names = spec.get("feature_names")
                block = PartyBlock(
                    name=spec["name"], x=spec["x"], ids=spec["ids"],
                    y=spec.get("y"), feature_ids=spec.get("feature_ids"),
                    feature_names=tuple(names) if names else None)
            ch.send({"op": "block_meta", "nonce": nonce,
                     "name": block.name, "n_features": block.n_features,
                     "feature_ids": block.feature_ids,
                     "has_y": block.y is not None})
        elif op == "hash_block_ids":
            if block is None:
                raise RuntimeError("no block loaded (load_block first)")
            if np.unique(block.ids).size != block.ids.size:
                raise ValueError(
                    f"party {block.name!r} has duplicate sample IDs: "
                    f"alignment would be ambiguous — deduplicate before "
                    f"ingest")
            ch.send({"op": "hashes", "nonce": nonce,
                     "hashes": block.hashed_ids(msg["salt"])})
        else:                                       # bin_block
            if block is None:
                raise RuntimeError("no block loaded (load_block first)")
            pos = np.asarray(msg["positions"], np.int64)
            x_i = block.x[pos]
            if block.feature_ids is not None:       # party-local order ->
                x_i = x_i[:, np.argsort(block.feature_ids)]  # ascending gid
            xb_i, b_i = binning.bin_dataset(x_i, int(msg["n_bins"]))
            # Aligned labels return to the coordinator session: the paper's
            # trust model (§4.3) keeps labels with the label-owner driving
            # training, and fit-time masking (mask_regression_targets /
            # encode_labels) applies downstream when privacy flags are set.
            # `block.y[pos]` is a fancy-index COPY, so the runtime guard
            # agrees with this suppression by construction.
            ch.send({"op": "binned", "nonce": nonce, "xb": xb_i,  # egress: ok(aligned labels to the coordinator/label-owner session per the paper's trust model; masked downstream when privacy flags are set)
                     "boundaries": b_i,
                     "y": block.y[pos] if block.y is not None else None})
    except Exception as e:
        try:
            ch.send({"op": "error", "nonce": nonce,
                     "message": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()})
        except transport.TransportError:
            pass
    return block


def _handle_stream(ch, msg, stream, index):
    """The party side of distributed_streaming_ingest; returns the held
    PartyStream.  The stream (raw chunks scanned from this party's own
    source, raw IDs, sketches) lives here; only hashed IDs, sketch-derived
    boundaries, binned values, and the aligned labels go back up the wire."""
    from repro import streaming
    from repro.core import crypto
    from repro.federation.distributed import stream_source_from_spec
    op, nonce = msg["op"], msg.get("nonce")
    try:
        if op == "stream_scan":
            source = stream_source_from_spec(msg["source"])
            if msg.get("append"):
                if stream is None:
                    raise RuntimeError(
                        "no stream held (stream_scan without append first)")
            else:
                stream = streaming.PartyStream(
                    chunk_rows=int(msg["chunk_rows"]),
                    capacity=int(msg["capacity"]),
                    salt=msg.get("salt", crypto.DEFAULT_SALT))
            stream.extend(source)
            merged = stream.merged_scan()
            if np.unique(merged.ids).size != merged.n_rows:
                raise ValueError(
                    f"party {merged.name!r} has duplicate sample IDs: "
                    f"alignment would be ambiguous — deduplicate before "
                    f"ingest")
            ch.send({"op": "stream_meta", "nonce": nonce,
                     "name": merged.name, "n_rows": merged.n_rows,
                     "hashes": merged.hashes,
                     "feature_ids": merged.feature_ids,
                     "n_features": merged.sketches.n_features,
                     "has_y": merged.y is not None})
        else:                                       # stream_bin
            if stream is None:
                raise RuntimeError("no stream held (stream_scan first)")
            pos = np.asarray(msg["positions"], np.int64)
            xb_i, b_i, y_i = streaming.party_stream_bin(
                stream, pos, int(msg["n_bins"]))
            ch.send({"op": "stream_binned", "nonce": nonce, "xb": xb_i,
                     "boundaries": b_i, "y": y_i})
    except Exception as e:
        try:
            ch.send({"op": "error", "nonce": nonce,
                     "message": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()})
        except transport.TransportError:
            pass
    return stream
