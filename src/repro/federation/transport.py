"""Wire transport for the party-per-process substrate.

The paper's deployed system runs each regional party as its own service and
moves only protocol messages — hashed IDs, binned values, masked statistics —
across the network.  This module is that wire layer, kept deliberately small:

  * **framing** — every message is a 4-byte big-endian length prefix followed
    by a msgpack payload.  ndarrays ride as ``{dtype, shape, raw bytes}``
    (no pickle on the wire); NamedTuple pytrees (PartyTree) register a codec
    via :func:`register_namedtuple`.
  * **Channel** — a connected socket with ``send``/``recv`` of framed
    messages and a per-round-trip timeout budget: a peer that does not
    produce a complete frame within the budget raises :class:`PartyTimeout`,
    a closed peer raises :class:`PartyDead`.
  * **RetryPolicy** — jittered exponential backoff between attempts; the
    jitter stream is seeded so fault-injection tests observe deterministic
    sleep schedules (the sleeper is injectable for the same reason).
  * **CircuitBreaker** — per-party consecutive-failure breaker with an
    observer seam: after ``threshold`` consecutive failures the circuit
    opens and further calls fail fast with :class:`CircuitOpenError`.  A
    recorded success (or ``reset``) closes it; with an optional
    ``cooldown_s`` an open circuit half-opens after the cooldown and lets
    probe calls through.  Every state flip is counted in the telemetry
    registry, traced as an instant span, and reported to the
    ``on_transition`` callback.

Nothing here imports jax or the protocol code — the coordinator/worker logic
that gives these messages meaning lives in federation/distributed.py and
federation/party_worker.py.  Two policy hooks ride along: the privacy
egress guard (`repro.analysis.runtime`, numpy-only): when
``REPRO_EGRESS_GUARD=1`` every outgoing payload is checked against the
raw-array taint registry before encoding, so a raw feature/ID/label buffer
can never be framed — the runtime twin of the static
`python -m repro.analysis` pass; and observability (`repro.observability`,
stdlib-only): when tracing is active, ``Channel.send`` stamps the current
span context onto the frame under the ``_trace`` key (receivers that don't
trace ignore it; with tracing disabled the key is never added, so wire
bytes are identical to uninstrumented code).
"""
from __future__ import annotations

import dataclasses
import socket
import struct
import time
from typing import Any, Callable

import msgpack
import numpy as np

from repro.analysis import runtime as egress_guard
from repro.observability import registry as telemetry
from repro.observability import trace as tracing

_LEN = struct.Struct(">I")
_MAX_FRAME = 1 << 31  # sanity bound; a larger frame means a corrupt stream


# --------------------------------------------------------------------- errors
class TransportError(RuntimeError):
    """Base class for wire-level failures."""


class PartyUnavailableError(TransportError):
    """One or more parties could not complete a protocol round.

    ``parties`` holds the party indices the failure is attributed to —
    the serving layer uses them to pick the surviving-tree degraded path.
    """

    def __init__(self, message: str, parties=()):  # noqa: D107
        super().__init__(message)
        self.parties = tuple(parties)


class PartyTimeout(PartyUnavailableError):
    """A party did not answer within the round-trip timeout budget."""


class PartyDead(PartyUnavailableError):
    """A party's connection is gone (process exit, socket close)."""


class CircuitOpenError(PartyUnavailableError):
    """A party's circuit breaker is open: failing fast without dispatch."""


class ProtocolError(TransportError):
    """A peer answered with an out-of-protocol message."""


# ---------------------------------------------------------------------- codec
_ND = "__nd__"
_NT = "__nt__"
_NAMEDTUPLES: dict[str, type] = {}


def register_namedtuple(cls: type) -> type:
    """Allow a NamedTuple type (e.g. core.tree.PartyTree) on the wire: it is
    encoded as its field dict plus the type name, and decoded back through
    this registry — the receiving process must register the same type."""
    _NAMEDTUPLES[cls.__name__] = cls
    return cls


def _default(obj):
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):  # NamedTuple
        name = type(obj).__name__
        if name not in _NAMEDTUPLES:
            raise TypeError(f"NamedTuple {name} is not wire-registered "
                            f"(transport.register_namedtuple)")
        return {_NT: name, "f": {k: v for k, v in obj._asdict().items()}}
    if isinstance(obj, np.generic):
        return obj.item()
    a = np.asarray(obj)
    if a.dtype == object:
        raise TypeError(f"cannot encode {type(obj).__name__} for the wire")
    return {_ND: True, "d": a.dtype.str, "s": list(a.shape),
            "b": a.tobytes()}


def _object_hook(obj: dict):
    if _ND in obj:
        a = np.frombuffer(obj["b"], dtype=np.dtype(obj["d"]))
        return a.reshape(obj["s"]).copy()
    if _NT in obj:
        cls = _NAMEDTUPLES.get(obj[_NT])
        if cls is None:
            raise ProtocolError(f"unregistered NamedTuple {obj[_NT]!r} on "
                                f"the wire")
        return cls(**obj["f"])
    return obj


def _encode(obj):
    """Pre-walk for types msgpack would serialize natively but wrongly:
    a NamedTuple IS a tuple, so the ``default`` hook never sees it."""
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):
        name = type(obj).__name__
        if name not in _NAMEDTUPLES:
            raise TypeError(f"NamedTuple {name} is not wire-registered "
                            f"(transport.register_namedtuple)")
        return {_NT: name, "f": {k: _encode(v)
                                 for k, v in obj._asdict().items()}}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    if isinstance(obj, dict):
        return {k: _encode(v) for k, v in obj.items()}
    return obj


def pack(msg: dict) -> bytes:
    body = msgpack.packb(_encode(msg), default=_default, use_bin_type=True)
    return _LEN.pack(len(body)) + body


def unpack(body: bytes) -> dict:
    return msgpack.unpackb(body, object_hook=_object_hook, raw=False,
                           strict_map_key=False)


# -------------------------------------------------------------------- channel
class Channel:
    """A connected message stream with per-round-trip timeout budgets."""

    def __init__(self, sock: socket.socket, *, party: int | None = None):
        self.sock = sock
        self.party = party            # peer's party index, when known
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._rbuf = b""

    def send(self, msg: dict) -> None:
        ctx = tracing.current_context()
        if ctx is not None and "_trace" not in msg:
            msg = dict(msg, _trace=ctx)
        egress_guard.check_egress(
            msg, context=f"Channel.send(party={self.party})")
        try:
            self.sock.sendall(pack(msg))
        except (OSError, ValueError) as e:
            raise PartyDead(f"party {self.party}: send failed ({e})",
                            parties=self._who()) from e

    def recv(self, timeout: float | None = None) -> dict:
        """Receive one framed message; ``timeout`` bounds the WHOLE frame."""
        deadline = None if timeout is None else time.monotonic() + timeout
        header = self._read(4, deadline)
        (n,) = _LEN.unpack(header)
        if n > _MAX_FRAME:
            raise ProtocolError(f"party {self.party}: oversized frame ({n})")
        return unpack(self._read(n, deadline))

    def _read(self, n: int, deadline: float | None) -> bytes:
        buf = self._rbuf
        while len(buf) < n:
            if deadline is None:
                self.sock.settimeout(None)
            else:
                left = deadline - time.monotonic()
                if left <= 0:
                    self._rbuf = buf
                    raise PartyTimeout(
                        f"party {self.party}: no reply within the "
                        f"round-trip budget", parties=self._who())
                self.sock.settimeout(left)
            try:
                chunk = self.sock.recv(1 << 20)
            except (socket.timeout, TimeoutError) as e:
                self._rbuf = buf
                raise PartyTimeout(
                    f"party {self.party}: no reply within the round-trip "
                    f"budget", parties=self._who()) from e
            except OSError as e:
                raise PartyDead(f"party {self.party}: connection lost ({e})",
                                parties=self._who()) from e
            if not chunk:
                raise PartyDead(f"party {self.party}: connection closed",
                                parties=self._who())
            buf += chunk
        self._rbuf = buf[n:]
        return buf[:n]

    def _who(self) -> tuple:
        return () if self.party is None else (self.party,)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def connect(host: str, port: int, *, timeout: float = 10.0,
            retry: "RetryPolicy | None" = None) -> Channel:
    """Dial a coordinator/worker endpoint, retrying per the policy."""
    policy = retry or RetryPolicy()
    last: Exception | None = None
    for attempt in range(policy.attempts):
        try:
            sock = socket.create_connection((host, port), timeout=timeout)
            sock.settimeout(None)
            return Channel(sock)
        except OSError as e:
            last = e
            if attempt + 1 < policy.attempts:
                policy.backoff(attempt)
    raise PartyDead(f"connect to {host}:{port} failed after "
                    f"{policy.attempts} attempts ({last})")


# ------------------------------------------------------------ fault tolerance
@dataclasses.dataclass
class RetryPolicy:
    """Jittered exponential backoff: delay_k = base * mult^k * (1 + j*u_k).

    ``seed`` makes the jitter stream deterministic and ``sleeper`` is
    injectable, so fault-injection tests can assert the exact backoff
    schedule (``slept`` records every delay handed to the sleeper).
    """

    attempts: int = 3
    base: float = 0.05
    mult: float = 2.0
    jitter: float = 0.5
    max_delay: float = 5.0
    seed: int = 0
    sleeper: Callable[[float], None] = time.sleep

    def __post_init__(self) -> None:
        if self.attempts < 1:
            raise ValueError("attempts must be >= 1")
        self._rng = np.random.default_rng(self.seed)
        self.slept: list[float] = []

    def delay(self, attempt: int) -> float:
        raw = self.base * self.mult ** attempt
        raw *= 1.0 + self.jitter * float(self._rng.random())
        return min(raw, self.max_delay)

    def backoff(self, attempt: int) -> None:
        d = self.delay(attempt)
        self.slept.append(d)
        telemetry.REGISTRY.counter("transport.retries").inc()
        telemetry.REGISTRY.histogram("transport.backoff_s").observe(d)
        with tracing.TRACER.span("retry.backoff", category="host",
                                 attempt=attempt, delay_s=d):
            self.sleeper(d)


class CircuitBreaker:
    """Per-party consecutive-failure breaker with half-open probes.

    ``record_failure`` K times in a row opens party i's circuit; ``allow``
    then raises :class:`CircuitOpenError` so callers fail fast instead of
    burning a timeout budget per request on a party that is plainly down.
    A recorded success closes the circuit again (the coordinator records one
    after every completed round-trip).

    With ``cooldown_s=None`` (the default) an open circuit stays open until
    a success or ``reset`` — the pre-existing behavior.  With a cooldown,
    ``allow`` transitions open→half_open once ``cooldown_s`` has elapsed on
    the (injectable) ``clock`` and lets the probe through; the probe's
    success closes the circuit, its failure re-opens it immediately.

    Observer seam: every state flip calls ``on_transition(party, old,
    new)``, increments ``transport.breaker.<new>`` in the telemetry
    registry, records an instant trace span, and is appended to the
    bounded ``transitions`` log.
    """

    _MAX_LOG = 256

    def __init__(self, threshold: int = 3, *,
                 cooldown_s: float | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[int, str, str], None] | None = None):
        if threshold < 1:
            raise ValueError("breaker threshold must be >= 1")
        if cooldown_s is not None and cooldown_s < 0:
            raise ValueError("breaker cooldown_s must be >= 0")
        self.threshold = int(threshold)
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.on_transition = on_transition
        self._fails: dict[int, int] = {}
        self._state: dict[int, str] = {}
        self._opened_at: dict[int, float] = {}
        self.transitions: list[tuple[int, str, str]] = []

    def state(self, party: int) -> str:
        return self._state.get(party, "closed")

    def _transition(self, party: int, new: str) -> None:
        old = self.state(party)
        if old == new:
            return
        if new == "closed":
            self._state.pop(party, None)
        else:
            self._state[party] = new
        if new == "open":
            self._opened_at[party] = self.clock()
        else:
            self._opened_at.pop(party, None)
        if len(self.transitions) < self._MAX_LOG:
            self.transitions.append((party, old, new))
        telemetry.REGISTRY.counter(f"transport.breaker.{new}").inc()
        tracing.TRACER.event("breaker", category="host", party=party,
                             from_state=old, to_state=new)
        if self.on_transition is not None:
            self.on_transition(party, old, new)

    def record_failure(self, party: int) -> None:
        self._fails[party] = self._fails.get(party, 0) + 1
        if self.state(party) == "half_open":
            # a failed probe re-opens immediately, whatever the count
            self._transition(party, "open")
        elif self._fails[party] >= self.threshold:
            self._transition(party, "open")

    def record_success(self, party: int) -> None:
        self._fails.pop(party, None)
        self._transition(party, "closed")

    def is_open(self, party: int) -> bool:
        return self.state(party) == "open"

    def open_parties(self) -> tuple[int, ...]:
        return tuple(sorted(p for p in self._state
                            if self._state[p] == "open"))

    def allow(self, party: int) -> None:
        if not self.is_open(party):
            return
        if self.cooldown_s is not None:
            opened = self._opened_at.get(party)
            if opened is not None and \
                    self.clock() - opened >= self.cooldown_s:
                self._transition(party, "half_open")
                return  # probe allowed
        raise CircuitOpenError(
            f"party {party}: circuit open after "
            f"{self._fails.get(party, self.threshold)} consecutive failures",
            parties=(party,))

    def reset(self, party: int | None = None) -> None:
        parties = tuple(self._state) if party is None else (party,)
        if party is None:
            self._fails.clear()
        else:
            self._fails.pop(party, None)
        for p in parties:
            self._transition(p, "closed")
