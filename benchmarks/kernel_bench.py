"""Kernel micro-benchmarks: histogram impls (the FF hot spot) + attention.

On this CPU host the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled — the number reported here is a correctness
path, not a TPU projection).  The scatter impl is the CPU production path;
the table is mainly here so regressions in the hot loop show up.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ops


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    n, f, b, l, c = 4096, 64, 32, 16, 2
    xb = jnp.asarray(rng.integers(0, b, (n, f)), jnp.int32)
    seg = jnp.asarray(rng.integers(0, l, (n,)), jnp.int32)
    stats = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    rows = []
    for impl in ("scatter", "ref"):
        t = timeit(lambda: ops.histogram(xb, seg, stats, l, b, impl)
                   .block_until_ready())
        gups = n * f / t / 1e9
        rows.append({"impl": impl, "seconds": t})
        emit(f"kernel/histogram_{impl}", t, f"updates_per_s={gups:.2f}G")
    # pallas interpret mode on a reduced shape (correctness path, not a TPU
    # projection — interpret executes the kernel body in Python)
    xs, ss, st = xb[:512, :8], seg[:512], stats[:512]
    t = timeit(lambda: ops.histogram(xs, ss, st, l, b, "pallas")
               .block_until_ready(), repeat=1)
    rows.append({"impl": "pallas_interpret", "seconds": t})
    emit("kernel/histogram_pallas_interpret", t, "reduced_shape=512x8")
    return rows


if __name__ == "__main__":
    run()
