"""Ablation of the TPU adaptation: quantile binning resolution.

The paper searches exact thresholds; our histogram builder quantizes to
n_bins (DESIGN.md §2). This ablation quantifies the accuracy cost of the
quantization on the paper-suite analogues — the justification for calling
the binned FF "lossless in the paper's sense" (FF == NonFF holds exactly at
ANY bin count; this measures binned-vs-finer, i.e. the adaptation itself).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import ForestParams, fit_federated_forest
from repro.data import load_dataset
from repro.data.tabular import train_test_split
from repro.data.metrics import accuracy


def run() -> list[dict]:
    rows = []
    for name in ("spambase", "waveform"):
        x, y, spec = load_dataset(name, seed=0)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=2)
        accs = {}
        for n_bins in (4, 8, 16, 32, 64):
            p = ForestParams(n_classes=max(spec.n_classes, 2),
                             n_estimators=8, max_depth=6, n_bins=n_bins,
                             seed=7)
            ff = fit_federated_forest(xtr, ytr, 2, p)
            accs[n_bins] = accuracy(yte, ff.predict(xte))
        rows.append({"dataset": name, **accs})
        emit(f"binning/{name}", 0.0,
             "|".join(f"bins{k}={v:.3f}" for k, v in accs.items()))
        # the adaptation claim: >=16 bins is within noise of 64 bins
        assert accs[64] - accs[16] < 0.02, accs
    return rows


if __name__ == "__main__":
    run()
