"""Reproduces the paper's Appendix communication-complexity analysis, and
verifies our level-synchronous adaptation IMPROVES on it.

Paper (recursive, per-node messages):
  training:   O(2^k (M+1)) per tree
  prediction: classical O(2^(k-1) M), optimized O(M) — one gather.

Ours (level-synchronous collectives):
  training:   3 collectives per level (gather gains/ids/bins fuse into
              all-gathers + 1 partition psum)  ->  O(k) per tree
  prediction: ONE psum for the entire forest.

We count actual collective *primitives* in the jaxpr of the shard_map-
lowered protocol over an AbstractMesh (vmap simulation resolves collectives
at trace time, so only the shard_map path shows the real schedule).  The
dry-run records the same schedule in optimized HLO on the production mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit
from repro import compat
from repro.core import ForestParams, impurity, prediction, tree

COLL_PRIMS = ("psum", "all_gather", "all_to_all", "ppermute",
              "psum_invariant", "reduce_scatter")


def _count_collectives(jaxpr) -> dict[str, int]:
    counts: dict[str, int] = {}
    seen = set()

    def walk(jx):
        if id(jx) in seen:
            return
        seen.add(id(jx))
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in COLL_PRIMS:
                counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                for j in _jaxprs_of(v):
                    walk(j)

    def _jaxprs_of(v):
        out = []
        if hasattr(v, "jaxpr"):
            out.append(v.jaxpr)
        elif hasattr(v, "eqns"):
            out.append(v)
        elif isinstance(v, (list, tuple)):
            for vv in v:
                out.extend(_jaxprs_of(vv))
        return out

    walk(jaxpr.jaxpr)
    return counts


def run() -> dict:
    m, depth, n_est, n, f = 4, 5, 3, 64, 12
    fp = f // m
    p = ForestParams(n_estimators=n_est, max_depth=depth, n_bins=8)
    mesh = compat.abstract_mesh((m,), ("parties",))

    # ---- training schedule (one tree: lax.map body traced once) ----------
    def fit_local(xb, gid, sel, w, ys):
        out = tree.build_tree(xb[0], gid[0], sel, w, ys, p)
        return jax.tree.map(lambda a: a[None], out)

    fit = compat.shard_map(
        fit_local, mesh=mesh,
        in_specs=(P("parties"), P("parties"), P(), P(), P()),
        out_specs=P("parties"), check_vma=False)
    jx = jax.make_jaxpr(fit)(
        jnp.zeros((m, n, fp), jnp.uint8), jnp.zeros((m, fp), jnp.int32),
        jnp.ones((f,), bool), jnp.ones((n,), jnp.float32),
        jnp.zeros((n, 2), jnp.float32))
    c_train = _count_collectives(jx)

    # ---- prediction schedules --------------------------------------------
    trees_shape = jax.eval_shape(fit, jnp.zeros((m, n, fp), jnp.uint8),
                                 jnp.zeros((m, fp), jnp.int32),
                                 jnp.ones((f,), bool),
                                 jnp.ones((n,), jnp.float32),
                                 jnp.zeros((n, 2), jnp.float32))
    stacked = jax.tree.map(
        lambda s: jnp.zeros((s.shape[0], n_est) + s.shape[1:], s.dtype),
        trees_shape)

    def pred_one_local(tr, xbt):
        tr = jax.tree.map(lambda a: a[0], tr)
        return prediction.forest_predict_oneround(tr, xbt[0], p,
                                                  aggregate=False)[None]

    def pred_cls_local(tr, xbt):
        tr = jax.tree.map(lambda a: a[0], tr)
        return prediction.forest_predict_classical(tr, xbt[0], p)[None]

    tree_specs = jax.tree.map(lambda _: P("parties"), stacked,
                              is_leaf=lambda x: hasattr(x, "shape"))
    xbt = jnp.zeros((m, 32, fp), jnp.uint8)
    c_one = _count_collectives(jax.make_jaxpr(compat.shard_map(
        pred_one_local, mesh=mesh, in_specs=(tree_specs, P("parties")),
        out_specs=P("parties"), check_vma=False))(stacked, xbt))
    c_cls = _count_collectives(jax.make_jaxpr(compat.shard_map(
        pred_cls_local, mesh=mesh, in_specs=(tree_specs, P("parties")),
        out_specs=P("parties"), check_vma=False))(stacked, xbt))

    result = {
        "train_collectives_per_tree": sum(c_train.values()),
        "train_detail": c_train,
        "train_paper_bound": (2 ** depth) * (m + 1),
        "predict_oneround_collectives": sum(c_one.values()),
        "predict_classical_collectives": sum(c_cls.values()),
        "predict_paper_classical_bound": (2 ** (depth - 1)) * m * n_est,
        "depth": depth, "n_estimators": n_est, "n_parties": m,
    }
    emit("comm/train", 0.0,
         f"ours={result['train_collectives_per_tree']}/tree "
         f"({c_train})|paper_recursive_bound={result['train_paper_bound']}")
    emit("comm/predict", 0.0,
         f"oneround={result['predict_oneround_collectives']}|"
         f"classical_levelsync={result['predict_classical_collectives']}|"
         f"paper_classical_bound={result['predict_paper_classical_bound']}")
    # the paper's headline: one collective for the WHOLE forest
    assert result["predict_oneround_collectives"] == 1, result
    return result


if __name__ == "__main__":
    run()
