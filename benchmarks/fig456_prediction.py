"""Reproduces Figs. 4-6: prediction efficiency, one-round vs classical.

Three sweeps on the spambase analogue (M = 2 parties):
  Fig.4  estimators 8..32      (depth 4)
  Fig.5  max depth 4..12       (8 estimators; paper sweeps to 16 where its
                                trees are pre-pruned anyway — dense level-wise
                                histograms cap us at 12, DESIGN.md §2)
  Fig.6  test-sample rate 0.1..0.4

For each point we report: single-host wall time of both predictors, the
collective-round counts (1 vs T·depth), and a *deployment-projected* total
time  t_total = t_wall + rounds · RTT  for a cross-region RTT of 20 ms (the
paper's setting is multi-organization WAN).  On one host communication is
free, so raw wall time inverts the paper's conclusion — the projected total
is the faithful comparison, and it reproduces the paper's Figs. 4–6 shape:
one-round is flat in T/depth/sample-rate, classical grows linearly.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import ForestParams, fit_federated_forest, prediction
from repro.data import load_dataset
from repro.data.tabular import train_test_split

RTT_S = float(os.environ.get("REPRO_BENCH_RTT_S", "0.02"))


def _fit(n_est, depth, seed=3):
    x, y, _ = load_dataset("spambase", seed=0)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.4, seed=seed)
    p = ForestParams(n_estimators=n_est, max_depth=depth, n_bins=16, seed=seed)
    ff = fit_federated_forest(xtr, ytr, 2, p)
    return ff, xte


def _point(tag, ff, xte):
    t_one = timeit(lambda: ff.predict(xte))
    t_cls = timeit(lambda: ff.predict_classical(xte))
    p = ff.params
    r_one = prediction.comm_rounds(p, "oneround")
    r_cls = prediction.comm_rounds(p, "classical")
    tot_one = t_one + r_one * RTT_S
    tot_cls = t_cls + r_cls * RTT_S
    emit(tag, t_one,
         f"oneround_s={t_one:.4f}|classical_s={t_cls:.4f}|"
         f"rounds={r_one}vs{r_cls}|"
         f"projected_total={tot_one:.3f}s_vs_{tot_cls:.3f}s|"
         f"projected_speedup={tot_cls / tot_one:.2f}x")
    return {"oneround_s": t_one, "classical_s": t_cls,
            "rounds_oneround": r_one, "rounds_classical": r_cls,
            "projected_oneround_s": tot_one, "projected_classical_s": tot_cls}


def run() -> dict:
    out = {"fig4": [], "fig5": [], "fig6": []}
    for n_est in (8, 16, 24, 32):                     # Fig. 4
        ff, xte = _fit(n_est, 4)
        out["fig4"].append({"n_estimators": n_est,
                            **_point(f"fig4/estimators={n_est}", ff, xte)})
    for depth in (4, 6, 8, 10, 12):                   # Fig. 5
        ff, xte = _fit(8, depth)
        out["fig5"].append({"depth": depth,
                            **_point(f"fig5/depth={depth}", ff, xte)})
    ff, xte = _fit(8, 6)
    n = xte.shape[0]
    for rate in (0.1, 0.2, 0.3, 0.4):                 # Fig. 6
        sub = xte[: max(1, int(n * rate / 0.4))]
        out["fig6"].append({"rate": rate,
                            **_point(f"fig6/rate={rate}", ff, sub)})
    return out


if __name__ == "__main__":
    run()
