"""Multi-host sharded execution benchmark on a real (trees x parties) mesh.

The sharded substrate's scaling claim has so far been anchored by
lower/compile dry-runs only.  This benchmark EXECUTES the forest fit and the
one-round prediction through ``run_sharded``/shard_map on a real mesh of
forced host devices (``--xla_force_host_platform_device_count``, the same
idiom the federation tests use), times both, and asserts the sharded build
is bit-identical to the single-device vmap simulation — so the number it
reports is the real protocol, not a shape-polymorphic proxy.

The mesh is launched in a subprocess so the forced device count cannot leak
into the rest of the bench harness.  On a single physical core the forced
devices time-slice, so wall-clock here anchors correctness + overhead of the
sharded path; on a genuinely multi-core/multi-chip host the same harness
measures real scaling.  REPRO_BENCH_FAST=1 shrinks the mesh to (2, 2) and
the training set.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit

_SCRIPT = r"""
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import numpy as np
import jax, jax.numpy as jnp
from repro.core import ForestParams, fit_federated_forest
from repro.data import make_classification

trees_ax, parties = {trees_ax}, {parties}
n, f = {rows}, 12
p = ForestParams(n_estimators={trees}, max_depth=6, n_bins=16, seed=0)
x, y = make_classification(n, f, 2, seed=0)

from repro.federation import Federation
mesh = jax.make_mesh((trees_ax, parties), ("trees", "parties"))
fed = Federation(parties=parties, substrate="sharded", mesh=mesh,
                 hist_impl="scatter", n_bins=p.n_bins)
fed.ingest(x, y)

t0 = time.perf_counter()
model = fed.fit(p)
jax.block_until_ready(model.trees_)
fit_s = time.perf_counter() - t0

sim = fit_federated_forest(x, y, parties, p)
for a, b in zip(jax.tree.leaves(model.trees_), jax.tree.leaves(sim.trees_)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

xt = x[: {pred_rows}]
want = np.asarray(sim.predict(xt))
t0 = time.perf_counter()
got = np.asarray(fed.predict(model, xt))
pred_s = time.perf_counter() - t0
np.testing.assert_array_equal(got, want)
print(f"SHARDED fit_s={{fit_s:.3f}} pred_s={{pred_s:.4f}} "
      f"pred_rows_s={{len(xt) / max(pred_s, 1e-12):.0f}} "
      f"mesh={{trees_ax}}x{{parties}} devices={{trees_ax * parties}}")
"""


def run() -> list[dict]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    trees_ax, parties = (2, 2) if fast else (2, 4)
    cfg = dict(devices=trees_ax * parties, trees_ax=trees_ax,
               parties=parties, trees=4, rows=600 if fast else 2000,
               pred_rows=256)
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT.format(**cfg)],
                         env=env, capture_output=True, text=True,
                         timeout=1500, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    if res.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n"
                           f"{res.stderr[-3000:]}")
    line = next(l for l in res.stdout.splitlines()
                if l.startswith("SHARDED"))
    kv = dict(tok.split("=") for tok in line.split()[1:])
    emit(f"sharded/fit_{trees_ax}x{parties}", float(kv["fit_s"]),
         f"mesh={kv['mesh']}|devices={kv['devices']}|bit_identical=1")
    emit(f"sharded/predict_{trees_ax}x{parties}", float(kv["pred_s"]),
         f"rows_s={kv['pred_rows_s']}|mesh={kv['mesh']}")
    return [{"mesh": kv["mesh"], "fit_s": float(kv["fit_s"]),
             "pred_s": float(kv["pred_s"]),
             "pred_rows_s": float(kv["pred_rows_s"])}]


if __name__ == "__main__":
    run()
