"""Streaming vs in-memory ingest: wall time, peak RSS (tracemalloc), and
sketch accuracy across chunk sizes and sketch capacities.

Quantifies the tentpole trade-off of the out-of-core data plane: the
chunked path re-reads CSV bytes twice (scan pass + bin pass) in exchange
for never holding a silo's raw features densely.  Rows report

  * ``ingest/inmem``          — whole-file ``from_csv`` + dense build;
  * ``ingest/stream-exact``   — chunked, ``capacity >= n`` (bit-identical
    partition, asserted);
  * ``ingest/stream-cap*``    — chunked with bounded sketches: peak memory
    down, tracked rank-error bound and binned-value agreement reported.
"""
from __future__ import annotations

import os
import tempfile
import tracemalloc

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.partyblock import PartyBlock
from repro.core.party import partition_from_blocks
from repro.data import make_classification
from repro.streaming import ChunkedCSVSource, streaming_ingest

M = 3


def _silo_csvs(n, f_per_silo, seed, outdir):
    x, y = make_classification(n, f_per_silo * M, 2, n_informative=10,
                               seed=seed)
    ids = np.array([f"c{i:07d}" for i in range(n)])
    rng, paths = np.random.default_rng(seed), []
    for i in range(M):
        cols = np.arange(i * f_per_silo, (i + 1) * f_per_silo)
        order = rng.permutation(n)
        b = PartyBlock(name=f"silo{i}", x=x[order][:, cols], ids=ids[order],
                       y=y[order] if i == 0 else None, feature_ids=cols)
        paths.append(b.to_csv(os.path.join(outdir, f"{b.name}.csv")))
    return paths


def _peak(fn):
    tracemalloc.start()
    out = fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, peak


def run() -> None:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    n, f_per_silo, n_bins = (4000, 32, 16) if fast else (20000, 64, 16)
    chunk = 500
    with tempfile.TemporaryDirectory() as d:
        paths = _silo_csvs(n, f_per_silo, seed=0, outdir=d)

        def inmem():
            return partition_from_blocks(
                [PartyBlock.from_csv(p) for p in paths], n_bins=n_bins)

        (ref, _, _), peak_ref = _peak(inmem)
        emit("ingest/inmem", timeit(inmem, repeat=1),
             f"n={n}|peak_mb={peak_ref / 1e6:.1f}")

        def stream(capacity):
            return streaming_ingest([ChunkedCSVSource(p) for p in paths],
                                    n_bins, chunk_rows=chunk,
                                    capacity=capacity)

        (part, _, _, streams), peak_ex = _peak(lambda: stream(n))
        assert np.array_equal(part.xb, ref.xb) \
            and np.array_equal(part.boundaries, ref.boundaries), \
            "exact streamed ingest must be bit-identical to the dense build"
        emit("ingest/stream-exact", timeit(lambda: stream(n), repeat=1),
             f"chunk={chunk}|peak_mb={peak_ex / 1e6:.1f}|bit_identical=1")

        for cap in (512,) if fast else (512, 2048):
            (part_c, _, _, streams), peak_c = _peak(lambda: stream(cap))
            err = max(s.merged_scan().sketches.err for s in streams)
            agree = float((part_c.xb == ref.xb).mean())
            emit(f"ingest/stream-cap{cap}",
                 timeit(lambda: stream(cap), repeat=1),
                 f"chunk={chunk}|peak_mb={peak_c / 1e6:.1f}"
                 f"|rank_err={err}|xb_agree={agree:.4f}")
            assert err <= 0.02 * n, \
                f"tracked rank error {err} above 2% of {n} rows"


if __name__ == "__main__":
    run()
