"""Shared benchmark helpers."""
from __future__ import annotations

import os
import time

import numpy as np

# Every emit() row, as a dict — the machine-readable mirror of the CSV
# stream, dumped by `python -m benchmarks.run --json-out FILE`.
RECORDS: list[dict] = []


def bench_rounds() -> int:
    """Paper uses 40 rounds for the Z-tests; default lower for CI speed."""
    return int(os.environ.get("REPRO_BENCH_ROUNDS", "8"))


def timeit(fn, *args, repeat: int = 3):
    """Median wall time (s) of fn(*args) after one warmup."""
    fn(*args)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    """One CSV row: name, us_per_call, derived.  Also recorded in RECORDS
    (derived's ``k=v|k=v`` pairs parsed into a dict, non-numeric values
    kept as strings) for the --json-out summary."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
    fields = {}
    for pair in derived.split("|"):
        if "=" not in pair:
            continue
        k, v = pair.split("=", 1)
        try:
            fields[k] = float(v)
        except ValueError:
            fields[k] = v
    RECORDS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                    "derived": fields})
