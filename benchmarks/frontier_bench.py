"""Dense vs frontier-compacted split search on deep sparse levels.

The paper's hot loop (split-statistics accumulation, §4) runs once per tree
level.  The dense builder histograms all ``2^d`` heap slots; on a deep level
only ``n_live`` nodes still carry samples, so the frontier path remaps them
into ``cap`` compact slots and pays O(n_live) instead of O(2^d) in the
histogram -> gains -> argbest stage.  Rows report per-level stage times at
realistic sparsity (n_live ~ N/64 nodes alive) plus an end-to-end deep-tree
build, with the dense/frontier speedup in the derived column.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import ForestParams, protocol, tree
from repro.data import make_classification

N, F, BINS, C = 4096, 16, 16, 2


def _level_inputs(depth: int, rng: np.random.Generator):
    """A sparse level-``depth`` routing state: n_live occupied heap slots."""
    width = 2 ** depth
    n_live = max(2, N // 64)
    live = np.sort(rng.choice(width, size=min(n_live, width), replace=False))
    seg = jnp.asarray(rng.choice(live, size=N), jnp.int32)
    xb = jnp.asarray(rng.integers(0, BINS, (N, F)), jnp.int32)
    wstats = jnp.asarray(rng.normal(size=(N, C)), jnp.float32)
    return xb, seg, wstats, width, len(live)


def _bench_level(depth: int, cap: int) -> dict:
    rng = np.random.default_rng(depth)
    xb, seg, wstats, width, n_live = _level_inputs(depth, rng)
    fmask = jnp.ones((F,), bool)
    feat_gid = jnp.arange(F, dtype=jnp.int32)
    p = ForestParams(max_depth=max(depth, 1), n_bins=BINS,
                     frontier_cap=cap)

    dense = jax.jit(lambda a, s, w: tree._split_search_dense(
        a, s, w, fmask, feat_gid, width, p, "scatter", None)[0])
    frontier = jax.jit(lambda a, s, w: tree._split_search_frontier(
        a, s, w, fmask, feat_gid, width, cap, p, "scatter"))

    t_dense = timeit(lambda: jax.block_until_ready(dense(xb, seg, wstats)))
    t_front = timeit(lambda: jax.block_until_ready(frontier(xb, seg, wstats)))
    speedup = t_dense / max(t_front, 1e-12)
    emit(f"frontier/level_d{depth}_dense", t_dense,
         f"width={width} live={n_live}")
    emit(f"frontier/level_d{depth}_frontier", t_front,
         f"cap={cap} speedup={speedup:.2f}x")
    return {"depth": depth, "dense_s": t_dense, "frontier_s": t_front,
            "speedup": speedup}


def _bench_build(depth: int, cap: int) -> dict:
    """End-to-end deep-tree build, dense vs compacted (same forest out)."""
    x, y = make_classification(1024, F, 2, seed=0)
    from repro.core import crypto, impurity
    from repro.core.party import make_vertical_partition
    part = make_vertical_partition(x, 2, BINS)
    y_stats = impurity.stat_channels(jnp.asarray(y), "classification", 2)
    sel = jnp.ones((1, part.n_features), bool)
    w = jnp.ones((1, part.n_samples), jnp.float32)
    xb, gid = jnp.asarray(part.xb), jnp.asarray(part.feat_gid)

    out = {}
    for name, fcap in (("dense", 0), ("frontier", cap)):
        p = ForestParams(n_estimators=1, max_depth=depth, n_bins=BINS,
                         bootstrap=False, frontier_cap=fcap)
        run = protocol.jit_simulated(tree.fit_spmd(p), n_party=2, n_shared=3)
        out[name] = timeit(
            lambda: jax.block_until_ready(run(xb, gid, sel, w, y_stats)),
            repeat=2)
    speedup = out["dense"] / max(out["frontier"], 1e-12)
    emit(f"frontier/build_d{depth}_dense", out["dense"], "")
    emit(f"frontier/build_d{depth}_frontier", out["frontier"],
         f"cap={cap} speedup={speedup:.2f}x")
    return {"depth": depth, **out, "speedup": speedup}


def run() -> list[dict]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    depths = (8, 10) if fast else (8, 10, 12)
    rows = [_bench_level(d, cap=128) for d in depths]
    rows.append(_bench_build(8 if fast else 12, cap=128))
    return rows


if __name__ == "__main__":
    run()
