"""Aggregate experiments/dryrun/*.json into the §Roofline markdown table."""
from __future__ import annotations

import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records() -> list[dict]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_table(recs: list[dict], mesh: str = "pod16x16") -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "mem/dev | useful-FLOP frac | note |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                         f"| SKIP: {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                         f"| FAIL: {r.get('error', '')[:60]} |")
            continue
        ro = r["roofline"]
        frac = ro.get("useful_flop_frac", 0.0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']:.3e} | "
            f"{ro['t_memory_s']:.3e} | {ro['t_collective_s']:.3e} | "
            f"**{ro['bottleneck']}** | {ro['mem_per_dev_gib']:.2f} GiB | "
            f"{frac:.2f} | |")
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    recs = load_records()
    print(fmt_table(recs))
    ok = sum(r["status"] == "ok" for r in recs)
    print(f"\n{ok} ok / {sum(r['status'] == 'skip' for r in recs)} skip / "
          f"{sum(r['status'] == 'fail' for r in recs)} fail "
          f"of {len(recs)} records")


if __name__ == "__main__":
    main()
