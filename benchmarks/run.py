"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Control cost with
REPRO_BENCH_ROUNDS (paper uses 40 rounds for Table 1's Z-tests; default 8)
and REPRO_BENCH_FAST=1 (skips the slower Table 1 datasets).

``--json-out FILE`` additionally writes the full run as one JSON document
(per-row records with parsed derived fields, plus environment knobs and
total wall time) so CI can archive comparable summaries per commit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json-out", default=None, metavar="FILE",
                    help="also dump all bench records as a JSON summary")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    t0 = time.time()
    from benchmarks import (binning_ablation, comm_complexity, common,
                            fig3_domains, fig456_prediction, frontier_bench,
                            ingest_bench, kernel_bench, serving_bench,
                            sharded_bench, table1_parity)

    if os.environ.get("REPRO_BENCH_FAST"):
        table1_parity.BENCH_SETS = ["ionosphere", "spambase", "waveform",
                                    "superconduct"]
    table1_parity.run()
    fig3_domains.run()
    fig456_prediction.run()
    comm_complexity.run()
    binning_ablation.run()
    ingest_bench.run()
    kernel_bench.run()
    frontier_bench.run()
    # async/autotune and fleet sections run in CI's dedicated `--mode async`
    # / `--mode fleet` steps (and locally via `python -m
    # benchmarks.serving_bench --mode async|fleet`)
    serving_bench.run("sync")
    # real (trees x parties) mesh execution in a forced-device subprocess
    sharded_bench.run()
    wall = time.time() - t0
    print(f"# total_bench_wall_s={wall:.1f}", file=sys.stderr)

    if args.json_out:
        summary = {
            "records": common.RECORDS,
            "total_wall_s": round(wall, 1),
            "env": {k: os.environ[k] for k in
                    ("REPRO_BENCH_FAST", "REPRO_BENCH_ROUNDS",
                     "JAX_PLATFORMS") if k in os.environ},
        }
        with open(args.json_out, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"# json summary: {args.json_out} "
              f"({len(common.RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    main()
