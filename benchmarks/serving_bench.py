"""Serving engine benchmark: dense vs leaf-compacted one-round prediction,
and synchronous vs async wave dispatch on mixed-size traffic.

For each batch bucket, runs repeated request waves through two ForestServers
sharing one fitted forest — the dense (full-heap mask) baseline and the
leaf-compacted path — and reports rows/s, p50/p95 wave latency, and the
per-wave psum payload bytes.  At depth >= 8 the heap is mostly dead
(n_nodes = 2^(depth+1)-1 vs live leaves bounded by the training rows), so
the compact mask shrinks the collective and the vote contraction
proportionally; the derived column carries the measured speedup.

The async section drives mixed-size request traffic through the
RequestQueue twice — a sync server (max_inflight=1) and an async one
(ring of 4 in-flight waves, host coalescing/padding/scatter overlapping
device execution) — asserts bit-identical results, reports the rows/s
speedup, and repeats with a traffic-autotuned bucket set, asserting the
compile-once contract (compile_count == len(buckets) after warmup, no
growth under traffic) in both modes.

The fleet section drives the same mixed-size traffic through a single-cell
baseline and a 4-cell ServingFleet (consistent-hash routing, per-cell queues
draining concurrently), asserts request-level bit-identity, reports the
rows/s ratio, and then forces overload against a throttled fleet to exercise
both typed shed paths (rate_limit + queue_depth) and the FleetMetrics
percentile/shed counters.  The >=2x fleet speedup claim is asserted only on
hosts with >= 4 cores — cells drain on threads, so a single-core box can
observe routing/bulkhead correctness but not parallel speedup.

REPRO_BENCH_FAST=1 drops to one depth and fewer/smaller waves (the CI smoke
configuration).  ``python -m benchmarks.serving_bench --mode async`` (or
``--mode fleet``) runs just that section (the CI smoke steps).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import RECORDS, emit
from repro.core import ForestParams, fit_federated_forest
from repro.data import make_classification
from repro.serving import (FleetOverloadError, ForestServer, RequestQueue,
                           ServingFleet, autotune_buckets)

PARTIES = 3
ASYNC_INFLIGHT = 3
FLEET_CELLS = 4


def _servers(depth: int, n_train: int, buckets):
    p = ForestParams(n_estimators=8, max_depth=depth, n_bins=16, seed=0)
    x, y = make_classification(n_train, 24, 2, seed=depth)
    ff = fit_federated_forest(x, y, PARTIES, p)
    dense = ForestServer.from_forest(ff, compact=False,
                                     buckets=buckets).warmup()
    compact = ForestServer.from_forest(ff, compact=True,
                                       buckets=buckets).warmup()
    return ff, x, dense, compact


def _drive(server: ForestServer, x, bucket: int, waves: int,
           rng: np.random.Generator):
    server.wave_stats.clear()
    for _ in range(waves):
        rows = x[rng.integers(0, len(x), size=bucket)]
        server.serve(rows)
    stats = server.stats_summary()
    stats["psum_bytes_wave"] = server.wave_stats[-1]["comm_bytes"]
    return stats


def _bench_depth(depth: int, fast: bool) -> list[dict]:
    buckets = (32, 256) if fast else (32, 256, 2048)
    waves = 4 if fast else 10
    ff, x, dense, compact = _servers(depth, 1500 if fast else 4000, buckets)
    lt = compact.leaf_table
    rows = []
    for bucket in buckets:
        rng = np.random.default_rng(bucket)
        # warmup wave outside the timed set (first call pays dispatch setup)
        _drive(dense, x, bucket, 1, rng)
        _drive(compact, x, bucket, 1, rng)
        sd = _drive(dense, x, bucket, waves, rng)
        sc = _drive(compact, x, bucket, waves, rng)
        speedup = sc["rows_per_s"] / max(sd["rows_per_s"], 1e-12)
        emit(f"serving/d{depth}_b{bucket}_dense", sd["p50_ms"] / 1e3,
             f"rows_s={sd['rows_per_s']:.0f}|p95_ms={sd['p95_ms']:.2f}|"
             f"psum_bytes={sd['psum_bytes_wave']}")
        emit(f"serving/d{depth}_b{bucket}_compact", sc["p50_ms"] / 1e3,
             f"rows_s={sc['rows_per_s']:.0f}|p95_ms={sc['p95_ms']:.2f}|"
             f"psum_bytes={sc['psum_bytes_wave']}|"
             f"leaf_slots={lt.capacity}_of_{ff.params.n_nodes}|"
             f"speedup={speedup:.2f}x")
        rows.append({"depth": depth, "bucket": bucket,
                     "dense": sd, "compact": sc, "speedup": speedup})
    return rows


def _drive_queue(server: ForestServer, x, sizes) -> tuple[dict, float]:
    """Submit one mixed-size traffic round and drain it; returns
    ({rid: preds}, rows/s over the drain)."""
    rng = np.random.default_rng(7)          # same rows for every server
    queue = RequestQueue(server)
    rids = [queue.submit(x[rng.integers(0, len(x), size=int(s))])
            for s in sizes]
    t0 = time.perf_counter()
    results = queue.drain()
    dt = time.perf_counter() - t0
    return ({r: results[r] for r in rids},
            int(np.sum(sizes)) / max(dt, 1e-12))


def _bench_async(fast: bool) -> list[dict]:
    """Sync vs async wave dispatch on mixed-size traffic, then the same
    traffic under an autotuned bucket set — compile-once asserted in all
    modes (the CI `--mode async` smoke)."""
    buckets = (32, 256)         # pipeline bench: waves cap at 256 rows
    n_req = 48 if fast else 96
    # interactive-latency forest + many small mixed-size requests: the
    # traffic profile where per-wave host work (bin/coalesce/pad/scatter)
    # is a real fraction of wave time — exactly what async dispatch
    # overlaps away (the depth sweep above covers the heavy-model regime)
    p = ForestParams(n_estimators=4, max_depth=6, n_bins=16, seed=0)
    x, y = make_classification(1200 if fast else 4000, 24, 2, seed=8)
    ff = fit_federated_forest(x, y, PARTIES, p)
    rng = np.random.default_rng(3)
    sizes = rng.integers(1, 100, size=n_req)

    rounds = 5
    sync = ForestServer.from_forest(ff, buckets=buckets,
                                    max_inflight=1).warmup()
    asyn = ForestServer.from_forest(ff, buckets=buckets,
                                    max_inflight=ASYNC_INFLIGHT).warmup()
    _drive_queue(sync, x, sizes)                       # dispatch-setup warm
    _drive_queue(asyn, x, sizes)
    # interleave best-of-N rounds so background-load drift on a shared CI
    # box hits both modes alike
    rows_s_sync = rows_s_async = 0.0
    for _ in range(rounds):
        res_s, r = _drive_queue(sync, x, sizes)
        rows_s_sync = max(rows_s_sync, r)
        res_a, r = _drive_queue(asyn, x, sizes)
        rows_s_async = max(rows_s_async, r)
    for (rs, vs), (ra, va) in zip(sorted(res_s.items()),
                                  sorted(res_a.items())):
        np.testing.assert_array_equal(vs, va)          # bit-identical
    assert sync.compile_count == len(buckets), "sync recompiled"
    assert asyn.compile_count == len(buckets), "async recompiled"
    speedup = rows_s_async / max(rows_s_sync, 1e-12)
    emit("serving/async_mixed", np.sum(sizes) / max(rows_s_async, 1e-12),
         f"rows_s_sync={rows_s_sync:.0f}|rows_s_async={rows_s_async:.0f}|"
         f"speedup={speedup:.2f}x|inflight={ASYNC_INFLIGHT}")

    # autotune epoch: buckets from the observed WAVE row-count distribution
    # (the queue coalesces requests, so waves — not raw request sizes — are
    # what the executables actually see)
    tuned_buckets = autotune_buckets(sync.wave_stats, warm=buckets)
    tuned = ForestServer.from_forest(ff, buckets=tuned_buckets,
                                     max_inflight=ASYNC_INFLIGHT).warmup()
    assert tuned.compile_count == len(tuned.buckets), \
        "autotuned warmup compiled a different executable count"
    _drive_queue(tuned, x, sizes)
    rows_s_tuned = 0.0
    for _ in range(rounds):
        res_t, r = _drive_queue(tuned, x, sizes)
        rows_s_tuned = max(rows_s_tuned, r)
    for (rs, vs), (rt, vt) in zip(sorted(res_s.items()),
                                  sorted(res_t.items())):
        np.testing.assert_array_equal(vs, vt)          # buckets don't change
    assert tuned.compile_count == len(tuned.buckets), \
        "recompiled under autotuned buckets"           # results, only padding
    emit("serving/async_autotuned", np.sum(sizes) / max(rows_s_tuned, 1e-12),
         f"rows_s={rows_s_tuned:.0f}|buckets={'/'.join(map(str, tuned.buckets))}|"
         f"speedup_vs_sync={rows_s_tuned / max(rows_s_sync, 1e-12):.2f}x|"
         f"compiles={tuned.compile_count}")
    return [{"mode": "async", "rows_s_sync": rows_s_sync,
             "rows_s_async": rows_s_async, "speedup": speedup,
             "autotuned_buckets": list(tuned.buckets),
             "rows_s_autotuned": rows_s_tuned,
             "compile_count_sync": sync.compile_count,
             "compile_count_async": asyn.compile_count,
             "compile_count_autotuned": tuned.compile_count}]


def _drive_fleet(fleet: ServingFleet, x, sizes) -> tuple[dict, float]:
    """One mixed-size traffic round through the fleet front door; returns
    ({rid: preds}, rows/s over the drain)."""
    rng = np.random.default_rng(7)          # same rows as _drive_queue
    rids = [fleet.submit(x[rng.integers(0, len(x), size=int(s))],
                         key=f"req-{i}")
            for i, s in enumerate(sizes)]
    t0 = time.perf_counter()
    results = fleet.drain()
    dt = time.perf_counter() - t0
    return ({r: results[r] for r in rids},
            int(np.sum(sizes)) / max(dt, 1e-12))


def _bench_fleet(fast: bool) -> list[dict]:
    """Single cell vs 4-cell fleet on mixed small-request traffic, then a
    forced-overload pass exercising both typed shed paths + FleetMetrics
    (the CI `--mode fleet` smoke)."""
    buckets = (32, 256)
    n_req = 32 if fast else 96
    p = ForestParams(n_estimators=4, max_depth=6, n_bins=16, seed=0)
    x, y = make_classification(1200 if fast else 4000, 24, 2, seed=8)
    ff = fit_federated_forest(x, y, PARTIES, p)
    rng = np.random.default_rng(3)
    sizes = rng.integers(1, 100, size=n_req)

    single = ForestServer.from_forest(ff, buckets=buckets,
                                      max_inflight=ASYNC_INFLIGHT).warmup()
    fleet = ServingFleet(
        [ForestServer.from_forest(ff, buckets=buckets,
                                  max_inflight=ASYNC_INFLIGHT)
         for _ in range(FLEET_CELLS)]).warmup()
    _drive_queue(single, x, sizes)                     # dispatch-setup warm
    _drive_fleet(fleet, x, sizes)
    rounds = 2 if fast else 5
    rows_s_single = rows_s_fleet = 0.0
    for _ in range(rounds):                            # interleaved best-of-N
        res_1, r = _drive_queue(single, x, sizes)
        rows_s_single = max(rows_s_single, r)
        res_f, r = _drive_fleet(fleet, x, sizes)
        rows_s_fleet = max(rows_s_fleet, r)
    # request-level bit-identity: routing may scatter requests across cells,
    # but every request's rows come back identical to the single server's
    for (r1, v1), (rf, vf) in zip(sorted(res_1.items()),
                                  sorted(res_f.items())):
        np.testing.assert_array_equal(v1, vf)
    for name, cell in fleet.cells.items():
        assert cell.server.compile_count == len(cell.server.buckets), \
            f"cell {name} recompiled under traffic"
    ratio = rows_s_fleet / max(rows_s_single, 1e-12)
    cores = os.cpu_count() or 1
    if cores >= 4 and not fast:
        assert ratio >= 2.0, \
            f"fleet at {FLEET_CELLS} cells only {ratio:.2f}x a single cell"
    m = fleet.metrics()
    assert m.rows > 0 and m.p99_ms >= m.p95_ms >= m.p50_ms > 0.0
    emit("serving/fleet_mixed", np.sum(sizes) / max(rows_s_fleet, 1e-12),
         f"rows_s_single={rows_s_single:.0f}|rows_s_fleet={rows_s_fleet:.0f}|"
         f"ratio={ratio:.2f}x|cells={FLEET_CELLS}|cores={cores}|"
         f"p50_ms={m.p50_ms:.2f}|p99_ms={m.p99_ms:.2f}")

    # forced overload, both typed shed paths.  (1) a starved token bucket:
    # after the initial burst drains, everything sheds at the front door
    servers = [cell.server for cell in fleet.cells.values()]
    limited = ServingFleet({f"r{i}": s for i, s in enumerate(servers)},
                           rate_limit_rows_per_s=1.0,
                           rate_burst=float(np.sum(sizes[:4]) + 1))
    shed = {"rate_limit": 0, "queue_depth": 0}
    for i, s in enumerate(sizes):
        try:
            limited.submit(x[:int(s)], key=f"ovl-{i}")
        except FleetOverloadError as err:
            assert err.reason == "rate_limit"
            shed["rate_limit"] += 1
    limited.drain()                     # serve what was admitted
    # (2) tiny bulkheads, no rate limit: one 60-row request fills a 64-row
    # cell queue, so every cell sheds from its second request on
    bulk = ServingFleet({f"q{i}": s for i, s in enumerate(servers)},
                        max_queue_rows=64)
    for i in range(10 * FLEET_CELLS):
        try:
            bulk.submit(x[:60], key=f"jam-{i}")
        except FleetOverloadError as err:
            assert err.reason == "queue_depth" and err.cell
            shed["queue_depth"] += 1
    bulk.drain()
    assert shed["rate_limit"] > 0 and shed["queue_depth"] > 0, shed
    lm, bm = limited.metrics(), bulk.metrics()
    assert lm.shed["rate_limit"] == shed["rate_limit"]
    assert bm.shed["queue_depth"] == shed["queue_depth"]
    emit("serving/fleet_overload", 0.0,
         f"shed_rate_limit={shed['rate_limit']}|"
         f"shed_queue_depth={shed['queue_depth']}|"
         f"accepted={lm.accepted + bm.accepted}|"
         f"dead_letters={lm.dead_letters + bm.dead_letters}")
    return [{"mode": "fleet", "cells": FLEET_CELLS, "cores": cores,
             "rows_s_single": rows_s_single, "rows_s_fleet": rows_s_fleet,
             "ratio": ratio, "shed": shed,
             "p50_ms": m.p50_ms, "p95_ms": m.p95_ms, "p99_ms": m.p99_ms}]


def run(mode: str = "all") -> list[dict]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    out = []
    if mode in ("all", "sync"):
        for d in ((8,) if fast else (8, 10)):
            out.extend(_bench_depth(d, fast))
    if mode in ("all", "async"):
        out.extend(_bench_async(fast))
    if mode in ("all", "fleet"):
        out.extend(_bench_fleet(fast))
    return out


if __name__ == "__main__":
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("all", "sync", "async", "fleet"),
                    default="all")
    ap.add_argument("--json-out", default=None, metavar="FILE",
                    help="dump the emitted records as a JSON summary")
    args = ap.parse_args()
    run(args.mode)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"mode": args.mode, "records": RECORDS}, f, indent=1)
