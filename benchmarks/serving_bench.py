"""Serving engine benchmark: dense vs leaf-compacted one-round prediction.

For each batch bucket, runs repeated request waves through two ForestServers
sharing one fitted forest — the dense (full-heap mask) baseline and the
leaf-compacted path — and reports rows/s, p50/p95 wave latency, and the
per-wave psum payload bytes.  At depth >= 8 the heap is mostly dead
(n_nodes = 2^(depth+1)-1 vs live leaves bounded by the training rows), so
the compact mask shrinks the collective and the vote contraction
proportionally; the derived column carries the measured speedup.

REPRO_BENCH_FAST=1 drops to one depth and fewer/smaller waves (the CI smoke
configuration).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit
from repro.core import ForestParams, fit_federated_forest
from repro.data import make_classification
from repro.serving import ForestServer

PARTIES = 3


def _servers(depth: int, n_train: int, buckets):
    p = ForestParams(n_estimators=8, max_depth=depth, n_bins=16, seed=0)
    x, y = make_classification(n_train, 24, 2, seed=depth)
    ff = fit_federated_forest(x, y, PARTIES, p)
    dense = ForestServer.from_forest(ff, compact=False,
                                     buckets=buckets).warmup()
    compact = ForestServer.from_forest(ff, compact=True,
                                       buckets=buckets).warmup()
    return ff, x, dense, compact


def _drive(server: ForestServer, x, bucket: int, waves: int,
           rng: np.random.Generator):
    server.wave_stats.clear()
    for _ in range(waves):
        rows = x[rng.integers(0, len(x), size=bucket)]
        server.serve(rows)
    stats = server.stats_summary()
    stats["psum_bytes_wave"] = server.wave_stats[-1]["comm_bytes"]
    return stats


def _bench_depth(depth: int, fast: bool) -> list[dict]:
    buckets = (32, 256) if fast else (32, 256, 2048)
    waves = 4 if fast else 10
    ff, x, dense, compact = _servers(depth, 1500 if fast else 4000, buckets)
    lt = compact.leaf_table
    rows = []
    for bucket in buckets:
        rng = np.random.default_rng(bucket)
        # warmup wave outside the timed set (first call pays dispatch setup)
        _drive(dense, x, bucket, 1, rng)
        _drive(compact, x, bucket, 1, rng)
        sd = _drive(dense, x, bucket, waves, rng)
        sc = _drive(compact, x, bucket, waves, rng)
        speedup = sc["rows_per_s"] / max(sd["rows_per_s"], 1e-12)
        emit(f"serving/d{depth}_b{bucket}_dense", sd["p50_ms"] / 1e3,
             f"rows_s={sd['rows_per_s']:.0f}|p95_ms={sd['p95_ms']:.2f}|"
             f"psum_bytes={sd['psum_bytes_wave']}")
        emit(f"serving/d{depth}_b{bucket}_compact", sc["p50_ms"] / 1e3,
             f"rows_s={sc['rows_per_s']:.0f}|p95_ms={sc['p95_ms']:.2f}|"
             f"psum_bytes={sc['psum_bytes_wave']}|"
             f"leaf_slots={lt.capacity}_of_{ff.params.n_nodes}|"
             f"speedup={speedup:.2f}x")
        rows.append({"depth": depth, "bucket": bucket,
                     "dense": sd, "compact": sc, "speedup": speedup})
    return rows


def run() -> list[dict]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    depths = (8,) if fast else (8, 10)
    out = []
    for d in depths:
        out.extend(_bench_depth(d, fast))
    return out


if __name__ == "__main__":
    run()
