"""Reproduces Fig. 3: accuracy & execution time vs number of domains M.

The paper uses the parkinson set (8 natural sub-domains); we use its
synthetic analogue, adding one feature-domain at a time to the federation,
and record accuracy, training time and prediction time.  Expected shape of
the result (paper): accuracy rises with M; training time ~linear in M
(all features examined); prediction time ~flat (the one-round algorithm is
scale-free in M).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import ForestParams, fit_federated_forest
from repro.data import make_classification
from repro.data.metrics import accuracy
from repro.data.tabular import train_test_split

N_DOMAINS = 8
FEATS_PER_DOMAIN = 24


def run() -> list[dict]:
    x, y = make_classification(1500, N_DOMAINS * FEATS_PER_DOMAIN, 2,
                               n_informative=48, seed=7)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=1)
    rows = []
    for m in range(1, N_DOMAINS + 1):
        f_use = m * FEATS_PER_DOMAIN               # add one domain at a time
        p = ForestParams(n_estimators=8, max_depth=6, n_bins=16, seed=2)
        t0 = time.perf_counter()
        ff = fit_federated_forest(xtr[:, :f_use], ytr, m, p)
        t_train = time.perf_counter() - t0
        t0 = time.perf_counter()
        pred = ff.predict(xte[:, :f_use])
        t_pred = time.perf_counter() - t0
        acc = accuracy(yte, pred)
        rows.append({"domains": m, "accuracy": acc,
                     "train_s": t_train, "predict_s": t_pred})
        emit(f"fig3/domains={m}", t_train,
             f"acc={acc:.3f}|train_s={t_train:.2f}|pred_s={t_pred:.3f}")
    return rows


if __name__ == "__main__":
    run()
