"""Reproduces Table 1: accuracy/RMSE parity of FF vs NonFF (+ RF1/RF2/F-LR).

For each dataset: RF1/RF2 train on one party's feature block only, F-LR is
the federated linear baseline, NonFF is the centralized forest (M=1), FF is
the federated forest (M=2).  A two-sample Z-test over REPRO_BENCH_ROUNDS
seeds tests H0: mean(NonFF) == mean(FF) — the paper's losslessness criterion.
(Our implementation is bit-identical under contiguous partitions, so p = 1.0
by construction; we run the statistical test anyway, as the paper did, with
non-contiguous partitions to exercise the realistic case.)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_rounds, emit, timeit
from repro.core import ForestParams, fit_federated_forest
from repro.core.fedlinear import FederatedLinear, split_columns
from repro.data import DATASETS, load_dataset
from repro.data.tabular import train_test_split
from repro.data.metrics import accuracy, rmse, ztest_two_sample

BENCH_SETS = ["ionosphere", "spambase", "parkinson", "waveform",
              "target_marketing", "kdd_cup_99", "gene",
              "year_prediction", "superconduct"]

# scaled-down forest hyper-params (CPU time budget); relative conclusions
# (parity, ordering of RF1/RF2 < NonFF≈FF) are insensitive to these
N_EST, DEPTH, BINS = 8, 6, 16


def _one_round(name: str, seed: int):
    spec = DATASETS[name]
    x, y, _ = load_dataset(name, seed=0)          # fixed data, varying forest
    # cap very wide sets for the bench budget
    if x.shape[1] > 512:
        x = x[:, :512]
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=seed)
    task = spec.task
    metric = accuracy if task == "classification" else rmse
    p = ForestParams(task=task, n_classes=max(spec.n_classes, 2),
                     n_estimators=N_EST, max_depth=DEPTH, n_bins=BINS,
                     seed=seed)
    out = {}
    # single-party baselines: each trains on half the feature space
    half = x.shape[1] // 2
    ff1 = fit_federated_forest(xtr[:, :half], ytr, 1, p)
    out["RF1"] = metric(yte, ff1.predict(xte[:, :half]))
    ff2 = fit_federated_forest(xtr[:, half:], ytr, 1, p)
    out["RF2"] = metric(yte, ff2.predict(xte[:, half:]))
    # F-LR — binary/regression only (the paper's Table 1 likewise leaves
    # F-LR blank for the multiclass sets)
    if task == "regression" or spec.n_classes == 2:
        flr = FederatedLinear(task=task).fit(split_columns(xtr, 2), ytr)
        out["F-LR"] = metric(yte, flr.predict(split_columns(xte, 2)))
    else:
        out["F-LR"] = float("nan")
    # NonFF vs FF (realistic non-contiguous vertical split)
    nonff = fit_federated_forest(xtr, ytr, 1, p)
    out["NonFF"] = metric(yte, nonff.predict(xte))
    ff = fit_federated_forest(xtr, ytr, 2, p, contiguous=False)
    out["FF"] = metric(yte, ff.predict(xte))
    return out


def run() -> list[dict]:
    rounds = bench_rounds()
    rows = []
    for name in BENCH_SETS:
        per_seed = [_one_round(name, s) for s in range(rounds)]
        agg = {k: np.array([r[k] for r in per_seed]) for k in per_seed[0]}
        _, pval = ztest_two_sample(agg["NonFF"], agg["FF"])
        row = {"dataset": name,
               **{k: (float(v.mean()), float(v.std())) for k, v in agg.items()},
               "p_value": pval}
        rows.append(row)
        emit(f"table1/{name}", 0.0,
             f"NonFF={agg['NonFF'].mean():.3f}±{agg['NonFF'].std():.3f}|"
             f"FF={agg['FF'].mean():.3f}±{agg['FF'].std():.3f}|"
             f"RF1={agg['RF1'].mean():.3f}|RF2={agg['RF2'].mean():.3f}|"
             f"F-LR={agg['F-LR'].mean():.3f}|p={pval:.3f}")
    return rows


if __name__ == "__main__":
    run()
