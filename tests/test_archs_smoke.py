"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(<=2 pattern units, d_model<=256, <=4 experts) runs one forward/train step and
one prefill+decode step on CPU; output shapes + finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import reduced
from repro.models import transformer

ARCHS = list(registry.ARCH_IDS)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))}
    if cfg.enc_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_frames, cfg.d_model)), jnp.float32)
    if cfg.n_patches:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(registry.get(arch))
    params = transformer.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: transformer.forward_train(p, b["tokens"], cfg, b)
    )(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, (ce, _) = transformer.lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    # an untrained model should be near uniform CE
    assert abs(float(ce) - np.log(cfg.vocab)) < 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    from repro.train.optim import adamw_init, adamw_update
    cfg = reduced(registry.get(arch))
    params = transformer.init_params(jax.random.key(1), cfg)
    batch = _batch(cfg, seed=1)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(transformer.lm_loss, has_aux=True)(p, b, cfg)
        p, o = adamw_update(p, g, o, lr=3e-3)
        return p, o, loss

    losses = []
    for _ in range(5):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"loss did not drop: {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduced(registry.get(arch))
    params = transformer.init_params(jax.random.key(2), cfg)
    b, s = 2, 16
    batch = _batch(cfg, b=b, s=s, seed=2)
    logits_p, cache = jax.jit(
        lambda p, bt: transformer.prefill(p, bt["tokens"], cfg, bt)
    )(params, batch)
    assert logits_p.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits_p, np.float32)).all()

    tok = jnp.argmax(logits_p, -1)[:, None]
    logits_d, cache = jax.jit(
        lambda p, c, t: transformer.decode_step(p, c, t, jnp.int32(s), cfg)
    )(params, cache, tok)
    assert logits_d.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits_d, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistency_with_forward(arch):
    """Prefill+decode must agree with full-sequence forward at the next
    position (cache correctness — incl. whisper's cross-attention cache and
    the SSM/xLSTM recurrent states)."""
    cfg = reduced(registry.get(arch))
    b, s = 1, 12
    if cfg.n_patches:
        # vision prefix must fit inside the prompt for the parity check
        cfg = cfg.with_(n_patches=4)
    params = transformer.init_params(jax.random.key(3), cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)))
    extras = {}
    if cfg.enc_layers:
        extras["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_frames, cfg.d_model)), jnp.float32)
    if cfg.n_patches:
        extras["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)), jnp.float32)
    _, cache = transformer.prefill(params, toks[:, :s], cfg, extras,
                                   cache_len=s + 8)
    dec, _ = transformer.decode_step(params, cache, toks[:, s:s + 1],
                                     jnp.int32(s), cfg)
    full, _ = transformer.forward_train(params, toks, cfg, extras)
    np.testing.assert_allclose(np.asarray(dec[0], np.float32),
                               np.asarray(full[0, -1], np.float32),
                               rtol=2e-2, atol=2e-2)
