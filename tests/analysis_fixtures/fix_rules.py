"""Fixture for the companion rule passes: bare asserts, nondeterminism,
and lock-discipline violations (this module is named in the tests'
policy override as a lock module and a determinism zone)."""
import threading
import time

import numpy as np


def shape_check(x):
    assert x.ndim == 2, "must be 2d"          # asserts: dies under -O
    return x


def noisy():
    a = np.random.rand(3)                     # determinism: legacy RNG
    rng = np.random.default_rng()             # determinism: unseeded
    return a, rng


def register_program(name):
    def deco(fn):
        return fn
    return deco


@register_program("toy")
def protocol_body(comm, payload):
    t = time.monotonic()                      # determinism: time in a zone
    return t


class SharedCounter:
    """Toy threaded counter.

    Lock discipline (checked by repro.analysis rules/locks):
        _lock: count, events
        unsynchronized (single writer): label
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.events = []
        self.label = ""
        self.undocumented = 0

    def good(self, n):
        with self._lock:
            self.count += n
            self.events.append(n)
        self.label = "ok"                     # documented unsynchronized

    def bad(self, n):
        self.count += n                       # locks: outside _lock
        self.events.append(n)                 # locks: outside _lock
        self.undocumented += 1                # locks: not in the map


class UndocumentedLocker:
    """Owns a lock but documents no discipline."""

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0
