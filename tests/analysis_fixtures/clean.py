"""CLEAN: everything on the wire passed a registered sanitizer or is
protocol metadata — must produce zero findings."""
from repro.core import binning, crypto


def ok(ch, block, n_bins, salt):
    xb, edges = binning.bin_dataset(block.x, n_bins)
    ch.send({"op": "binned",
             "hashes": crypto.hash_ids(block.ids, salt=salt),
             "xb": xb, "boundaries": edges,
             "name": block.name, "n_features": block.n_features,
             "has_y": block.y is not None})


def ok_reassigned(ch, block, salt):
    ids = block.ids
    ids = crypto.hash_ids(ids, salt=salt)   # strong update cleans `ids`
    ch.send({"op": "hashes", "hashes": ids})
