"""LEAK: features are properly binned, but raw IDs ride in the same
message unsanitized — partial sanitization must still be flagged."""
from repro.core import binning


def leak(ch, block, n_bins):
    xb, edges = binning.bin_dataset(block.x, n_bins)
    ch.send({"op": "binned", "xb": xb, "boundaries": edges,
             "ids": block.ids})
