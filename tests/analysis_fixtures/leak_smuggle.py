"""LEAK: raw values smuggled inside nested containers — a dict buried in a
list, and a NamedTuple field."""
import collections

Wrapped = collections.namedtuple("Wrapped", "meta blob")


def leak_dict(ch, block):
    payload = {"meta": block.n_features, "blob": block.y}
    envelope = {"op": "stats", "parts": [payload]}
    ch.send(envelope)


def leak_namedtuple(ch, block):
    msg = Wrapped(meta=1, blob=block.x)
    ch.send({"op": "wrapped", "body": msg})
