"""Suppression fixture: a justified raw send is silenced by
`# egress: ok(reason)`; an empty-reason suppression silences nothing and
is itself reported."""


def provision(ch, block):
    ch.send({"op": "load", "x": block.x})  # egress: ok(fixture: provisioning a party's own worker)


def bad_suppression(ch, block):
    ch.send({"op": "load", "ids": block.ids})  # egress: ok()
