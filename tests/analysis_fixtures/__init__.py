# Fixture modules for the privacy-egress analyzer tests.  These files are
# PARSED by the analyzer (never imported), so they reference PartyBlock-like
# objects and channels freely without any runtime dependencies.
