"""LEAK: raw IDs forwarded through a local helper — the finding must fire
at the call site via the helper's param→sink summary."""


def _forward(ch, payload):
    ch.send({"op": "relay", "data": payload})


def _hop(ch, payload):
    _forward(ch, payload)       # two-deep chain exercises the fixpoint


def leak(ch, block):
    _hop(ch, block.ids)
