"""LEAK: raw features sent straight to the wire."""


def leak(ch, block):
    ch.send({"op": "dump", "x": block.x})
