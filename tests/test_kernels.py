"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs pure-jnp
oracle, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.histogram import histogram_pallas
from repro.kernels.ref import flash_attention_ref, histogram_ref


@pytest.mark.parametrize("n,f,b,l,c", [
    (64, 3, 8, 1, 2),        # tiny
    (300, 11, 16, 6, 3),     # ragged (pad both axes)
    (512, 8, 32, 12, 2),     # exact tile boundaries
    (1030, 17, 64, 32, 5),   # multi-chunk, multi-tile
])
def test_histogram_pallas_matches_ref(n, f, b, l, c):
    rng = np.random.default_rng(n + f)
    xb = jnp.asarray(rng.integers(0, b, (n, f)), jnp.int32)
    seg = jnp.asarray(rng.integers(-1, l, (n,)), jnp.int32)
    stats = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    want = histogram_ref(xb, seg, stats, l, b)
    got = histogram_pallas(xb, seg, stats, l, b, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("platform,want", [
    ("cpu", "scatter"),          # generic host: scatter-add lowering
    ("gpu", "segment_sum"),      # tuned unsorted-segment reduction
    ("cuda", "segment_sum"),
    ("rocm", "segment_sum"),
    ("tpu", "pallas"),           # compiled Pallas kernel
])
def test_auto_backend_resolution_per_platform(monkeypatch, platform, want):
    """hist_impl="auto" resolves per detected platform — covered without the
    hardware by monkeypatching the detection seam."""
    monkeypatch.setattr(ops, "detected_platform", lambda: platform)
    assert ops.resolve_backend("auto") == want
    assert want in ops.available_backends()


def test_resolve_backend_passthrough_and_unknown():
    assert ops.resolve_backend("scatter") == "scatter"
    with pytest.raises(ValueError, match="unknown impl"):
        ops.resolve_backend("warp-histogram")


@pytest.mark.parametrize("impl", ["scatter", "pallas", "ref", "segment_sum"])
def test_histogram_impl_agreement(impl):
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.integers(0, 16, (257, 9)), jnp.int32)
    seg = jnp.asarray(rng.integers(-1, 4, (257,)), jnp.int32)
    stats = jnp.asarray(rng.normal(size=(257, 3)), jnp.float32)
    want = histogram_ref(xb, seg, stats, 4, 16)
    got = ops.histogram(xb, seg, stats, 4, 16, impl)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,f,b,l,c", [(64, 3, 8, 1, 2), (300, 11, 16, 6, 3),
                                       (1030, 17, 64, 32, 5)])
def test_segment_sum_matches_scatter(n, f, b, l, c):
    """The GPU segment-sum backend is a drop-in for scatter (CPU sweep);
    both accumulate identical flat bucket ids, so agreement is exact up to
    f32 reduction order."""
    rng = np.random.default_rng(n + f)
    xb = jnp.asarray(rng.integers(0, b, (n, f)), jnp.int32)
    seg = jnp.asarray(rng.integers(-1, l, (n,)), jnp.int32)
    stats = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    want = ops.histogram(xb, seg, stats, l, b, "scatter")
    got = ops.histogram(xb, seg, stats, l, b, "segment_sum")
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_histogram_stats_dtype_bf16_inputs():
    """bf16 stats are accumulated in f32 (preferred_element_type)."""
    rng = np.random.default_rng(1)
    xb = jnp.asarray(rng.integers(0, 8, (128, 4)), jnp.int32)
    seg = jnp.asarray(rng.integers(0, 2, (128,)), jnp.int32)
    stats = jnp.asarray(rng.normal(size=(128, 2)), jnp.bfloat16)
    want = histogram_ref(xb, seg, stats.astype(jnp.float32), 2, 8)
    got = histogram_pallas(xb, seg, stats.astype(jnp.float32), 2, 8)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


def test_histogram_weighted_totals():
    """Column sums of the histogram reproduce global weighted stats."""
    rng = np.random.default_rng(2)
    n = 400
    xb = jnp.asarray(rng.integers(0, 8, (n, 5)), jnp.int32)
    seg = jnp.zeros((n,), jnp.int32)
    stats = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    h = histogram_pallas(xb, seg, stats, 1, 8)
    np.testing.assert_allclose(h.sum((0, 2)),
                               jnp.broadcast_to(stats.sum(0), (5, 2)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- attention
def _flash(q, k, v, **kw):
    from repro.kernels.flash_attention import flash_attention
    return flash_attention(q, k, v, interpret=True, **kw)


@pytest.mark.parametrize("sq,sk,d", [(128, 128, 64), (256, 256, 64), (128, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(sq, sk, d, causal):
    rng = np.random.default_rng(sq + d)
    q = jnp.asarray(rng.normal(size=(1, 2, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, sk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, sk, d)), jnp.float32)
    want = flash_attention_ref(q, k, v, causal=causal)
    got = _flash(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_window_and_dtype(dtype):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 2, 256, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 2, 256, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 2, 256, 64)), dtype)
    want = flash_attention_ref(q, k, v, causal=True, window=128)
    got = _flash(q, k, v, causal=True, window=128)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=tol, atol=tol)
