"""Party-per-process substrate: the correctness oracle and deterministic
fault injection.

Oracle — a real 3-party localhost deployment (one OS process per party,
message-passing collectives over sockets) is BIT-IDENTICAL to the vmap
simulation: fit + predict on both tasks, CSV party ingest, and serving
through Federation.serve with a ServeConfig.

Fault tolerance — every failure mode the coordinator claims to handle is
demonstrated deterministically via the workers' one-shot chaos hook:

  * ``drop_run``  — the round times out, the jittered-backoff retry
    recovers it exactly (and the injectable sleeper records the schedule);
  * ``delay_run`` — a PartyTimeout surfaces when the retry budget is 1,
    and the aborted worker rejoins the next round;
  * ``die``       — the dead party is detected, retries fast-fail, the
    circuit breaker opens, health() reports the party down;
  * degraded serving — after a kill, ForestServer answers from the trees
    whose split paths avoid the dead party's features, exactly.
"""
import time

import jax
import numpy as np
import pytest

from repro.core import ForestParams
from repro.core.partyblock import CSVSource
from repro.data import make_classification, make_party_views, make_regression
from repro.federation import Federation, distributed
from repro.federation.distributed import DistributedSubstrate, surviving_trees
from repro.federation.transport import (CircuitOpenError, PartyDead,
                                        PartyTimeout, PartyUnavailableError,
                                        RetryPolicy)
from repro.serving import ServeConfig

M = 3


def _trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.fixture(scope="module")
def dist_fed():
    """One 3-party deployment shared by the oracle tests (fault tests build
    their own — they kill workers)."""
    fed = Federation(parties=M, substrate="distributed", n_bins=8,
                     round_timeout=60.0,
                     retry=RetryPolicy(attempts=2, base=0.05, seed=0))
    yield fed
    fed.close()


# ------------------------------------------------------------------- oracle
@pytest.mark.parametrize("task", ["classification", "regression"])
def test_fit_predict_bit_identity(dist_fed, task):
    if task == "classification":
        x, y = make_classification(120, 6, 2, seed=0)
    else:
        x, y = make_regression(120, 6, seed=1)
    p = ForestParams(task=task, n_estimators=3, max_depth=3, n_bins=8,
                     seed=0)
    sim = Federation(parties=M, n_bins=8)
    sim.ingest(x, y)
    ref = sim.fit(p)
    dist_fed.ingest(x, y)
    model = dist_fed.fit(p)
    _trees_equal(ref.trees_, model.trees_)
    xt = x[:40]
    np.testing.assert_array_equal(np.asarray(dist_fed.predict(model, xt)),
                                  np.asarray(sim.predict(ref, xt)))


def test_distributed_csv_ingest_matches_in_process(dist_fed, tmp_path):
    """Per-party CSV extracts ingested through the party processes (raw
    features and IDs never leave the worker) build the same partition — and
    the same fitted forest — as the in-process block path."""
    x, y = make_classification(90, 6, 2, seed=2)
    blocks, _, _ = make_party_views(x, y, M, overlap=0.8, seed=2)
    sources = [CSVSource(b.to_csv(str(tmp_path / f"{b.name}.csv")),
                         name=b.name) for b in blocks]
    sim = Federation(parties=M, n_bins=8)
    part_sim = sim.ingest(sources, validate=True)
    # validate=True re-bins the central matrix the distributed substrate
    # never holds — refused loudly, not silently skipped
    with pytest.raises(ValueError, match="validate"):
        dist_fed.ingest(sources, validate=True)
    part = dist_fed.ingest(sources)
    np.testing.assert_array_equal(part.xb, part_sim.xb)
    np.testing.assert_array_equal(part.feat_gid, part_sim.feat_gid)
    np.testing.assert_array_equal(part.boundaries, part_sim.boundaries)
    # the coordinator only ever sees hashed IDs: aligned_ids_ carries the
    # same canonical ordering, one salted hash away from the raw IDs
    from repro.core import crypto
    np.testing.assert_array_equal(dist_fed.aligned_ids_,
                                  crypto.hash_ids(sim.aligned_ids_))
    p = ForestParams(n_estimators=2, max_depth=3, n_bins=8, seed=0)
    _trees_equal(sim.fit(p).trees_, dist_fed.fit(p).trees_)


# ---------------------------------------------------------- fault injection
def _toy(sub):
    """The cheap two-collective conformance protocol — runs in numpy at the
    workers, so fault tests pay no jit tax."""
    prog = sub.program(None, 1, 1,
                       distributed=distributed.toy_affine_spec())
    x = np.arange(sub.n_parties * 4, dtype=np.int32).reshape(
        sub.n_parties, 4)
    return prog, x, np.int32(3)


def test_retry_recovers_dropped_round():
    """A swallowed run message times out; the retry replays the round
    bit-identically, sleeping the deterministic jittered-backoff schedule."""
    policy = RetryPolicy(attempts=3, base=0.01, seed=7,
                         sleeper=lambda d: None)
    sub = DistributedSubstrate(2, round_timeout=2.0, retry=policy)
    try:
        prog, x, s = _toy(sub)
        want = np.asarray(prog(x, s))           # healthy round first
        sub.chaos(0, "drop_run")
        got = np.asarray(prog(x, s))
        np.testing.assert_array_equal(got, want)
        assert len(policy.slept) == 1           # one timeout, one backoff
        twin = RetryPolicy(attempts=3, base=0.01, seed=7)
        assert policy.slept[0] == twin.delay(0)  # schedule is reproducible
    finally:
        sub.shutdown()


def test_round_timeout_surfaces_then_worker_rejoins():
    """With a retry budget of 1, a delayed party surfaces PartyTimeout
    attributed to it; the abort unblocks the worker, which serves the next
    round normally."""
    sub = DistributedSubstrate(2, round_timeout=1.0,
                               retry=RetryPolicy(attempts=1))
    try:
        prog, x, s = _toy(sub)
        want = np.asarray(prog(x, s))
        sub.chaos(1, "delay_run", seconds=2.0)
        with pytest.raises(PartyTimeout) as err:
            prog(x, s)
        assert err.value.parties == (1,)
        time.sleep(2.0)                  # let the worker wake + drain abort
        np.testing.assert_array_equal(np.asarray(prog(x, s)), want)
    finally:
        sub.shutdown()


def test_killed_party_opens_circuit_breaker():
    """A hard process death fails the round on every retry, opens the
    party's circuit (later calls fail fast, no timeout burned), and shows
    up in health() and unavailable_parties()."""
    policy = RetryPolicy(attempts=3, base=0.01, seed=0,
                         sleeper=lambda d: None)
    sub = DistributedSubstrate(2, round_timeout=10.0, retry=policy,
                               breaker_threshold=3)
    try:
        prog, x, s = _toy(sub)
        prog(x, s)                              # healthy round first
        sub.chaos(1, "die")
        with pytest.raises(PartyDead):
            prog(x, s)                          # all 3 attempts fail
        assert len(policy.slept) == 2           # backoff between attempts
        assert 1 in sub.unavailable_parties()
        with pytest.raises(CircuitOpenError):
            prog(x, s)                          # breaker: fail fast
        h = sub.health(timeout=2.0)
        assert h[1] is None and h[0] is not None
    finally:
        sub.shutdown()


def test_degraded_serving_after_kill_is_exact():
    """Kill a party mid-traffic: with allow_degraded the server answers
    from the trees whose split paths avoid the dead party's features —
    bit-identical to a forest holding only those trees (their masks never
    consult the dead party, so the leaf intersection is unchanged)."""
    p = ForestParams(n_estimators=10, max_depth=3, n_bins=8,
                     max_features=0.34, seed=0)
    x, y = make_classification(160, 9, 2, seed=0)
    sim = Federation(parties=M, n_bins=8)
    sim.ingest(x, y)
    ref = sim.fit(p)
    fed = Federation(parties=M, substrate="distributed", n_bins=8,
                     retry=RetryPolicy(attempts=2, base=0.01, seed=0,
                                       sleeper=lambda d: None))
    try:
        fed.ingest(x, y)
        model = fed.fit(p)
        server = fed.serve(model, ServeConfig(buckets=(32,),
                                              allow_degraded=True))
        xt = x[:30]
        want = np.asarray(sim.predict(ref, xt))
        np.testing.assert_array_equal(server.serve(xt), want)
        assert not server.wave_stats[-1].get("degraded")

        # kill the party the most trees' split paths avoid
        survivors = {pi: surviving_trees(model.trees_, [pi]).size
                     for pi in range(M)}
        victim = max(survivors, key=survivors.get)
        assert survivors[victim] > 0, "fixture forest has no avoider trees"
        fed.substrate.chaos(victim, "die")
        got = server.serve(xt)
        stats = server.wave_stats[-1]
        assert stats.get("degraded")
        assert victim in stats["dead_parties"]
        assert stats["n_trees"] == survivors[victim]
        assert victim in fed.substrate.unavailable_parties()

        sel = surviving_trees(ref.trees_, [victim])
        deg = type(ref)(p)
        deg.trees_ = jax.tree.map(lambda a: np.asarray(a)[:, sel],
                                  ref.trees_)
        deg.partition_ = ref.partition_
        deg._decode = ref._decode
        np.testing.assert_array_equal(got, np.asarray(deg.predict(xt)))
    finally:
        fed.close()


def test_degraded_serving_refused_without_optin():
    """Without allow_degraded a dead party is a hard serving error — no
    silently approximate answers."""
    p = ForestParams(n_estimators=2, max_depth=3, n_bins=8, seed=0)
    x, y = make_classification(80, 6, 2, seed=1)
    fed = Federation(parties=M, substrate="distributed", n_bins=8,
                     retry=RetryPolicy(attempts=2, base=0.01, seed=0,
                                       sleeper=lambda d: None))
    try:
        fed.ingest(x, y)
        model = fed.fit(p)
        server = fed.serve(model, ServeConfig(buckets=(32,)))
        server.serve(x[:10])
        fed.substrate.chaos(0, "die")
        with pytest.raises(PartyUnavailableError):
            server.serve(x[:10])
    finally:
        fed.close()


# ----------------------------------------------------------- privacy egress
def _tcp_channel_pair():
    """A real loopback TCP Channel pair (Channel sets TCP_NODELAY, so a
    unix socketpair won't do)."""
    import socket

    from repro.federation.transport import Channel
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    a = socket.create_connection(lst.getsockname(), timeout=5)
    b, _ = lst.accept()
    lst.close()
    return Channel(a, party=0), Channel(b, party=0)


def test_egress_guard_blocks_raw_send_and_names_the_key():
    """Deliberately ship a raw-tagged feature block / raw IDs through a
    real Channel: the wire refuses, the error names the payload key path
    and the taint label.  Sanitized protocol traffic on the same channel
    flows untouched."""
    from repro.analysis import runtime as egress_rt
    from repro.analysis.runtime import PrivacyViolationError
    from repro.core.partyblock import PartyBlock

    assert egress_rt.enabled(), "conftest must arm REPRO_EGRESS_GUARD"
    tx, rx = _tcp_channel_pair()
    try:
        block = PartyBlock(name="leaky", x=np.arange(10.0).reshape(5, 2),
                           ids=np.arange(5), y=np.zeros(5, np.int64))
        with pytest.raises(PrivacyViolationError) as ei:
            tx.send({"op": "leak", "payload": {"x": block.x}})
        assert ei.value.path == "msg['payload']['x']"
        assert "raw features" in str(ei.value)
        assert "'leaky'" in str(ei.value)
        with pytest.raises(PrivacyViolationError) as ei:
            tx.send({"op": "leak", "ids": block.ids})
        assert ei.value.path == "msg['ids']"
        assert "raw sample IDs" in str(ei.value)
        # a column view shares the raw buffer — still blocked
        with pytest.raises(PrivacyViolationError):
            tx.send({"op": "leak", "col": block.x[:, 0]})
        # the sanctioned protocol message is untouched and round-trips
        hashes = block.hashed_ids("salt0")
        tx.send({"op": "hashes", "hashes": hashes})
        got = rx.recv(timeout=10)
        np.testing.assert_array_equal(np.asarray(got["hashes"]), hashes)
    finally:
        tx.sock.close()
        rx.sock.close()


def test_guarded_traffic_is_bit_identical(dist_fed):
    """The egress guard is armed for the whole suite (conftest): this pins
    down explicitly that guarded distributed fit/predict/ingest produce
    bit-identical results to the in-process simulation — the guard only
    ever blocks, it never perturbs."""
    from repro.analysis import runtime as egress_rt

    assert egress_rt.enabled()
    x, y = make_classification(90, 6, 2, seed=7)
    p = ForestParams(n_estimators=2, max_depth=3, n_bins=8, seed=4)
    sim = Federation(parties=M, n_bins=8)
    sim.ingest(x, y)
    ref = sim.fit(p)
    dist_fed.ingest(x, y)
    model = dist_fed.fit(p)
    _trees_equal(ref.trees_, model.trees_)
    np.testing.assert_array_equal(
        np.asarray(dist_fed.predict(model, x[:25])),
        np.asarray(sim.predict(ref, x[:25])))
