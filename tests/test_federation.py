"""Federation session API tests.

The load-bearing claims:
  * session-built forests are BIT-IDENTICAL to the direct
    FederatedForest.fit path (both tasks) — the session adds an owner, not
    a different code path;
  * forest / boosting / F-LR all conform to the shared Estimator protocol
    and train/predict through one session surface;
  * the session owns the histogram backend (hist_impl) — the per-estimator
    override is deprecated;
  * the LeafTable plan behind fed.predict / fed.serve is invalidated and
    rebuilt when a model's ``trees_`` changes (fit_resumable continuations);
  * the sharded substrate lowers the same session programs on a
    (trees, parties) mesh (dry-run, subprocess-isolated device count).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import (BoostParams, FederatedForest, ForestParams,
                        LinearParams)
from repro.data import make_classification, make_regression
from repro.data.metrics import accuracy
from repro.federation import Estimator, Federation, SimulatedSubstrate


@pytest.fixture(scope="module")
def cls_data():
    x, y = make_classification(700, 18, 3, seed=0)
    return x[:500], y[:500], x[500:], y[500:]


@pytest.fixture(scope="module")
def reg_data():
    x, y = make_regression(500, 12, seed=1)
    return x[:380], y[:380], x[380:], y[380:]


def _trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------------- fit parity (exact)
@pytest.mark.parametrize("task", ["classification", "regression"])
def test_session_fit_bit_identical_to_direct(cls_data, reg_data, task):
    """Federation.fit == FederatedForest.fit, down to the last bit."""
    xtr, ytr, xte, _ = cls_data if task == "classification" else reg_data
    p = ForestParams(task=task, n_classes=3, n_estimators=4, max_depth=6,
                     n_bins=16, seed=7)
    fed = Federation(parties=3, n_bins=p.n_bins)
    part = fed.ingest(xtr, ytr)
    session_model = fed.fit(p)
    direct = FederatedForest(p).fit(part, ytr)
    _trees_equal(session_model.trees_, direct.trees_)
    np.testing.assert_array_equal(fed.predict(session_model, xte),
                                  direct.predict(xte))
    # the compact session predict is also bit-identical to the dense kernel
    np.testing.assert_array_equal(fed.predict(session_model, xte),
                                  session_model.predict(xte))


def test_session_substrate_resolved_once(cls_data):
    fed = Federation(parties=2)
    assert isinstance(fed.substrate, SimulatedSubstrate)
    m1 = fed.fit(ForestParams(n_estimators=2, max_depth=3, n_bins=32,
                              n_classes=3),
                 fed.ingest(cls_data[0], cls_data[1]), cls_data[1])
    assert m1.substrate is fed.substrate


def test_session_requires_ingest_or_explicit_data():
    fed = Federation(parties=2)
    with pytest.raises(ValueError, match="ingest"):
        fed.fit(ForestParams(n_estimators=1))


def test_session_rejects_bin_count_mismatch(cls_data):
    """A spec binned differently from the ingested partition would train on
    truncated histograms — must be a loud error, not a silent wrong model."""
    fed = Federation(parties=2, n_bins=32)
    fed.ingest(cls_data[0], cls_data[1])
    with pytest.raises(ValueError, match="n_bins"):
        fed.fit(ForestParams(n_estimators=1, n_bins=16, n_classes=3))


def test_serve_with_knobs_is_not_cached(cls_data):
    """serve() must honor per-call server knobs — different knobs never get
    the cached knob-free server back."""
    xtr, ytr = cls_data[0], cls_data[1]
    p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, n_classes=3)
    fed = Federation(parties=2, n_bins=8)
    fed.ingest(xtr, ytr)
    model = fed.fit(p)
    s1 = fed.serve(model, buckets=(32,))
    s2 = fed.serve(model, buckets=(32,), vote_impl="argmax")
    assert s2 is not s1 and s2.vote_impl == "argmax"
    assert fed.serve(model, buckets=(32,)) is s1   # knob-free path cached


# -------------------------------------------------- estimator conformance
def test_estimator_protocol_conformance(cls_data, reg_data):
    """One session surface drives all three model families."""
    xtr, ytr, xte, yte = cls_data
    fed = Federation(parties=3)
    fed.ingest(xtr, ytr)

    forest = fed.fit(ForestParams(n_estimators=5, max_depth=5, n_bins=32,
                                  n_classes=3))
    linear = fed.fit(LinearParams(steps=200))
    models = [forest, linear]

    rxtr, rytr, rxte, ryte = reg_data
    fed_r = Federation(parties=2, n_bins=16)
    fed_r.ingest(rxtr, rytr)
    boost = fed_r.fit(BoostParams(n_rounds=5, max_depth=3, n_bins=16))
    models.append(boost)

    for model in models:
        assert isinstance(model, Estimator), type(model)

    for model in (forest, linear):
        preds = fed.predict(model, xte)
        assert preds.shape == (len(xte),)
    assert accuracy(yte, fed.predict(forest, xte)) > 0.5
    assert fed_r.predict(boost, rxte).shape == (len(rxte),)


def test_fedlinear_partition_and_legacy_blocks_agree(cls_data):
    """The partition path (session) and the legacy block-list path train
    the identical F-LR model when the column split matches."""
    from repro.core.fedlinear import FederatedLinear
    xtr, ytr, xte, _ = cls_data
    fed = Federation(parties=2)
    part = fed.ingest(xtr, ytr)
    m_sess = fed.fit(LinearParams(steps=150))
    m_legacy = FederatedLinear(steps=150).fit(part.split_raw(xtr), ytr)
    np.testing.assert_array_equal(fed.predict(m_sess, xte),
                                  m_legacy.predict(part.split_raw(xte)))


# ------------------------------------------------------ hist_impl ownership
def test_forest_hist_impl_field_deprecated():
    with pytest.warns(DeprecationWarning, match="hist_impl"):
        FederatedForest(ForestParams(n_estimators=1), hist_impl="scatter")


def test_session_hist_impl_is_source_of_truth(cls_data):
    """Session-level hist_impl overrides the spec's — and produces the same
    forest (backends are exact-equivalent)."""
    xtr, ytr, xte, _ = cls_data
    p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, n_classes=3,
                     hist_impl="auto")
    fed = Federation(parties=2, hist_impl="scatter", n_bins=8)
    part = fed.ingest(xtr, ytr)
    model = fed.fit(p)
    assert model.params.hist_impl == "scatter"
    # boosting specs get the session backend too
    boost = Federation(parties=2, hist_impl="scatter", n_bins=8)
    boost.ingest(xtr, (ytr == 1).astype(np.float64))
    bm = boost.fit(BoostParams(task="binary", n_rounds=2, max_depth=3,
                               n_bins=8))
    assert bm.params.hist_impl == "scatter"
    # same trees as the default backend (exactness across backends)
    ref = FederatedForest(p).fit(part, ytr)
    _trees_equal(model.trees_, ref.trees_)


# ----------------------------------------------------- LeafTable freshness
def test_predict_plan_refreshes_when_trees_change(cls_data, tmp_path):
    """fit_resumable extends the forest in place; the session's cached
    LeafTable must be rebuilt, not silently reused."""
    xtr, ytr, xte, _ = cls_data
    p4 = ForestParams(n_estimators=4, max_depth=6, n_bins=16, n_classes=3,
                      seed=3)
    fed = Federation(parties=3, n_bins=16)
    fed.ingest(xtr, ytr)
    d = str(tmp_path / "resume")
    model = fed.fit_resumable(p4, d)
    first = fed.predict(model, xte)
    plan_before = fed._plans[id(model)][1]

    # continuation: same seed-derived randomness, more trees -> trees_ swaps
    p6 = dataclasses.replace(p4, n_estimators=6)
    model.params = p6
    model.fit_resumable(fed._partition, ytr, d)
    assert int(model.trees_.is_leaf.shape[1]) == 6

    second = fed.predict(model, xte)
    plan_after = fed._plans[id(model)][1]
    assert plan_after is not plan_before
    direct = model.predict(xte)
    np.testing.assert_array_equal(second, direct)
    # the 4-tree prefix is the identical forest, so most votes agree but the
    # result must come from the 6-tree forest, not a stale 4-tree plan
    assert second.shape == first.shape


def test_serve_refreshes_server_when_trees_change(cls_data, tmp_path):
    """fed.serve returns the cached compiled server while trees_ is
    unchanged, and refreshes it in place when the model was updated."""
    xtr, ytr, xte, _ = cls_data
    p = ForestParams(n_estimators=3, max_depth=6, n_bins=16, n_classes=3,
                     seed=5)
    fed = Federation(parties=2, n_bins=16)
    fed.ingest(xtr, ytr)
    model = fed.fit(p)
    server = fed.serve(model, buckets=(32, 64))
    server.warmup()
    assert server.compile_count == 2
    assert fed.serve(model, buckets=(32, 64)) is server   # cache hit
    assert server.compile_count == 2                      # no recompiles
    np.testing.assert_array_equal(server.serve(xte), model.predict(xte))

    # refit -> trees_ is a new stack; same handle, same buckets
    model.params = dataclasses.replace(p, n_estimators=5)
    model.fit(fed._partition, ytr)
    server2 = fed.serve(model, buckets=(32, 64))
    assert server2 is server                              # refreshed in place
    assert int(server.trees.is_leaf.shape[1]) == 5
    np.testing.assert_array_equal(server.serve(xte), model.predict(xte))
    assert server.compile_count > 2                       # old execs dropped


# ---------------------------------------------------- serving, all families
def test_serve_boosting_model(reg_data):
    """fed.serve stands up the bucketed async engine for boosting — same
    compile-once contract, outputs match the estimator's predict."""
    rxtr, rytr, rxte, _ = reg_data
    fed = Federation(parties=2, n_bins=16)
    fed.ingest(rxtr, rytr)
    model = fed.fit(BoostParams(n_rounds=4, max_depth=3, n_bins=16))
    server = fed.serve(model, buckets=(32, 64), max_inflight=3)
    assert fed.serve(model, buckets=(32, 64), max_inflight=3) is server
    server.warmup()
    assert server.compile_count == 2
    out = server.serve(rxte)
    # one fused float32 program vs the per-round float64 host accumulation:
    # same ensemble, summation order differs
    np.testing.assert_allclose(out, model.predict(rxte), rtol=1e-4,
                               atol=1e-4)
    assert server.compile_count == 2                      # no recompiles
    # zero-row dtype matches, through the same engine path
    assert server.serve(rxte[:0]).dtype == out.dtype


def test_serve_boosting_binary(cls_data):
    xtr, ytr, xte, _ = cls_data
    fed = Federation(parties=2, n_bins=16)
    fed.ingest(xtr, (ytr == 1).astype(np.float64))
    model = fed.fit(BoostParams(task="binary", n_rounds=3, max_depth=3,
                                n_bins=16))
    server = fed.serve(model, buckets=(64,))
    np.testing.assert_array_equal(server.serve(xte),
                                  model.predict(xte).astype(np.int32))


def test_serve_linear_model(cls_data):
    """fed.serve works for F-LR: raw rows split/standardized per party and
    served through the same bucketed engine."""
    from repro.serving import LinearServer, RequestQueue
    xtr, ytr, xte, _ = cls_data
    fed = Federation(parties=3)
    part = fed.ingest(xtr, ytr)
    model = fed.fit(LinearParams(steps=150))
    server = fed.serve(model, buckets=(32, 128))
    assert isinstance(server, LinearServer)
    server.warmup()
    assert server.compile_count == 2
    want = model.predict(part.split_raw(xte))
    np.testing.assert_array_equal(server.serve(xte), want)
    assert server.compile_count == 2
    # queue traffic over the linear engine too
    q = RequestQueue(server)
    rid = q.submit(xte[:40])
    np.testing.assert_array_equal(q.drain()[rid], want[:40])


def test_serve_autotune_refreshes_buckets(cls_data):
    """serve(autotune_buckets=True) derives the bucket set from traffic and
    refreshes the cached server in place, keeping compile-once per epoch."""
    xtr, ytr, xte, _ = cls_data
    p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, n_classes=3,
                     seed=9)
    fed = Federation(parties=2, n_bins=8)
    fed.ingest(xtr, ytr)
    model = fed.fit(p)
    counts = list(np.random.default_rng(0).integers(1, 120, size=50))
    server = fed.serve(model, autotune_buckets=True, traffic=counts)
    server.warmup()
    assert server.buckets[-1] >= max(counts)
    assert server.compile_count == len(server.buckets)
    for n in (3, 40, 100):
        np.testing.assert_array_equal(server.serve(xte[:n]),
                                      model.predict(xte[:n]))
    assert server.compile_count == len(server.buckets)    # epoch stability
    # next epoch reuses the same cached server (wave_stats-driven retune)
    assert fed.serve(model, autotune_buckets=True) is server


# ------------------------------------------------------------- checkpoints
def test_session_save_load_roundtrip(cls_data, tmp_path):
    """fed.save -> fed.load rehydrates a servable model, reconstructing the
    label decode from (n_classes, seed)."""
    xtr, ytr, xte, _ = cls_data
    p = ForestParams(n_estimators=3, max_depth=5, n_bins=16, n_classes=3,
                     seed=11)
    fed = Federation(parties=3, n_bins=16)
    fed.ingest(xtr, ytr)
    model = fed.fit(p)
    fed.save(model, str(tmp_path))
    restored = fed.load(str(tmp_path), p)
    _trees_equal(model.trees_, restored.trees_)
    np.testing.assert_array_equal(restored.predict(xte), model.predict(xte))
    np.testing.assert_array_equal(fed.predict(restored, xte),
                                  fed.predict(model, xte))


def test_save_load_model_family_tag(reg_data, tmp_path):
    """A saved boosting stack must never silently reload as a forest: save
    tags the family, load dispatches on it and rejects mismatches."""
    rxtr, rytr, rxte, _ = reg_data
    fed = Federation(parties=2, n_bins=16)
    fed.ingest(rxtr, rytr)
    model = fed.fit(BoostParams(n_rounds=3, max_depth=3, n_bins=16))
    d = str(tmp_path / "boost")
    fed.save(model, d)

    with pytest.raises(ValueError, match="boosting"):
        fed.load(d, ForestParams(task="regression", n_estimators=3,
                                 n_bins=16))
    with pytest.raises(ValueError, match="task"):
        fed.load(d, BoostParams(task="binary", n_rounds=3, max_depth=3,
                                n_bins=16))

    restored = fed.load(d, BoostParams(n_rounds=3, max_depth=3, n_bins=16))
    assert restored.base_ == model.base_
    assert len(restored.trees_) == len(model.trees_)
    np.testing.assert_allclose(restored.predict(rxte), model.predict(rxte),
                               rtol=1e-6)
    # and the restored handle serves through the same engine
    server = fed.serve(restored, buckets=(64,))
    np.testing.assert_allclose(server.serve(rxte), model.predict(rxte),
                               rtol=1e-4, atol=1e-4)

    # the reverse mismatch: a forest checkpoint refuses BoostParams
    fmodel = fed.fit(ForestParams(task="regression", n_estimators=2,
                                  max_depth=3, n_bins=16))
    d2 = str(tmp_path / "forest")
    fed.save(fmodel, d2)
    with pytest.raises(ValueError, match="forest"):
        fed.load(d2, BoostParams(n_rounds=2, n_bins=16))


def test_load_untagged_legacy_checkpoint(reg_data, tmp_path):
    """fit_resumable chunks (bare PartyTree snapshots, no meta) still load
    as forests — the pre-tag format stays readable."""
    from repro import ckpt
    rxtr, rytr, rxte, _ = reg_data
    fed = Federation(parties=2, n_bins=16)
    fed.ingest(rxtr, rytr)
    p = ForestParams(task="regression", n_estimators=2, max_depth=3,
                     n_bins=16)
    model = fed.fit(p)
    ckpt.save_checkpoint(str(tmp_path), 2, model.trees_)   # no meta
    restored = fed.load(str(tmp_path), p)
    np.testing.assert_array_equal(restored.predict(rxte),
                                  model.predict(rxte))


# ------------------------------------------------------- sharded substrate
_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core.types import ForestParams
from repro.federation import Federation

mesh = jax.make_mesh((2, 4), ("trees", "parties"))
fed = Federation(parties=4, substrate="sharded", mesh=mesh,
                 hist_impl="scatter")
p = ForestParams(n_classes=2, n_estimators=2, max_depth=5, n_bins=8)
m, n, fp, t = 4, 4096, 8, 4
fit_args = (jax.ShapeDtypeStruct((m, n, fp), jnp.uint8),
            jax.ShapeDtypeStruct((m, fp), jnp.int32),
            jax.ShapeDtypeStruct((t, m * fp), jnp.bool_),
            jax.ShapeDtypeStruct((t, n), jnp.float32),
            jax.ShapeDtypeStruct((n, p.n_stat_channels), jnp.float32))
fit = fed.fit_program(p)
c = jax.jit(fit).lower(*fit_args).compile()
assert c.memory_analysis().temp_size_in_bytes > 0
trees_shape = jax.eval_shape(fit, *fit_args)
pred = fed.predict_program(p, compact=True, mask_dtype=jnp.uint8)
xb_test = jax.ShapeDtypeStruct((m, 512, fp), jnp.uint8)
leaf_idx = jax.ShapeDtypeStruct((t, 2 ** p.max_depth), jnp.int32)
jax.jit(pred).lower(trees_shape, xb_test, leaf_idx).compile()

# boosting builds one tree per round: its T=1 per-round args must NOT shard
# over a multi-shard "trees" axis (tree_sharded=False) — executes eagerly
from repro.core import BoostParams
from repro.data import make_regression
bmesh = jax.make_mesh((2, 1), ("trees", "parties"))
bfed = Federation(parties=1, substrate="sharded", mesh=bmesh)
x, y = make_regression(200, 6, seed=0)
bfed.ingest(x, y, n_bins=8)
bm = bfed.fit(BoostParams(n_rounds=2, max_depth=2, n_bins=8))
assert bfed.predict(bm, x[:32]).shape == (32,)
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_substrate_drydown_lowers():
    """The session's sharded substrate lowers fit + compact predict on a
    (trees, parties) mesh (subprocess so the forced device count never
    leaks into other tests)."""
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SHARDED_OK" in res.stdout


def test_sharded_substrate_validation():
    from repro.federation import resolve_substrate
    with pytest.raises(ValueError, match="mesh"):
        Federation(parties=2, substrate="sharded")
    with pytest.raises(ValueError, match="unknown substrate"):
        resolve_substrate("warp-drive")


def test_run_sharded_matches_run_simulated_single_party():
    """protocol.run_sharded on a 1-device parties mesh == run_simulated."""
    import jax.numpy as jnp
    from repro.core import protocol
    from repro.core.types import PARTY_AXIS
    from repro.launch import mesh as mesh_mod

    def fn(x_i, scale):
        return jax.lax.psum(x_i.sum(), PARTY_AXIS) * scale

    x = jnp.arange(8.0).reshape(1, 8)          # one party's block
    mesh = mesh_mod.make_host_mesh(1, axes=(PARTY_AXIS,), shape=(1,))
    sim = protocol.run_simulated(fn, (x,), (2.0,))
    shd = protocol.run_sharded(fn, (x,), (2.0,), mesh=mesh)
    np.testing.assert_array_equal(np.asarray(sim), np.asarray(shd))


def test_from_checkpoint_with_mesh_derives_party_count(tmp_path):
    """ForestServer.from_checkpoint(mesh=...) must take M from the
    checkpointed stack, not the session default (regression test)."""
    from repro.launch import mesh as mesh_mod
    from repro.serving import ForestServer
    x, y = make_classification(300, 10, 2, seed=31)
    p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, seed=32)
    fed = Federation(parties=1, n_bins=8)
    part = fed.ingest(x[:250], y[:250])
    model = fed.fit(p)
    fed.save(model, str(tmp_path))
    mesh = mesh_mod.make_host_mesh(1, axes=("trees", "parties"),
                                   shape=(1, 1))
    server = ForestServer.from_checkpoint(str(tmp_path), p, mesh=mesh,
                                          partition=part, buckets=(32,))
    np.testing.assert_array_equal(server.serve(x[250:]),
                                  model.predict(x[250:]))


def test_load_respects_fit_time_privacy_flags(cls_data, tmp_path):
    """A forest fitted with encrypt_labels=False must load with the same
    flag (the checkpoint stores no privacy metadata — documented contract);
    the reconstructed decode is only applied to encrypted fits."""
    xtr, ytr, xte, _ = cls_data
    p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, n_classes=3,
                     seed=13)
    fed = Federation(parties=2, n_bins=8)
    fed.ingest(xtr, ytr)
    model = fed.fit(p, encrypt_labels=False)
    fed.save(model, str(tmp_path))
    restored = fed.load(str(tmp_path), p, encrypt_labels=False)
    np.testing.assert_array_equal(restored.predict(xte), model.predict(xte))
