"""Math-level tests for model internals: the chunked SSD scan vs a naive
step-by-step recurrence oracle, RoPE/M-RoPE properties, MoE routing
invariants, ring-buffer cache semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import registry
from repro.configs.base import reduced
from repro.models import layers, ssm


# ---------------------------------------------------------------- SSD scan
def _naive_recurrence(a, xin, bk, cq, h0):
    """h_t = a_t h_{t-1} + xin_t ⊗ bk_t ; y_t = h_t · cq_t  (per head)."""
    b, s, h, p = xin.shape
    n = bk.shape[-1]
    hcur = np.array(h0, np.float64)
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        hcur = (hcur * a[:, t, :, None, None]
                + np.einsum("bhp,bhn->bhpn", xin[:, t], bk[:, t]))
        ys[:, t] = np.einsum("bhpn,bhn->bhp", hcur, cq[:, t])
    return ys, hcur


@pytest.mark.parametrize("s,chunk", [(16, 4), (17, 4), (32, 32), (7, 16)])
def test_chunked_ssd_matches_naive(s, chunk):
    rng = np.random.default_rng(s)
    b, h, p, n = 2, 3, 4, 5
    a = rng.uniform(0.6, 1.0, (b, s, h))
    xin = rng.normal(size=(b, s, h, p))
    bk = rng.normal(size=(b, s, h, n))
    cq = rng.normal(size=(b, s, h, n))
    h0 = rng.normal(size=(b, h, p, n))
    want_y, want_h = _naive_recurrence(a, xin, bk, cq, h0)
    got_y, got_h = ssm.chunked_ssd(
        jnp.asarray(a, jnp.float32), jnp.asarray(xin, jnp.float32),
        jnp.asarray(bk, jnp.float32), jnp.asarray(cq, jnp.float32),
        jnp.asarray(h0, jnp.float32), chunk)
    np.testing.assert_allclose(got_y, want_y, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_h, want_h, rtol=2e-4, atol=2e-4)


def test_ssd_decode_step_matches_scan_tail():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 1, 9, 2, 3, 4
    a = jnp.asarray(rng.uniform(0.5, 1.0, (b, s, h)), jnp.float32)
    xin = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    bk = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cq = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    y_all, h_all = ssm.chunked_ssd(a, xin, bk, cq, h0, chunk=4)
    # run first s-1 steps, then one decode step
    y_pre, h_pre = ssm.chunked_ssd(a[:, :-1], xin[:, :-1], bk[:, :-1],
                                   cq[:, :-1], h0, chunk=4)
    y_last, h_last = ssm.ssd_decode_step(a[:, -1:], xin[:, -1:], bk[:, -1:],
                                         cq[:, -1:], h_pre)
    np.testing.assert_allclose(y_last[:, 0], y_all[:, -1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h_last, h_all, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 40), st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_ssd_state_decay_bound(s, chunk):
    """With |decay|<=1 and bounded inputs the state norm stays bounded
    (numerical-stability property the 500k-decode path relies on)."""
    rng = np.random.default_rng(s * 7 + chunk)
    b, h, p, n = 1, 2, 3, 3
    a = jnp.asarray(rng.uniform(0.0, 1.0, (b, s, h)), jnp.float32)
    xin = jnp.asarray(rng.uniform(-1, 1, (b, s, h, p)), jnp.float32)
    bk = jnp.asarray(rng.uniform(-1, 1, (b, s, h, n)), jnp.float32)
    cq = jnp.asarray(rng.uniform(-1, 1, (b, s, h, n)), jnp.float32)
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    y, hf = ssm.chunked_ssd(a, xin, bk, cq, h0, chunk)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(hf)).max() <= s * np.sqrt(p * n) + 1e-3


# ------------------------------------------------------------------- RoPE
def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = layers.apply_rope(x, pos, theta=1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rot(q,i), rot(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    def dot_at(i, j):
        qi = layers.apply_rope(q, jnp.array([[i]]), 1e4)
        kj = layers.apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)
    assert dot_at(3, 1) != pytest.approx(dot_at(3, 2), rel=1e-3)


def test_mrope_sections_cover_head_dim():
    cfg = registry.get("qwen2-vl-2b")
    assert sum(cfg.mrope_sections) == cfg.head_dim // 2
    x = jnp.ones((1, 4, 2, cfg.head_dim), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4)[None, None], (3, 1, 4))
    y = layers.apply_rope(x, pos, cfg.rope_theta, cfg.mrope_sections)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


# -------------------------------------------------------------------- MoE
def test_moe_routing_conservation():
    """With no-drop capacity, each token's output = gate-weighted sum of its
    top-k experts; router mass conserved."""
    cfg = reduced(registry.get("phi3.5-moe-42b-a6.6b"))
    p = layers.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, cfg.d_model)),
                    jnp.float32)
    y, aux = layers.moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.5  # load-balance loss ~1 for near-uniform router

    # manual dense check: same result computed expert-by-expert
    t = 2 * 8
    xf = x.reshape(t, -1)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gv, idx = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros((t, cfg.d_model), np.float32)
    for e in range(cfg.n_experts):
        g = jax.nn.silu((xf @ p["we_gate"][e]).astype(jnp.float32))
        u = xf @ p["we_up"][e]
        ye = (g * u.astype(jnp.float32)).astype(x.dtype) @ p["we_down"][e]
        for kk in range(cfg.top_k):
            sel = np.asarray(idx[:, kk] == e)
            want[sel] += np.asarray(gv[:, kk])[sel, None] * np.asarray(ye)[sel]
    if "shared" in p:
        want += np.asarray(layers.mlp(p["shared"], xf[None])[0])
    np.testing.assert_allclose(np.asarray(y.reshape(t, -1)), want,
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------- ring buffer
def test_ring_buffer_cache_eviction_semantics():
    """Sliding-window decode: cache slot reuse keeps exactly the last
    `window` positions visible."""
    cfg = reduced(registry.get("glm4-9b")).with_(sliding_window=8)
    from repro.models import transformer
    params = transformer.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 21)))
    # path A: prefill 12, decode 9
    _, cache = transformer.prefill(params, toks[:, :12], cfg, {})
    for i in range(12, 21):
        la, cache = transformer.decode_step(params, cache, toks[:, i:i + 1],
                                            jnp.int32(i), cfg)
    # path B: prefill 20, decode last
    _, cache_b = transformer.prefill(params, toks[:, :20], cfg, {})
    lb, _ = transformer.decode_step(params, cache_b, toks[:, 20:21],
                                    jnp.int32(20), cfg)
    np.testing.assert_allclose(np.asarray(la, np.float32),
                               np.asarray(lb, np.float32), rtol=2e-2, atol=2e-2)
