import os

# Smoke tests and benches must see the single real CPU device. The 512-device
# dry-run sets XLA_FLAGS itself in launch/dryrun.py __main__ (never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
