import os

# Smoke tests and benches must see the single real CPU device. The 512-device
# dry-run sets XLA_FLAGS itself in launch/dryrun.py __main__ (never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Run the whole suite with the privacy egress guard armed: every
# Channel.send (coordinator AND spawned party workers, which inherit the
# env) refuses raw-tagged arrays.  Normal traffic must be bit-identical
# with the guard on — that's part of what the suite proves.
os.environ.setdefault("REPRO_EGRESS_GUARD", "1")
