"""Substrate-layer tests: optimizer, checkpointing, data pipeline, F-LR,
crypto, sharding rules, end-to-end small training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import crypto
from repro.core.fedlinear import FederatedLinear, split_columns
from repro.data import make_classification, make_regression
from repro.data.metrics import accuracy, f1_binary, rmse, ztest_two_sample
from repro.train import optim


def test_adamw_converges_quadratic():
    w = {"a": jnp.array([3.0, -2.0]), "b": jnp.array(5.0)}
    opt = optim.adamw_init(w)

    def loss(p):
        return (p["a"] ** 2).sum() + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(w)
        w, opt = optim.adamw_update(w, g, opt, lr=5e-2, weight_decay=0.0)
    assert float(loss(w)) < 1e-3


def test_cosine_lr_schedule():
    lrs = [float(optim.cosine_lr(jnp.array(s), peak=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0           # warmup rises
    assert lrs[99] < 0.2                    # decays toward floor
    assert max(lrs) <= 1.0 + 1e-6


def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16)},
            "lst": [jnp.array(3), jnp.array([1, 2])]}
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    back = restore_checkpoint(tmp_path, 7, jax.tree.map(lambda x: x, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fedlinear_classification_parity():
    """F-LR with M parties == single-party logistic regression (exact: the
    psum of block dots IS the full dot)."""
    x, y = make_classification(600, 20, 2, seed=4)
    f1 = FederatedLinear().fit([x[:500]], y[:500])
    f3 = FederatedLinear().fit(split_columns(x[:500], 3), y[:500])
    p1 = f1.predict([x[500:]])
    p3 = f3.predict(split_columns(x[500:], 3))
    assert np.mean(p1 == p3) > 0.99
    assert accuracy(y[500:], p3) > 0.7


def test_fedlinear_regression():
    x, y = make_regression(600, 15, nonlinear=False, noise=0.1, seed=5)
    fl = FederatedLinear(task="regression", lr=0.3, steps=600).fit(
        split_columns(x[:500], 2), y[:500])
    pred = fl.predict(split_columns(x[500:], 2))
    assert rmse(y[500:], pred) < 0.5 * np.std(y[500:])


def test_crypto_id_alignment():
    a = crypto.hash_ids(np.array([10, 11, 12, 13]))
    b = crypto.hash_ids(np.array([12, 13, 14]))
    ia, ib = crypto.align_ids(a, b)
    assert len(ia) == 2
    assert set(zip(ia.tolist(), ib.tolist())) == {(2, 0), (3, 1)}


def test_crypto_label_roundtrip():
    y = np.array([0, 1, 2, 1, 0])
    y_enc, dec = crypto.encode_labels(y, 3, seed=1)
    assert not np.array_equal(y, y_enc) or True  # permutation may be identity
    np.testing.assert_array_equal(dec(y_enc), y)
    yr = np.random.default_rng(0).normal(size=10)
    yr_m, dec_r = crypto.mask_regression_targets(yr, seed=2)
    np.testing.assert_allclose(dec_r(yr_m), yr, atol=1e-9)


def test_pairwise_masks_cancel():
    m = crypto.pairwise_cancelling_masks(5, (3, 2), seed=3)
    np.testing.assert_allclose(m.sum(0), 0.0, atol=1e-5)


def test_ztest_sanity():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 1, 200)
    _, p_same = ztest_two_sample(a, a + rng.normal(0, 0.01, 200))
    _, p_diff = ztest_two_sample(a, a + 1.0)
    assert p_same > 0.05 and p_diff < 0.01


def test_f1_binary():
    assert f1_binary([1, 1, 0, 0], [1, 0, 0, 0]) == pytest.approx(2 / 3)


def test_training_reduces_ce_end_to_end():
    """examples/train_transformer.py contract at tiny scale."""
    from repro.configs import registry
    from repro.launch.train import train_loop
    cfg = registry.get("internlm2-1.8b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, dtype="float32", remat="none")
    _, losses = train_loop(cfg, steps=30, batch=4, seq=32, lr=3e-3,
                           log_every=29)
    assert losses[-1] < losses[0]


def test_sharding_rules_divisibility():
    """Every param spec must divide the mesh axes it names (on shapes from
    all 10 archs) — the invariant the dry-run relies on."""
    from repro import compat
    from repro.configs import registry as reg
    from repro.models import sharding, transformer
    # AbstractMesh: full production topology without needing 256 devices
    # (constructed via compat — the ctor signature changed across jax versions)
    mesh = compat.abstract_mesh((16, 16), ("data", "model"))
    for arch in reg.ARCH_IDS:
        cfg = reg.get(arch)
        shapes = jax.eval_shape(
            lambda k, c=cfg: transformer.init_params(k, c), jax.random.key(0))
        specs = sharding.param_specs(shapes, mesh)

        def check(path, leaf, spec):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
                if ax is None:
                    continue
                size = (np.prod([mesh.shape[a] for a in ax])
                        if isinstance(ax, tuple) else mesh.shape[ax])
                assert dim % size == 0, (arch, path, leaf.shape, spec)

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), shapes, specs)
