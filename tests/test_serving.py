"""Serving engine tests: leaf-compacted prediction bit-identity, the
bucket/pad/compile-once ForestServer contract, checkpoint loading, and the
vote-impl parity matrix.

The load-bearing claim mirrors the builder's: compaction only drops dead
mask columns, so the one-round prediction through a LeafTable is
BIT-IDENTICAL to the dense path — classification and regression, aggregated
and per-tree, across party counts (the multi-party run_simulated path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt
from repro.core import (ForestParams, fit_federated_forest, prediction,
                        protocol)
from repro.data import make_classification, make_regression
from repro.serving import ForestServer, RequestQueue, load_forest_trees


@pytest.fixture(scope="module")
def cls_forest():
    x, y = make_classification(900, 24, 3, seed=0)
    p = ForestParams(n_classes=3, n_estimators=5, max_depth=8, n_bins=16,
                     seed=1)
    return fit_federated_forest(x[:700], y[:700], 3, p), x[700:]


@pytest.fixture(scope="module")
def reg_forest():
    x, y = make_regression(600, 18, seed=2)
    p = ForestParams(task="regression", n_estimators=4, max_depth=7,
                     n_bins=16, seed=3)
    return fit_federated_forest(x[:450], y[:450], 2, p), x[450:]


def _run_spmd(ff, x_test, **kw):
    """forest_predict_oneround through the multi-party run_simulated path."""
    xb = jnp.asarray(ff.partition_.bin_test(np.asarray(x_test)))

    def fn(trees, xbt):
        return prediction.forest_predict_oneround(trees, xbt, ff.params, **kw)
    return np.asarray(protocol.run_simulated(fn, (ff.trees_, xb)))


# --------------------------------------------------------------- leaf table
def test_leaf_table_structure(cls_forest):
    ff, _ = cls_forest
    lt = ff.leaf_table()
    is_leaf = np.asarray(ff.trees_.is_leaf[0])               # shared view
    t, nn = is_leaf.shape
    idx, n_live = np.asarray(lt.leaf_idx), np.asarray(lt.n_live)
    assert lt.capacity <= ff.params.max_leaves
    np.testing.assert_array_equal(n_live, is_leaf.sum(1))
    for i in range(t):
        ids = idx[i][idx[i] >= 0]
        assert len(ids) == n_live[i] <= lt.capacity
        assert (np.diff(ids) > 0).all()                      # heap order
        assert is_leaf[i][ids].all()                         # only live leaves
        assert (idx[i][n_live[i]:] == -1).all()              # tail is padding


# ------------------------------------------------- bit-identity, all routes
def test_compact_bit_identical_classification(cls_forest):
    ff, xte = cls_forest
    np.testing.assert_array_equal(ff.predict(xte), ff.predict_compact(xte))


def test_compact_bit_identical_regression(reg_forest):
    ff, xte = reg_forest
    dense, compact = ff.predict(xte), ff.predict_compact(xte)
    assert dense.dtype == compact.dtype
    np.testing.assert_array_equal(dense, compact)            # bit-identical


@pytest.mark.parametrize("aggregate", [True, False])
def test_compact_bit_identical_per_tree(cls_forest, aggregate):
    """The tree-sharded production hook (aggregate=False) compacts too."""
    ff, xte = cls_forest
    lt = ff.leaf_table()
    dense = _run_spmd(ff, xte, aggregate=aggregate)
    compact = _run_spmd(ff, xte, aggregate=aggregate,
                        leaf_idx=lt.leaf_idx)
    np.testing.assert_array_equal(dense, compact)


def test_compact_mask_columns_match_dense(cls_forest):
    """Column j of the compact mask IS dense column leaf_idx[j] (per party)."""
    ff, xte = cls_forest
    lt = ff.leaf_table()
    xb = jnp.asarray(ff.partition_.bin_test(np.asarray(xte)))[0]  # party 0
    tree0 = jax.tree.map(lambda a: a[0, 0], ff.trees_)
    dense = np.asarray(prediction.tree_leaf_membership(
        tree0, xb, ff.params))
    compact = np.asarray(prediction.tree_leaf_membership_compact(
        tree0, xb, ff.params, lt.leaf_idx[0]))
    idx = np.asarray(lt.leaf_idx[0])
    valid = idx >= 0
    np.testing.assert_array_equal(compact[:, valid], dense[:, idx[valid]])
    assert not compact[:, ~valid].any()                      # padding is dead


# ----------------------------------------------------- vote-impl parity
@pytest.mark.parametrize("aggregate", [True, False])
@pytest.mark.parametrize("compact", [False, True])
def test_vote_impl_parity(cls_forest, aggregate, compact):
    """argmax (masked-max over int8 leaf labels) == einsum vote, aggregated
    and per-tree, dense and leaf-compacted: each sample hits exactly one
    leaf, so both reduce the same single nonzero contribution."""
    ff, xte = cls_forest
    li = ff.leaf_table().leaf_idx if compact else None
    ein = _run_spmd(ff, xte, aggregate=aggregate, vote_impl="einsum",
                    leaf_idx=li)
    arg = _run_spmd(ff, xte, aggregate=aggregate, vote_impl="argmax",
                    leaf_idx=li)
    np.testing.assert_array_equal(ein, arg)


# -------------------------------------------------- checkpoint round-trip
def test_forest_checkpoint_roundtrip(cls_forest, tmp_path):
    """save/restore of the fitted PartyTree stack through ckpt/checkpoint.py
    — the exact load path ForestServer.from_checkpoint depends on."""
    ff, xte = cls_forest
    ckpt.save_checkpoint(tmp_path, 5, ff.trees_)
    restored = load_forest_trees(str(tmp_path))              # latest step
    for a, b in zip(jax.tree_util.tree_leaves(ff.trees_),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    server = ForestServer.from_checkpoint(
        str(tmp_path), ff.params, buckets=(64, 256),
        partition=ff.partition_, decode=ff._decode)
    np.testing.assert_array_equal(server.serve(xte), ff.predict(xte))


# ------------------------------------------------------------- the server
def test_server_compile_once_across_buckets(cls_forest):
    """>= 3 buckets serve after warmup with zero recompilation, and every
    batch size routes to the right bucket."""
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(8, 32, 128))
    server.warmup()
    assert server.compile_count == 3
    want = ff.predict(xte)
    for n in (3, 8, 20, 32, 97, 128, 60, 5):                 # hits all buckets
        got = server.serve(xte[:n])
        np.testing.assert_array_equal(got, want[:n])
    assert server.compile_count == 3                         # no recompiles
    buckets_used = {w["bucket"] for w in server.wave_stats}
    assert buckets_used == {8, 32, 128}
    stats = server.stats_summary()
    assert stats["waves"] == 8 and stats["rows_per_s"] > 0


def test_server_micro_batches_oversized_requests(cls_forest):
    """Requests above the largest bucket run as waves of that bucket."""
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(16, 64))
    n = len(xte)                                             # 200 > 64
    got = server.serve(xte)
    np.testing.assert_array_equal(got, ff.predict(xte))
    # 200 rows -> three 64-row waves + one 8-row tail (16-bucket): exactly
    # the two bucket executables, nothing per-request
    assert server.compile_count == 2
    assert sum(w["n_rows"] for w in server.wave_stats) == n
    assert [w["bucket"] for w in server.wave_stats] == [64, 64, 64, 16]


def test_server_dense_equals_compact(cls_forest):
    ff, xte = cls_forest
    dense = ForestServer.from_forest(ff, compact=False, buckets=(64,))
    compact = ForestServer.from_forest(ff, compact=True, buckets=(64,))
    np.testing.assert_array_equal(dense.serve(xte), compact.serve(xte))
    # and the compact psum payload is strictly smaller
    assert (compact.wave_stats[-1]["comm_bytes"]
            < dense.wave_stats[-1]["comm_bytes"])


def test_server_sharded_mode_single_device(cls_forest):
    """run_sharded execution (shard_map over a (trees, parties) mesh with
    the aggregate=False hook) — a 1x1 host mesh serving a 1-party forest
    matches the estimator, and stays compile-once."""
    from repro.data import make_classification
    from repro.launch import mesh as mesh_mod
    x, y = make_classification(400, 12, 2, seed=21)
    p = ForestParams(n_estimators=3, max_depth=5, n_bins=16, seed=22)
    ff = fit_federated_forest(x[:300], y[:300], 1, p)
    mesh = mesh_mod.make_host_mesh(1, axes=("trees", "parties"), shape=(1, 1))
    server = ForestServer.from_forest(ff, mesh=mesh, buckets=(32, 64))
    server.warmup()
    np.testing.assert_array_equal(server.serve(x[300:]), ff.predict(x[300:]))
    assert server.compile_count == 2


def test_server_regression_task(reg_forest):
    ff, xte = reg_forest
    server = ForestServer.from_forest(ff, buckets=(32, 128))
    np.testing.assert_array_equal(server.serve(xte), ff.predict(xte))


def test_server_empty_batch(cls_forest):
    """A zero-row request is ordinary traffic: empty output, no wave."""
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(32,))
    out = server.serve(xte[:0])
    assert out.shape == (0,)
    assert len(server.wave_stats) == 0


# -------------------------------------------------------------- the queue
def test_queue_coalesces_and_scatters(cls_forest):
    """Requests of mixed sizes share waves; each gets its own rows back."""
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(64,))
    queue = RequestQueue(server, max_wave_rows=64)
    want = ff.predict(xte)
    sizes, rids, lo = [5, 50, 90, 1, 17], [], 0
    spans = []
    for s in sizes:
        rids.append(queue.submit(xte[lo:lo + s]))
        spans.append((lo, s))
        lo += s
    results = queue.drain()
    assert set(results) == set(rids)
    for rid, (start, s) in zip(rids, spans):
        np.testing.assert_array_equal(results[rid], want[start:start + s])
    assert len(queue.request_stats) == len(sizes)
    # 163 rows through 64-row waves -> at most ceil(163/64)+fragmentation
    assert len(server.wave_stats) <= 5


def test_queue_zero_row_request_does_not_wedge(cls_forest):
    """A zero-row request retires cleanly and later requests still serve."""
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(32,))
    queue = RequestQueue(server)
    r0 = queue.submit(xte[:0])
    r1 = queue.submit(xte[:7])
    results = queue.drain()
    assert results[r0].shape == (0,)
    np.testing.assert_array_equal(results[r1], ff.predict(xte[:7]))
    # drained queue serves follow-up traffic too
    r2 = queue.submit(xte[7:12])
    np.testing.assert_array_equal(queue.drain()[r2], ff.predict(xte[7:12]))


def test_queue_cross_wave_request_spanning(cls_forest):
    """One request split over >= 2 waves/buckets scatters back correctly,
    also through the async pump (in-flight ring > 1)."""
    ff, xte = cls_forest
    want = ff.predict(xte)
    for inflight in (1, 3):
        server = ForestServer.from_forest(ff, buckets=(16, 64),
                                          max_inflight=inflight)
        queue = RequestQueue(server, max_wave_rows=64)
        big = queue.submit(xte)                  # 200 rows -> >= 4 waves
        small = queue.submit(xte[:5])
        results = queue.drain()
        np.testing.assert_array_equal(results[big], want)
        np.testing.assert_array_equal(results[small], want[:5])
        assert len(server.wave_stats) >= 4       # genuinely spanned waves


@pytest.mark.parametrize("mask_regression", [False, True])
def test_queue_zero_row_dtype_matches_decoded(mask_regression):
    """Zero-row results come from the engine's decode path, so their dtype
    matches non-empty decoded outputs — including the masked-regression
    unmasker, whose output dtype differs from the raw program output."""
    x, y = make_regression(400, 10, seed=4)
    p = ForestParams(task="regression", n_estimators=2, max_depth=4,
                     n_bins=16, seed=5)
    ff = fit_federated_forest(x[:300], y[:300], 2, p,
                              mask_regression=mask_regression)
    server = ForestServer.from_forest(ff, buckets=(32,))
    queue = RequestQueue(server)
    rz, rn = queue.submit(x[:0]), queue.submit(x[300:340])
    results = queue.drain()
    assert results[rz].dtype == results[rn].dtype
    assert results[rz].shape == (0,)
    assert server.serve(x[:0]).dtype == results[rn].dtype
    np.testing.assert_array_equal(results[rn], ff.predict(x[300:340]))


@pytest.mark.parametrize("task", ["classification", "regression"])
def test_queue_drain_parity_with_serve(cls_forest, reg_forest, task):
    """Decode lives in exactly one layer (engine.collect): raw rows through
    queue.submit+drain == server.serve, both tasks, values AND dtype."""
    ff, xte = cls_forest if task == "classification" else reg_forest
    server = ForestServer.from_forest(ff, buckets=(32, 64))
    queue = RequestQueue(server)
    rids = [queue.submit(xte[:50]), queue.submit(xte[50:83])]
    results = queue.drain()
    direct = server.serve(xte[:83])
    got = np.concatenate([results[rids[0]], results[rids[1]]])
    assert got.dtype == direct.dtype
    np.testing.assert_array_equal(got, direct)
    np.testing.assert_array_equal(direct, ff.predict(xte[:83]))


# -------------------------------------------------------- async wave ring
@pytest.mark.parametrize("fixture", ["cls", "reg"])
def test_async_bit_identical_to_sync(cls_forest, reg_forest, fixture):
    """The async pipeline (bounded in-flight ring) is bit-identical to the
    sync path on mixed-size traffic — same executables, FIFO collection."""
    ff, xte = cls_forest if fixture == "cls" else reg_forest
    sync = ForestServer.from_forest(ff, buckets=(16, 64), max_inflight=1)
    asyn = ForestServer.from_forest(ff, buckets=(16, 64), max_inflight=4)
    got_s, got_a = sync.serve(xte), asyn.serve(xte)   # spans several waves
    assert got_s.dtype == got_a.dtype
    np.testing.assert_array_equal(got_s, got_a)
    # the async ring actually ran deeper than one in-flight wave
    assert max(w["inflight"] for w in asyn.wave_stats) > 1
    assert max(w["inflight"] for w in sync.wave_stats) == 1
    # queue traffic too: mixed request sizes through both pumps
    want = ff.predict(xte)
    for server in (sync, asyn):
        q = RequestQueue(server, max_wave_rows=64)
        rids = [q.submit(xte[lo:lo + s])
                for lo, s in ((0, 5), (5, 90), (95, 33), (128, 1))]
        res = q.drain()
        for rid, (lo, s) in zip(rids, ((0, 5), (5, 90), (95, 33), (128, 1))):
            np.testing.assert_array_equal(res[rid], want[lo:lo + s])


def test_dispatch_wave_rejects_oversized_and_empty(cls_forest):
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(16,))
    xb = ff.partition_.bin_test(np.asarray(xte))
    with pytest.raises(ValueError, match="wave of"):
        server.dispatch_wave(xb[:, :17])
    with pytest.raises(ValueError, match="wave of"):
        server.dispatch_wave(xb[:, :0])


def test_queue_drain_failure_leaves_rows_redispatchable(cls_forest):
    """A dispatch failure mid-pump must not strand dispatched-but-unserved
    rows: sent cursors roll back to done, so a retry serves everything."""
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(16, 64), max_inflight=2)
    queue = RequestQueue(server, max_wave_rows=64)
    rids = [queue.submit(xte[:90]), queue.submit(xte[90:120])]
    real_dispatch, boom = server.dispatch_wave, [True]

    def failing(xb):
        if boom[0]:
            boom[0] = False
            raise RuntimeError("transient dispatch failure")
        return real_dispatch(xb)

    server.dispatch_wave = failing
    with pytest.raises(RuntimeError):
        queue.drain()
    assert server._n_inflight == 0               # discarded ring was drained
    server.dispatch_wave = real_dispatch
    results = queue.drain()                      # retry serves every row
    want = ff.predict(xte[:120])
    np.testing.assert_array_equal(results[rids[0]], want[:90])
    np.testing.assert_array_equal(results[rids[1]], want[90:120])
    # a bad binned request is rejected at submit, not mid-pump
    with pytest.raises(ValueError, match="width"):
        queue.submit(np.zeros((server.n_parties, 4, server._fp() + 1),
                              np.uint8), binned=True)
    with pytest.raises(ValueError, match="binned request"):
        queue.submit(np.zeros((server.n_parties + 2, 4, server._fp()),
                              np.uint8), binned=True)


# ----------------------------------------------- serving-path guard rails
def test_serve_binned_rejects_width_mismatch(cls_forest):
    """A batch whose per-party width differs from the bound width must fail
    loudly up front, not with an opaque XLA shape error mid-wave."""
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(32,))
    fp = server._fp()
    bad = np.zeros((server.n_parties, 10, fp + 3), np.uint8)
    with pytest.raises(ValueError, match=rf"width {fp + 3}.*width {fp}"):
        server.serve_binned(bad)
    # a width-free server binds the first width it sees, then holds it
    free = ForestServer(ff.trees_, ff.params, buckets=(32,),
                        n_features_per_party=fp)
    with pytest.raises(ValueError, match="width"):
        free.serve_binned(bad)


def test_strip_raises_on_unexpected_rank(cls_forest):
    """Per-tree / multi-output shapes must not be sliced silently (the old
    code took out[0] of ANY multi-dim output)."""
    ff, _ = cls_forest
    server = ForestServer.from_forest(ff, buckets=(32,))
    with pytest.raises(ValueError, match="unexpected shape"):
        server._strip(np.zeros((4, 5, 6)), 5)
    with pytest.raises(ValueError, match="unexpected shape"):
        server._strip(np.zeros((server.n_parties + 1, 5)), 5)
    # the two legitimate shapes pass
    assert server._strip(np.arange(8), 5).shape == (5,)
    assert server._strip(np.zeros((server.n_parties, 8)), 5).shape == (5,)


# ------------------------------------------------------- bucket autotuning
def test_autotune_buckets_from_traffic():
    from repro.serving import autotune_buckets, observed_row_counts
    rng = np.random.default_rng(0)
    counts = rng.integers(1, 300, size=100)
    buckets = autotune_buckets(counts, warm=(32, 256, 2048))
    assert list(buckets) == sorted(set(buckets))          # ascending/unique
    assert len(buckets) <= 4
    assert buckets[-1] >= counts.max()                    # covers the max
    # too little traffic -> warm start unchanged
    assert autotune_buckets([5, 7], warm=(32, 256)) == (32, 256)
    # stats-record extraction: wave_stats and request_stats shapes
    rows = observed_row_counts([{"n_rows": 3}, {"rows": 9}, {"n_rows": 0}],
                               [4, 0])
    assert rows.tolist() == [3, 9, 4]


def test_autotuned_buckets_compile_once(cls_forest):
    """A server retuned from observed traffic compiles each bucket exactly
    once per autotune epoch: warmup compiles len(buckets), traffic that
    fits recompiles nothing, surviving buckets keep their executables."""
    from repro.serving import autotune_buckets
    ff, xte = cls_forest
    server = ForestServer.from_forest(ff, buckets=(32, 128))
    server.warmup()
    assert server.compile_count == 2
    for n in (3, 30, 100, 128):                  # observe traffic
        server.serve(xte[:n])
    assert server.compile_count == 2
    tuned = autotune_buckets(server.wave_stats, warm=server.buckets,
                             min_observations=4)
    server.set_buckets(tuned)
    server.warmup()
    epoch_compiles = server.compile_count
    assert epoch_compiles <= 2 + len(tuned)      # survivors kept their exec
    for n in (3, 30, 100, int(tuned[-1])):       # epoch traffic
        got = server.serve(xte[:n])
        np.testing.assert_array_equal(got, ff.predict(xte[:n]))
    assert server.compile_count == epoch_compiles  # compile-once per epoch
    # 128 survived the retune (traffic hit it), so its executable was kept
    if 128 in tuned:
        assert epoch_compiles < 2 + len(tuned)
