"""Frontier-compacted builder invariants (the tentpole's losslessness claim)
and the histogram backend registry.

The compacted build must be BIT-IDENTICAL to the dense build — same
``PartyTree`` arrays, same predictions — on both tasks, with single-pass and
multi-pass (tiny cap) compaction, and under tree batching.  Compaction is a
pure re-indexing of histogram rows; any deviation means it changed which
samples a node accumulates, which would break the paper's FF(M) == FF(1)
guarantee downstream.
"""
import jax
import numpy as np
import pytest

from repro.core import ForestParams, fit_federated_forest
from repro.data import make_classification, make_regression
from repro.kernels import ops


def _assert_same_forest(a, b):
    ta = jax.tree.map(np.asarray, a.trees_)
    tb = jax.tree.map(np.asarray, b.trees_)
    for field in ta._fields:
        np.testing.assert_array_equal(
            getattr(ta, field), getattr(tb, field), err_msg=field)


# deep + small-N: levels beyond depth log2(cap) engage the compacted path
_DEEP = dict(n_estimators=3, max_depth=9, n_bins=8, seed=3)


def test_frontier_bit_identical_classification():
    x, y = make_classification(300, 12, 2, seed=0)
    dense = fit_federated_forest(
        x, y, 3, ForestParams(frontier_cap=0, **_DEEP))
    frontier = fit_federated_forest(
        x, y, 3, ForestParams(frontier_cap=64, **_DEEP))
    _assert_same_forest(dense, frontier)
    np.testing.assert_array_equal(dense.predict(x), frontier.predict(x))


def test_frontier_bit_identical_regression():
    x, y = make_regression(250, 8, seed=2)
    deep = dict(task="regression", n_estimators=2, max_depth=8, n_bins=8,
                seed=1)
    dense = fit_federated_forest(
        x, y, 2, ForestParams(frontier_cap=0, **deep))
    frontier = fit_federated_forest(
        x, y, 2, ForestParams(frontier_cap=32, **deep))
    _assert_same_forest(dense, frontier)
    np.testing.assert_allclose(dense.predict(x), frontier.predict(x),
                               rtol=0, atol=0)


def test_frontier_multipass_tiny_cap():
    """cap=4 forces the while_loop through many passes per level — the
    scatter-back must still reassemble the exact dense level results."""
    x, y = make_classification(200, 10, 3, seed=1)
    deep = dict(n_classes=3, n_estimators=2, max_depth=8, n_bins=8, seed=5)
    dense = fit_federated_forest(
        x, y, 2, ForestParams(frontier_cap=0, **deep))
    frontier = fit_federated_forest(
        x, y, 2, ForestParams(frontier_cap=4, **deep))
    _assert_same_forest(dense, frontier)
    np.testing.assert_array_equal(dense.predict(x), frontier.predict(x))


def test_frontier_composes_with_hist_subtraction():
    """Dense shallow levels may use the subtraction trick while deep levels
    compact; classification subtraction is exact, so the forest still
    matches the plain dense build bit-for-bit."""
    x, y = make_classification(200, 10, 3, seed=1)
    deep = dict(n_classes=3, n_estimators=2, max_depth=8, n_bins=8, seed=5)
    dense = fit_federated_forest(
        x, y, 2, ForestParams(frontier_cap=0, **deep))
    both = fit_federated_forest(
        x, y, 2, ForestParams(frontier_cap=16, hist_subtraction=True, **deep))
    _assert_same_forest(dense, both)


def test_trees_per_batch_identical():
    """vmap-batched bagging (incl. the T % batch != 0 padding path) builds
    the same trees as the seed's pure lax.map — with deep levels and a tiny
    frontier_cap so the batched build also exercises the compacted
    while_loop (the tentpole's two mechanisms composed, not in isolation).
    """
    x, y = make_classification(200, 10, 3, seed=1)
    base = dict(n_classes=3, n_estimators=5, max_depth=8, n_bins=8, seed=7,
                frontier_cap=8)
    one = fit_federated_forest(
        x, y, 2, ForestParams(trees_per_batch=1, **base))
    batched = fit_federated_forest(
        x, y, 2, ForestParams(trees_per_batch=3, **base))
    _assert_same_forest(one, batched)
    np.testing.assert_array_equal(one.predict(x), batched.predict(x))
    # and the batched frontier build still matches the dense lax.map build
    dense = fit_federated_forest(
        x, y, 2, ForestParams(**{**base, "frontier_cap": 0}))
    _assert_same_forest(dense, batched)


# --------------------------------------------------- histogram backend registry
def test_registry_contents_and_auto_resolution():
    for name in ("scatter", "pallas", "pallas_interpret", "ref"):
        assert name in ops.available_backends()
    resolved = ops.resolve_backend("auto")
    assert resolved in ops.BACKENDS
    if jax.default_backend() == "cpu":
        assert resolved == "scatter"
    with pytest.raises(ValueError, match="unknown impl"):
        ops.resolve_backend("nope")


def test_registry_extension_point():
    calls = []

    @ops.register_backend("_test_probe")
    def probe(xb, seg, stats, n_level, n_bins):
        calls.append(n_level)
        return ops.BACKENDS["scatter"](xb, seg, stats, n_level, n_bins)

    try:
        rng = np.random.default_rng(0)
        xb = rng.integers(0, 4, (64, 3)).astype(np.int32)
        seg = rng.integers(-1, 2, (64,)).astype(np.int32)
        stats = rng.normal(size=(64, 2)).astype(np.float32)
        got = ops.histogram(xb, seg, stats, 2, 4, impl="_test_probe")
        want = ops.histogram(xb, seg, stats, 2, 4, impl="scatter")
        np.testing.assert_allclose(got, want, rtol=0, atol=0)
        assert calls == [2]
    finally:
        del ops.BACKENDS["_test_probe"]


def test_params_knob_validation():
    with pytest.raises(ValueError, match="frontier_cap"):
        ForestParams(frontier_cap=-1)
    with pytest.raises(ValueError, match="trees_per_batch"):
        ForestParams(trees_per_batch=0)
