"""Substrate-conformance suite: every registered substrate, one contract.

Parameterized over the ``SUBSTRATES`` registry, so a newly registered
substrate is pulled into the suite automatically (and fails loudly until
this file's fixture knows how to build it).  The contract under test:

  * the toy two-collective protocol (``toy_affine``: all_gather + psum +
    axis_index) is BIT-IDENTICAL to the vmap simulation at the same party
    count — the same oracle the forest fit/predict programs rely on;
  * the lifecycle seams behave: ``compile`` returns an executable with
    unchanged semantics, ``context`` is re-enterable, ``exchange`` is the
    transport seam (None in-process, a real round trip distributed),
    ``shutdown`` is idempotent;
  * ``resolve_substrate`` validates party counts and rejects unknown names
    with the registry listing;
  * ``register_substrate`` round-trips a new factory through resolution.
"""
import contextlib

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.federation import distributed
from repro.federation.substrate import (SUBSTRATES, SimulatedSubstrate,
                                        register_substrate, resolve_substrate)

# party count each substrate runs the toy collective at (sharded is bound
# by the host's device count: 1 on the CPU test rig)
PARTY_COUNTS = {"simulated": 3, "sharded": 1, "distributed": 2}


@pytest.fixture(scope="module")
def pool():
    subs = {
        "simulated": resolve_substrate("simulated"),
        "sharded": resolve_substrate(
            "sharded", Mesh(np.array(jax.devices()[:1]), ("parties",))),
        "distributed": resolve_substrate(
            "distributed", parties=PARTY_COUNTS["distributed"]),
    }
    missing = set(SUBSTRATES) - set(subs)
    assert not missing, (
        f"substrates {sorted(missing)} are registered but the conformance "
        f"fixture does not build them — add them to this suite")
    yield subs
    subs["distributed"].shutdown()


def _toy(sub, m: int) -> np.ndarray:
    x = np.arange(m * 4, dtype=np.int32).reshape(m, 4)
    prog = sub.program(distributed.toy_affine_fn, 1, 1,
                       distributed=distributed.toy_affine_spec())
    with sub.context():
        out = sub.compile(prog)(x, np.int32(3))
    return np.asarray(out)


def test_registry_is_fully_covered():
    assert set(PARTY_COUNTS) == set(SUBSTRATES)


@pytest.mark.parametrize("name", sorted(PARTY_COUNTS))
def test_toy_collective_bit_identity(pool, name):
    """Both collectives + the party index, bit-identical to the simulation
    at the same party count, on every registered substrate."""
    m = PARTY_COUNTS[name]
    got = _toy(pool[name], m)
    want = _toy(SimulatedSubstrate(), m)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("name", sorted(PARTY_COUNTS))
def test_jit_matches_compile(pool, name):
    """``jit`` (program + compile in one step) agrees with the two-step
    path — on the distributed substrate both are the protocol itself."""
    sub, m = pool[name], PARTY_COUNTS[name]
    x = np.arange(m * 4, dtype=np.int32).reshape(m, 4)
    run = sub.jit(distributed.toy_affine_fn, 1, 1,
                  distributed=distributed.toy_affine_spec())
    with sub.context():
        np.testing.assert_array_equal(np.asarray(run(x, np.int32(3))),
                                      _toy(sub, m))


@pytest.mark.parametrize("name", sorted(PARTY_COUNTS))
def test_context_is_reenterable(pool, name):
    for _ in range(2):
        with pool[name].context():
            pass


@pytest.mark.parametrize("name", sorted(PARTY_COUNTS))
def test_exchange_seam(pool, name):
    """In-process substrates have no transport: exchange is None.  The
    distributed substrate answers a real ping round trip."""
    r = pool[name].exchange("ping", party=0)
    if name == "distributed":
        assert r["op"] == "pong" and r["party"] == 0
    else:
        assert r is None


def test_shutdown_idempotent(pool):
    for name in ("simulated", "sharded"):
        pool[name].shutdown()
        pool[name].shutdown()        # in-process: nothing to tear down, twice
    from repro.federation.distributed import DistributedSubstrate
    cold = DistributedSubstrate(2)   # never started: no workers to reap
    cold.shutdown()
    cold.shutdown()


def test_resolve_validates_party_count(pool):
    with pytest.raises(ValueError, match="executes"):
        resolve_substrate(pool["sharded"], parties=3)
    with pytest.raises(ValueError, match="executes"):
        resolve_substrate(pool["distributed"], parties=5)
    # the simulation runs any party count: no n_parties to contradict
    assert resolve_substrate(pool["simulated"], parties=7) is pool["simulated"]


def test_resolve_unknown_name_lists_registry():
    with pytest.raises(ValueError, match="registered"):
        resolve_substrate("carrier-pigeon")
    with pytest.raises(ValueError, match="registered"):
        resolve_substrate(42)


def test_register_substrate_roundtrip():
    """A decorated factory resolves by name and receives the factory
    options; unregistering restores the registry."""
    calls = {}

    @register_substrate("test-echo")
    def _make(mesh=None, parties=None, **opts):
        calls.update(opts, parties=parties)
        return SimulatedSubstrate()

    try:
        sub = resolve_substrate("test-echo", parties=4, flavor="x")
        assert isinstance(sub, SimulatedSubstrate)
        assert calls == {"parties": 4, "flavor": "x"}
    finally:
        del SUBSTRATES["test-echo"]
    with pytest.raises(ValueError, match="registered"):
        resolve_substrate("test-echo")
