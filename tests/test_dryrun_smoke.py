"""Dry-run smoke: the case builder lowers+compiles on a small in-process
mesh (subprocess so the forced host-device count never leaks into other
tests)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch import cases

out = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch, shape in [("internlm2-1.8b", "decode_32k"),
                    ("xlstm-350m", "long_500k"),
                    ("qwen2-vl-2b", "prefill_32k")]:
    case = cases.input_specs(arch, shape, mesh)
    compiled = case.lower(mesh).compile()
    ma = compiled.memory_analysis()
    out[f"{arch}:{shape}"] = int(ma.temp_size_in_bytes)

# federated forest protocol on a (trees, parties) mesh
fmesh = jax.make_mesh((2, 4), ("trees", "parties"))
fn, args, _ = cases.forest_case("ff_train", fmesh)
c = jax.jit(fn).lower(*args).compile()
out["ff_train"] = int(c.memory_analysis().temp_size_in_bytes)
print(json.dumps(out))
"""


@pytest.mark.slow
def test_cases_lower_on_small_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(out) == 4
    for k, v in out.items():
        assert v > 0, (k, v)


def test_skip_table_is_principled():
    from repro.launch import cases
    assert ("whisper-large-v3", "long_500k") in cases.SKIPS
    with pytest.raises(cases.Skip):
        cases.arch_for_shape("whisper-large-v3", cases.SHAPES["long_500k"])


def test_swa_variant_applied_for_long_context():
    from repro.launch import cases
    cfg = cases.arch_for_shape("qwen3-32b", cases.SHAPES["long_500k"])
    assert cfg.sliding_window == cases.SWA_WINDOW
    cfg = cases.arch_for_shape("xlstm-350m", cases.SHAPES["long_500k"])
    assert cfg.sliding_window is None  # natively sub-quadratic
    cfg = cases.arch_for_shape("qwen3-32b", cases.SHAPES["decode_32k"])
    assert cfg.sliding_window is None  # full attention below 500k
