"""Party-first data plane: PartyBlock ingestion, M-party hashed-ID
alignment, party-local binning, and party-block serving.

The load-bearing claims:
  * M-party ``crypto.align_ids`` puts every party on one canonical common
    ordering — invariant to per-party row shuffles and to party order —
    and fails loudly on duplicate IDs / empty intersections;
  * ingesting shuffled, partially-overlapping PartyBlocks (superset rows
    per party) yields a partition — and a fitted forest, and served
    outputs — BIT-IDENTICAL to the centrally pre-aligned build, on both
    tasks and both substrates (party-local binning is per-feature, hence
    lossless by construction);
  * the raw-matrix compat path is a thin adapter over PartyBlocks and
    preserves its pre-aligned row order exactly;
  * serving re-aligns out-of-order / superset per-party request blocks
    before dispatch (ForestServer.serve_parties, RequestQueue.submit_parties).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import (FederatedForest, ForestParams, PartyBlock, crypto,
                        partition_from_blocks)
from repro.core.partyblock import CSVSource, align_party_blocks
from repro.data import make_classification, make_party_views, make_regression
from repro.federation import Federation


def _trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _parts_equal(a, b):
    np.testing.assert_array_equal(a.xb, b.xb)
    np.testing.assert_array_equal(a.feat_gid, b.feat_gid)
    np.testing.assert_array_equal(a.boundaries, b.boundaries)
    assert a.n_features == b.n_features
    for ra, rb in zip(a.raw_parts, b.raw_parts):
        np.testing.assert_array_equal(ra, rb)


# --------------------------------------------------- M-party alignment core
def test_align_ids_multiparty_canonical_order():
    """Positions index one shared ordering (sorted common hashed IDs),
    whatever each party's row order or the party order is."""
    rng = np.random.default_rng(0)
    ids = np.array([f"u{i}" for i in range(40)])
    views = [rng.permutation(ids) for _ in range(3)]
    hashed = [crypto.hash_ids(v) for v in views]
    pos = crypto.align_ids(*hashed)
    assert len(pos) == 3
    ref = views[0][pos[0]]
    for v, p in zip(views, pos):
        np.testing.assert_array_equal(v[p], ref)
    # canonical: sorted by hashed value
    np.testing.assert_array_equal(crypto.hash_ids(ref),
                                  np.sort(crypto.hash_ids(ids)))
    # party order permutation -> same canonical ordering
    pos_rev = crypto.align_ids(*hashed[::-1])
    np.testing.assert_array_equal(views[2][pos_rev[0]], ref)


def test_align_ids_two_party_compat():
    """The historical 2-party unpack still works (quickstart.py shape)."""
    a = crypto.hash_ids(np.arange(10))
    b = crypto.hash_ids(np.arange(5, 15))
    ia, ib = crypto.align_ids(a, b)
    np.testing.assert_array_equal(a[ia], b[ib])
    assert len(ia) == 5


def test_align_ids_errors():
    a = crypto.hash_ids(["x", "y", "z"])
    with pytest.raises(ValueError, match="duplicate"):
        crypto.align_ids(np.concatenate([a, a[:1]]), a)
    with pytest.raises(ValueError, match="intersection"):
        crypto.align_ids(a, crypto.hash_ids(["p", "q"]))
    with pytest.raises(ValueError, match="at least one"):
        crypto.align_ids()


def test_ingest_errors_are_loud():
    """Satellite: empty intersection / in-party duplicates surface as clear
    ValueErrors from Federation.ingest, not shape errors deep in the stack."""
    fed = Federation(parties=2)
    a = PartyBlock("a", np.zeros((3, 2)), ids=["1", "2", "3"], y=[0, 1, 0])
    with pytest.raises(ValueError, match="intersection"):
        fed.ingest([a, PartyBlock("b", np.zeros((2, 2)), ids=["8", "9"])])
    with pytest.raises(ValueError, match="duplicate"):
        fed.ingest([a, PartyBlock("b", np.zeros((3, 2)),
                                  ids=["1", "1", "3"])])
    with pytest.raises(ValueError, match="labels ride"):
        fed.ingest([a, PartyBlock("b", np.zeros((3, 2)),
                                  ids=["1", "2", "3"])], y=np.zeros(3))
    with pytest.raises(ValueError, match="declares 2"):
        fed.ingest([a])
    with pytest.raises(ValueError, match="more than one party"):
        fed.ingest([a, PartyBlock("b", np.zeros((3, 2)), ids=["1", "2", "3"],
                                  y=[1, 0, 1])])
    with pytest.raises(ValueError, match="unique"):
        fed.ingest([a, PartyBlock("a", np.zeros((3, 2)),
                                  ids=["1", "2", "3"])])
    # raw-matrix-only knobs must not be silently dropped on the block path
    ok = PartyBlock("b", np.zeros((3, 2)), ids=["1", "2", "3"])
    with pytest.raises(ValueError, match="raw-matrix"):
        fed.ingest([a, ok], contiguous=False)
    with pytest.raises(ValueError, match="raw-matrix"):
        fed.ingest([a, ok], seed=7)


def test_block_validation():
    with pytest.raises(ValueError, match="sample IDs for"):
        PartyBlock("p", np.zeros((3, 2)), ids=["1", "2"])
    with pytest.raises(ValueError, match="labels for"):
        PartyBlock("p", np.zeros((3, 2)), ids=["1", "2", "3"], y=[1])
    with pytest.raises(ValueError, match="feature_ids must be set"):
        partition_from_blocks(
            [PartyBlock("a", np.zeros((2, 1)), ids=["1", "2"],
                        feature_ids=[0]),
             PartyBlock("b", np.zeros((2, 1)), ids=["1", "2"])], 4)
    with pytest.raises(ValueError, match="partition 0..F-1"):
        partition_from_blocks(
            [PartyBlock("a", np.zeros((2, 1)), ids=["1", "2"],
                        feature_ids=[0]),
             PartyBlock("b", np.zeros((2, 1)), ids=["1", "2"],
                        feature_ids=[2])], 4)


# ------------------------------------------- losslessness under real ingest
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("contiguous", [True, False])
def test_partition_from_blocks_bit_identical_to_dense(seed, contiguous):
    """Property-style: shuffled rows, permuted party order, disjoint extra
    samples per party — the aligned partition equals the dense pre-aligned
    build bit for bit (party-local binning included, validate=True)."""
    x, y = make_classification(260, 11, 2, seed=seed)
    blocks, xa, ya = make_party_views(x, y, 3, overlap=0.7,
                                      contiguous=contiguous, seed=seed)
    order = np.random.default_rng(seed).permutation(3)
    part, yb, ids = partition_from_blocks([blocks[i] for i in order], 8,
                                          validate=True)
    dense = Federation(parties=3, n_bins=8, seed=seed).ingest(
        xa, ya, contiguous=contiguous)
    _parts_equal(part, dense)
    np.testing.assert_array_equal(yb, ya)
    assert len(ids) == len(xa)
    np.testing.assert_array_equal(part.dense_raw(), xa)


@pytest.mark.parametrize("task", ["classification", "regression"])
def test_party_first_fit_and_serve_bit_identical(task):
    """Acceptance: fit from realistic PartyBlocks == fit from the central
    pre-aligned matrix — bit-identical forest, identical predictions and
    served outputs — for both tasks (simulated substrate; the sharded
    substrate is covered subprocess-side below)."""
    if task == "classification":
        x, y = make_classification(300, 10, 3, seed=4)
        p = ForestParams(task=task, n_classes=3, n_estimators=4, max_depth=5,
                         n_bins=16, seed=11)
    else:
        x, y = make_regression(300, 10, seed=4)
        p = ForestParams(task=task, n_estimators=4, max_depth=5, n_bins=16,
                         seed=11)
    blocks, xa, ya = make_party_views(x, y, 3, overlap=0.75, seed=4)

    fed = Federation(parties=3, n_bins=16)
    part = fed.ingest(blocks, validate=True)
    assert part.n_samples == len(xa)
    np.testing.assert_array_equal(fed.labels_, ya)
    model = fed.fit(p)

    fed_c = Federation(parties=3, n_bins=16)
    fed_c.ingest(xa, ya)
    central = fed_c.fit(p)

    _trees_equal(model.trees_, central.trees_)
    xt = xa[:64]
    np.testing.assert_array_equal(fed.predict(model, xt),
                                  fed_c.predict(central, xt))
    # serving: identical outputs through the bucketed engine
    server = fed.serve(model, buckets=(32,))
    np.testing.assert_array_equal(server.serve(xt), central.predict(xt))


def test_ingest_invariant_to_party_order_and_shuffle():
    """Permuting the block list and re-shuffling each party's rows cannot
    change the session's partition, labels, or fitted forest."""
    x, y = make_classification(240, 9, 2, seed=6)
    blocks, _, _ = make_party_views(x, y, 3, overlap=0.8, seed=6)
    rng = np.random.default_rng(0)
    reshuffled = []
    for b in blocks[::-1]:
        perm = rng.permutation(b.n_samples)
        reshuffled.append(PartyBlock(
            name=b.name, x=b.x[perm], ids=b.ids[perm],
            y=None if b.y is None else b.y[perm],
            feature_ids=b.feature_ids))
    p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, seed=3)
    fed1, fed2 = (Federation(parties=3, n_bins=8) for _ in range(2))
    part1, part2 = fed1.ingest(blocks), fed2.ingest(reshuffled)
    _parts_equal(part1, part2)
    np.testing.assert_array_equal(fed1.labels_, fed2.labels_)
    np.testing.assert_array_equal(fed1.aligned_ids_, fed2.aligned_ids_)
    _trees_equal(fed1.fit(p).trees_, fed2.fit(p).trees_)


# -------------------------------------------------------- DataSource / CSV
def test_csv_roundtrip_and_source(tmp_path):
    x, y = make_classification(60, 6, 2, seed=8)
    blocks, xa, ya = make_party_views(x, y, 2, overlap=0.9, seed=8)
    sources = []
    for b in blocks:
        sources.append(CSVSource(b.to_csv(str(tmp_path / f"{b.name}.csv")),
                                 name=b.name))
    loaded = sources[0].load()
    assert loaded.name == blocks[0].name
    np.testing.assert_array_equal(loaded.ids, blocks[0].ids)
    np.testing.assert_array_equal(loaded.x, blocks[0].x)
    np.testing.assert_array_equal(loaded.y, blocks[0].y)
    assert loaded.y.dtype == np.int64          # integral labels -> int
    # global feature ids survive the round trip (gf<N> headers)
    np.testing.assert_array_equal(loaded.feature_ids, blocks[0].feature_ids)

    # full ingest through the DataSource hook == the dense build
    fed = Federation(parties=2, n_bins=8)
    part = fed.ingest(sources, validate=True)
    dense = Federation(parties=2, n_bins=8).ingest(xa, ya)
    np.testing.assert_array_equal(part.xb, dense.xb)
    np.testing.assert_array_equal(fed.labels_, ya)


def test_csv_roundtrip_preserves_encoding_under_name_reorder(tmp_path):
    """Party names whose sorted order differs from the original party order
    must not scramble the global column encoding through a CSV round trip —
    feature_ids ride along in the headers."""
    x, y = make_classification(80, 6, 2, seed=21)
    blocks, xa, ya = make_party_views(x, y, 2, overlap=0.9, seed=21)
    renamed = [PartyBlock(name=n, x=b.x, ids=b.ids, y=b.y,
                          feature_ids=b.feature_ids)
               for n, b in zip(("zulu", "alpha"), blocks)]
    sources = [CSVSource(b.to_csv(str(tmp_path / f"{b.name}.csv")),
                         name=b.name) for b in renamed]
    fed_direct = Federation(parties=2, n_bins=8)
    direct = fed_direct.ingest(renamed)
    fed_csv = Federation(parties=2, n_bins=8)
    via_csv = fed_csv.ingest(sources, validate=True)
    _parts_equal(direct, via_csv)            # the round trip is the identity
    assert via_csv.party_names == ("alpha", "zulu")   # canonical name sort
    # and the model is still the dense pre-aligned one: the party AXIS order
    # differs (sorted by the new names) but the global column encoding — and
    # hence every split and prediction — is preserved bit-for-bit
    p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, seed=2)
    fed_dense = Federation(parties=2, n_bins=8)
    fed_dense.ingest(xa, ya)
    np.testing.assert_array_equal(
        fed_csv.predict(fed_csv.fit(p), xa),
        fed_dense.predict(fed_dense.fit(p), xa))


def test_ingest_empty_blocks_raise_loudly():
    """Zero-row blocks must hit the empty-intersection error, not an
    IndexError deep in binning (the identity fast path included)."""
    empty = [PartyBlock("a", np.empty((0, 2)), ids=np.empty(0, dtype="<U4")),
             PartyBlock("b", np.empty((0, 3)), ids=np.empty(0, dtype="<U4"))]
    with pytest.raises(ValueError, match="intersection"):
        Federation(parties=2).ingest(empty)


def test_csv_regression_labels_keep_float_dtype(tmp_path):
    """Whole-number regression targets round trip as float64: only
    lexically-integer label columns ("3", not "3.0") become class ids."""
    b = PartyBlock("reg", np.arange(8.0).reshape(4, 2),
                   ids=["a", "b", "c", "d"], y=[10.0, 20.0, 30.0, 40.0])
    loaded = PartyBlock.from_csv(b.to_csv(str(tmp_path / "reg.csv")))
    assert loaded.y.dtype == np.float64
    np.testing.assert_array_equal(loaded.y, b.y)


def test_parse_party_csv_specs():
    from repro.launch.train import parse_party_csvs
    s = parse_party_csvs(["bank=/data/run=3/bank.csv", "/tmp/bare.csv",
                          "/data/run=3/ecom.csv"], "id", "label")
    assert (s[0].name, s[0].path) == ("bank", "/data/run=3/bank.csv")
    assert (s[1].name, s[1].path) == (None, "/tmp/bare.csv")
    assert (s[2].name, s[2].path) == (None, "/data/run=3/ecom.csv")


def test_csv_missing_id_column(tmp_path):
    f = tmp_path / "bad.csv"
    f.write_text("a,b\n1.0,2.0\n")
    with pytest.raises(ValueError, match="no 'id' column"):
        PartyBlock.from_csv(str(f))


def test_csv_nan_and_missing_values_raise_loudly(tmp_path):
    """Satellite: a NaN or empty feature cell fails the parse naming the
    column and the data row — binning would otherwise silently bucket NaNs
    and corrupt every split on that feature."""
    f = tmp_path / "nan.csv"
    f.write_text("id,age,income\nu1,33,50000\nu2,41,NaN\nu3,29,61000\n")
    with pytest.raises(ValueError, match=r"'income'.*data row 1"):
        PartyBlock.from_csv(str(f))
    f.write_text("id,age,income\nu1,33,50000\nu2,41,1.0\nu3,,61000\n")
    with pytest.raises(ValueError, match=r"'age'.*data row 2"):
        PartyBlock.from_csv(str(f))
    # the chunked reader shares the parse helpers: same contract, and the
    # row index stays global even when the bad row is deep in a later chunk
    from repro.streaming import ChunkedCSVSource
    f.write_text("id,a\n" + "".join(f"u{i},{i}.5\n" for i in range(7))
                 + "u7,nan\n")
    with pytest.raises(ValueError, match=r"'a'.*data row 7"):
        for _ in ChunkedCSVSource(str(f)).iter_chunks(3):
            pass


# ------------------------------------------------------ party-block serving
def test_serve_parties_realigns_out_of_order_and_superset():
    """ForestServer.serve_parties: request blocks keyed by hashed IDs with
    shuffled rows and party-local extras serve exactly the model's
    predictions on the aligned common rows."""
    x, y = make_classification(260, 9, 2, seed=10)
    blocks, xa, ya = make_party_views(x, y, 3, overlap=0.85, seed=10)
    fed = Federation(parties=3, n_bins=16)
    part = fed.ingest(blocks)
    model = fed.fit(ForestParams(n_estimators=3, max_depth=4, n_bins=16,
                                 seed=1))
    server = fed.serve(model, buckets=(64,))

    xt, _ = make_classification(40, 9, 2, seed=77)
    qids = np.array([f"q{i}" for i in range(len(xt))])
    rng = np.random.default_rng(3)
    req = []
    for i, name in enumerate(part.party_names):
        gid = part.feat_gid[i][part.feat_gid[i] >= 0]
        rows = rng.permutation(len(xt))
        extra = rng.normal(size=(4, len(gid)))
        req.append(PartyBlock(
            name=name, x=np.concatenate([xt[rows][:, gid], extra]),
            ids=np.concatenate([qids[rows],
                                [f"{name}-only{j}" for j in range(4)]])))
    ids, preds = server.serve_parties(req[::-1])    # any party order
    order = np.argsort(crypto.hash_ids(qids))
    np.testing.assert_array_equal(ids, qids[order])
    np.testing.assert_array_equal(preds, model.predict(xt[order]))

    # queue path: same alignment, results keyed by request id
    from repro.serving import RequestQueue
    q = RequestQueue(server)
    rid, q_ids = q.submit_parties(req)
    np.testing.assert_array_equal(q_ids, ids)
    np.testing.assert_array_equal(q.drain()[rid], preds)


def test_linear_serve_parties_roundtrip():
    """LinearServer.serve_parties: the F-LR engine accepts raw per-party
    request blocks through the same re-alignment path as the tree engines —
    aligned rows stay raw and are standardized with the fit-time moments."""
    from repro.core import LinearParams
    from repro.serving import LinearServer, ServeConfig
    x, y = make_classification(240, 8, 2, seed=11)
    blocks, xa, ya = make_party_views(x, y, 3, overlap=0.85, seed=11)
    fed = Federation(parties=3, n_bins=8)
    part = fed.ingest(blocks)
    model = fed.fit(LinearParams(steps=150))
    server = fed.serve(model, ServeConfig(buckets=(64,)))
    assert isinstance(server, LinearServer)

    xt, _ = make_classification(30, 8, 2, seed=78)
    qids = np.array([f"q{i}" for i in range(len(xt))])
    rng = np.random.default_rng(4)
    req = []
    for i, name in enumerate(part.party_names):
        gid = part.feat_gid[i][part.feat_gid[i] >= 0]
        rows = rng.permutation(len(xt))
        extra = rng.normal(size=(3, len(gid)))
        req.append(PartyBlock(
            name=name, x=np.concatenate([xt[rows][:, gid], extra]),
            ids=np.concatenate([qids[rows],
                                [f"{name}-only{j}" for j in range(3)]])))
    ids, preds = server.serve_parties(req[::-1])    # any party order
    order = np.argsort(crypto.hash_ids(qids))
    np.testing.assert_array_equal(ids, qids[order])
    np.testing.assert_array_equal(preds, model.predict(xt[order]))


def test_hash_ids_cache_bit_identity():
    """The serving-path hash cache is invisible: cold and warm lookups
    produce identical digests, and repeated IDs hit the cache."""
    crypto._HASH_CACHE.clear()
    ids = np.array([f"u{i}" for i in range(50)])
    cold = crypto.hash_ids(ids)
    assert len(crypto._HASH_CACHE) >= 50
    warm = crypto.hash_ids(np.concatenate([ids, ids]))
    np.testing.assert_array_equal(warm[:50], cold)
    np.testing.assert_array_equal(warm[50:], cold)
    # a different salt is a different preimage, never a stale cache hit
    assert not np.array_equal(crypto.hash_ids(ids, salt="other"), cold)


def test_serve_parties_validates_block_names():
    x, y = make_classification(200, 8, 2, seed=12)
    blocks, _, _ = make_party_views(x, y, 2, overlap=0.9, seed=12)
    fed = Federation(parties=2, n_bins=8)
    part = fed.ingest(blocks)
    model = fed.fit(ForestParams(n_estimators=2, max_depth=3, n_bins=8))
    server = fed.serve(model, buckets=(32,))
    bad = PartyBlock("nobody", np.zeros((2, 4)), ids=["1", "2"])
    with pytest.raises(ValueError, match="cover exactly"):
        server.serve_parties([blocks[0], bad])
    with pytest.raises(ValueError, match="features"):
        server.serve_parties([
            PartyBlock(b.name, np.zeros((2, b.n_features + 1)),
                       ids=["1", "2"]) for b in blocks])


# ------------------------------------------------- raw-matrix compat adapter
def test_raw_matrix_adapter_preserves_row_order():
    """The compat path is PartyBlocks underneath, but pre-aligned implicit
    IDs take the identity alignment: rows stay exactly as given."""
    x, y = make_classification(150, 7, 2, seed=14)
    fed = Federation(parties=2, n_bins=8)
    part = fed.ingest(x, y)
    np.testing.assert_array_equal(fed.aligned_ids_, np.arange(len(x)))
    np.testing.assert_array_equal(fed.labels_, y)
    np.testing.assert_array_equal(part.dense_raw(), x)
    assert part.party_names == ("party000", "party001")


# ------------------------------------------------------- sharded substrate
_SHARDED_BLOCKS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import numpy as np
import jax
from repro.core import ForestParams, PartyBlock, crypto
from repro.data import make_classification, make_party_views
from repro.federation import Federation

x, y = make_classification(240, 9, 2, seed=5)
blocks, xa, ya = make_party_views(x, y, 3, overlap=0.8, seed=5)
p = ForestParams(n_estimators=2, max_depth=4, n_bins=8, seed=3)

mesh = jax.make_mesh((2, 3), ("trees", "parties"))
fed = Federation(parties=3, substrate="sharded", mesh=mesh, n_bins=8,
                 hist_impl="scatter")
part = fed.ingest(blocks, validate=True)
model = fed.fit(p)

fed_c = Federation(parties=3, substrate="sharded", mesh=mesh, n_bins=8,
                   hist_impl="scatter")
fed_c.ingest(xa, ya)
central = fed_c.fit(p)

for la, lb in zip(jax.tree_util.tree_leaves(model.trees_),
                  jax.tree_util.tree_leaves(central.trees_)):
    assert np.array_equal(np.asarray(la), np.asarray(lb)), "trees diverge"

xt = xa[:32]
assert np.array_equal(fed.predict(model, xt), fed_c.predict(central, xt))

# party-block serving on the sharded substrate, out-of-order + superset
server = fed.serve(model, buckets=(32,))
qids = np.array([f"q{i}" for i in range(len(xt))])
rng = np.random.default_rng(0)
req = []
for i, name in enumerate(part.party_names):
    gid = part.feat_gid[i][part.feat_gid[i] >= 0]
    rows = rng.permutation(len(xt))
    extra = rng.normal(size=(3, len(gid)))
    req.append(PartyBlock(
        name=name, x=np.concatenate([xt[rows][:, gid], extra]),
        ids=np.concatenate([qids[rows], [f"{name}-{j}" for j in range(3)]])))
ids, preds = server.serve_parties(req)
order = np.argsort(crypto.hash_ids(qids))
assert np.array_equal(ids, qids[order])
assert np.array_equal(preds, central.predict(xt[order]))
print("PARTY_SHARDED_OK")
"""


def test_party_ingest_sharded_substrate_bit_identical():
    """Acceptance, sharded half: the same PartyBlock ingest feeds the
    shard_map substrate and stays bit-identical to the dense pre-aligned
    build — fit, predict, and party-block serving (subprocess so the forced
    device count never leaks into other tests)."""
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _SHARDED_BLOCKS_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1500,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-3000:]
    assert "PARTY_SHARDED_OK" in res.stdout
