"""Tests for the privacy-egress analyzer (static pass + rule passes +
runtime taint registry).  Wire-level guard behavior (Channel.send raising
through real worker processes) lives in test_distributed.py."""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import run_analysis
from repro.analysis import runtime as rt
from repro.analysis.__main__ import main as cli_main
from repro.analysis.policy import DEFAULT_POLICY

FIXTURES = Path(__file__).parent / "analysis_fixtures"
SRC_REPRO = Path(__file__).parents[1] / "src" / "repro"


def _egress_on(path, *more):
    return [f for f in run_analysis([path, *more], rules=("egress",))
            if f.rule == "egress"]


# --------------------------------------------------------------- static pass
class TestEgressFixtures:
    def test_direct_send_flagged(self):
        findings = _egress_on(FIXTURES / "leak_direct.py")
        assert len(findings) == 1
        assert "raw feature matrix" in findings[0].message
        assert "`send`" in findings[0].message
        assert findings[0].symbol == "leak"

    def test_send_via_helper_flagged_at_call_site(self):
        findings = _egress_on(FIXTURES / "leak_helper.py")
        assert len(findings) == 1
        f = findings[0]
        assert f.symbol == "leak"           # the call site, not the helper
        assert "via `_hop`" in f.message
        assert "raw sample IDs" in f.message

    def test_partial_sanitize_still_flagged(self):
        findings = _egress_on(FIXTURES / "leak_partial.py")
        assert len(findings) == 1
        # binned features are clean; the raw ids beside them are not
        assert "raw sample IDs" in findings[0].message
        assert "raw feature matrix" not in findings[0].message

    def test_container_and_namedtuple_smuggling_flagged(self):
        findings = _egress_on(FIXTURES / "leak_smuggle.py")
        assert {f.symbol for f in findings} == {"leak_dict",
                                                "leak_namedtuple"}
        by_sym = {f.symbol: f.message for f in findings}
        assert "raw labels" in by_sym["leak_dict"]
        assert "raw feature matrix" in by_sym["leak_namedtuple"]

    def test_clean_fixture_has_no_findings(self):
        assert _egress_on(FIXTURES / "clean.py") == []

    def test_suppression_with_reason_silences(self):
        findings = run_analysis([FIXTURES / "suppressed.py"],
                                rules=("egress",))
        # `provision` is suppressed; `bad_suppression` keeps its egress
        # finding AND the empty-reason comment is reported
        assert {f.symbol for f in findings if f.rule == "egress"} \
            == {"bad_suppression"}
        assert any(f.rule == "suppression" for f in findings)


class TestCompanionRules:
    def test_asserts_rule(self):
        findings = run_analysis([FIXTURES / "fix_rules.py"],
                                rules=("asserts",))
        assert [f.symbol for f in findings] == ["shape_check"]
        assert "python -O" in findings[0].message

    def test_asserts_rule_exempts_launch_demos(self):
        launch = SRC_REPRO / "launch"
        # demo asserts ARE the CI gate; the policy must keep exempting them
        assert run_analysis([launch], rules=("asserts",)) == []

    def test_determinism_rule(self):
        findings = run_analysis([FIXTURES / "fix_rules.py"],
                                rules=("determinism",))
        msgs = " | ".join(f.message for f in findings)
        assert len(findings) == 3
        assert "legacy global-state RNG" in msgs
        assert "unseeded np.random.default_rng()" in msgs
        assert "time-dependent call" in msgs          # @register_program zone

    def test_locks_rule(self):
        policy = dataclasses.replace(DEFAULT_POLICY,
                                     lock_modules=("fix_rules.py",))
        findings = run_analysis([FIXTURES / "fix_rules.py"],
                                rules=("locks",), policy=policy)
        assert len(findings) == 4
        bad = [f for f in findings if f.symbol == "SharedCounter.bad"]
        assert len(bad) == 3
        assert any("outside `with self._lock:`" in f.message for f in bad)
        assert any("not covered" in f.message for f in bad)
        undoc = [f for f in findings if f.symbol == "UndocumentedLocker"]
        assert len(undoc) == 1 and "no 'Lock discipline'" in undoc[0].message


def test_real_tree_is_finding_free():
    """The acceptance gate: src/repro passes every rule with no findings."""
    assert run_analysis([SRC_REPRO]) == []


def test_cli_json_and_exit_codes(tmp_path, capsys):
    # leak fixture: findings -> exit 1 under --fail-on-findings
    rc = cli_main([str(FIXTURES / "leak_direct.py"), "--json",
                   "--fail-on-findings", "--no-baseline"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert report["findings"] and report["findings"][0]["rule"] == "egress"

    # baseline the findings, then the same run passes
    baseline = tmp_path / "baseline.json"
    assert cli_main([str(FIXTURES / "leak_direct.py"),
                     "--write-baseline", str(baseline)]) == 0
    capsys.readouterr()
    rc = cli_main([str(FIXTURES / "leak_direct.py"), "--fail-on-findings",
                   "--baseline", str(baseline)])
    out = capsys.readouterr().out
    assert rc == 0 and "1 baselined" in out

    # the real tree passes clean with the checked-in (empty) baseline
    assert cli_main([str(SRC_REPRO), "--fail-on-findings"]) == 0
    capsys.readouterr()


# ------------------------------------------------------------- runtime twin
class TestRuntimeRegistry:
    def test_taint_and_lookup_views_and_copies(self):
        assert rt.enabled(), "conftest must set REPRO_EGRESS_GUARD=1"
        arr = np.arange(12.0).reshape(3, 4)
        rt.taint(arr, "unit-test raw block")
        assert rt.lookup(arr) == "unit-test raw block"
        # views share the buffer -> still tainted
        assert rt.lookup(arr[1:, :2]) == "unit-test raw block"
        assert rt.lookup(arr.reshape(-1)) == "unit-test raw block"
        # fancy-index / arithmetic copies are new buffers -> clean
        assert rt.lookup(arr[np.array([0, 2])]) is None
        assert rt.lookup(arr + 0) is None

    def test_check_egress_names_the_key_path(self):
        arr = rt.taint(np.ones(4), "raw ids for path test")
        with pytest.raises(rt.PrivacyViolationError) as ei:
            rt.check_egress({"op": "x", "payload": {"ids": arr}},
                            context="unit")
        assert ei.value.path == "msg['payload']['ids']"
        assert "raw ids for path test" in str(ei.value)
        # NamedTuple fields are named, not numbered
        from collections import namedtuple
        Wrapped = namedtuple("Wrapped", "meta blob")
        with pytest.raises(rt.PrivacyViolationError) as ei:
            rt.check_egress({"w": Wrapped(meta=1, blob=arr)})
        assert ei.value.path == "msg['w'].blob"

    def test_allow_egress_scopes_the_allowance(self):
        arr = rt.taint(np.ones(3), "raw for allowance test")
        with rt.allow_egress("unit test provisioning"):
            rt.check_egress({"x": arr})       # allowed, no raise
        with pytest.raises(rt.PrivacyViolationError):
            rt.check_egress({"x": arr})       # allowance ended with scope
        with pytest.raises(ValueError):
            rt.allow_egress("")               # reasons are mandatory

    def test_partyblock_construction_tags_raw_fields(self):
        from repro.core.partyblock import PartyBlock
        b = PartyBlock(name="acme", x=np.ones((4, 2)),
                       ids=np.arange(4), y=np.zeros(4, np.int64))
        assert "raw features" in (rt.lookup(b.x) or "")
        assert "raw sample IDs" in (rt.lookup(b.ids) or "")
        assert "raw labels" in (rt.lookup(b.y) or "")
        # hashed ids are a fresh sanitized array -> clean
        assert rt.lookup(b.hashed_ids("salt")) is None

    def test_registry_prunes_dead_entries(self):
        before = rt.registry_size()
        for _ in range(64):
            rt.taint(np.zeros(8), "ephemeral")
        assert rt.registry_size() <= before + 64   # dead refs don't pile up
