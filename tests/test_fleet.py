"""Serving-fleet tests: routing, admission, fault tolerance, observability.

The load-bearing acceptance claims:

  * **Bit-identity oracle** — whatever the consistent-hash routing decides,
    every request served through the fleet is bit-identical to a single
    ModelServer serving the same rows (cells are replicas of one compiled
    engine; routing must be invisible in the outputs).
  * **Zero lost accepted requests** — killing 1 of 4 cells with traffic
    pending re-routes its keyspace to the survivors; every accepted request
    resolves or dead-letters, never drops silently.

Plus the satellites riding along: RequestQueue multi-producer thread
safety, per-cell bucket autotune without recompiling surviving buckets,
"auto" build-knob resolution bit-identity, and the well-formed zero stats
record for just-spawned cells.
"""
import threading

import numpy as np
import pytest

from repro.core import ForestParams, crypto, fit_federated_forest
from repro.data import make_classification, make_party_views
from repro.federation import Federation
from repro.serving import (AlertThresholds, FleetOverloadError, ForestServer,
                           PoisonedWaveError, RequestQueue, ServeConfig,
                           ServingFleet, alerts)
from repro.serving.fleet import HashRing, TokenBucket
from repro.serving.metrics import busy_seconds


@pytest.fixture(scope="module")
def fleet_env():
    """One fitted forest + a 4-cell fleet + the single-server oracle."""
    x, y = make_classification(600, 18, 3, seed=0)
    fed = Federation(parties=3, n_bins=16)
    fed.ingest(x[:450], y[:450])
    model = fed.fit(ForestParams(n_classes=3, n_estimators=4, max_depth=6,
                                 n_bins=16, seed=1))
    cfg = ServeConfig(buckets=(32, 128))
    fleet = fed.serve_fleet(model, cfg, n_cells=4).warmup()
    single = fed.serve(model, cfg)
    return fed, model, cfg, fleet, single, x[450:]


# ----------------------------------------------------------- hash ring
def test_hash_ring_stability_under_remove():
    """Consistent hashing's defining property: removing a cell re-routes
    ONLY the keys that routed to it — everyone else's routing is stable."""
    ring = HashRing(vnodes=64)
    for n in ("a", "b", "c", "d"):
        ring.add(n)
    keys = [f"k{i}" for i in range(3000)]
    before = {k: ring.route(k) for k in keys}
    ring.remove("c")
    moved = [k for k in keys if ring.route(k) != before[k]]
    assert moved and all(before[k] == "c" for k in moved)
    # and the displaced share is roughly 1/4, not the whole keyspace
    assert 0.10 < len(moved) / len(keys) < 0.45


def test_hash_ring_add_steals_only_adjacent_keyspace():
    ring = HashRing(vnodes=64)
    for n in ("a", "b", "c"):
        ring.add(n)
    keys = [f"s{i}" for i in range(3000)]
    before = {k: ring.route(k) for k in keys}
    ring.add("d")
    moved = [k for k in keys if ring.route(k) != before[k]]
    assert moved and all(ring.route(k) == "d" for k in moved)


def test_hash_ring_spreads_keys():
    ring = HashRing(vnodes=64)
    for n in ("a", "b", "c", "d"):
        ring.add(n)
    counts: dict = {}
    for i in range(4000):
        counts[ring.route(f"x{i}")] = counts.get(ring.route(f"x{i}"), 0) + 1
    assert set(counts) == {"a", "b", "c", "d"}
    assert min(counts.values()) > 200       # no starved cell


# --------------------------------------------------------- token bucket
def test_token_bucket_refills_on_injected_clock():
    t = [0.0]
    tb = TokenBucket(rate=100.0, capacity=100.0, clock=lambda: t[0])
    assert tb.try_acquire(100) and not tb.try_acquire(1)
    t[0] = 0.25
    assert tb.try_acquire(25) and not tb.try_acquire(1)
    t[0] = 10.0                              # refill clamps at capacity
    assert tb.try_acquire(100) and not tb.try_acquire(1)


# ------------------------------------------------- bit-identity oracle
def test_fleet_bit_identity_oracle(fleet_env):
    """For any routing outcome, fleet predictions == the single server's,
    over mixed request sizes spanning both buckets and coalesced waves."""
    _, _, _, fleet, single, xt = fleet_env
    rng = np.random.default_rng(0)
    rids = {}
    for i in range(16):
        chunk = xt[rng.integers(0, len(xt), size=int(rng.integers(1, 90)))]
        rids[fleet.submit(chunk, key=f"oracle-{i}")] = chunk
    out = fleet.drain()
    assert set(out) == set(rids)
    for rid, chunk in rids.items():
        np.testing.assert_array_equal(out[rid], single.serve(chunk))
    # traffic actually spread: more than one cell served rows
    served = [c for c in fleet.cells.values()
              if c.server.stats()["rows"] > 0]
    assert len(served) > 1


def test_fleet_serve_parties_through_front_door():
    """Party-block requests ride the same admission path: aligned on hashed
    IDs, admitted as binned rows, bit-identical to the direct server."""
    x, y = make_classification(260, 9, 2, seed=10)
    blocks, _, _ = make_party_views(x, y, 3, overlap=0.85, seed=10)
    fed = Federation(parties=3, n_bins=16)
    part = fed.ingest(blocks)
    model = fed.fit(ForestParams(n_estimators=3, max_depth=4, n_bins=16,
                                 seed=1))
    cfg = ServeConfig(buckets=(64,))
    fleet = fed.serve_fleet(model, cfg, n_cells=2)
    single = fed.serve(model, cfg)
    xt, _ = make_classification(30, 9, 2, seed=77)
    qids = np.array([f"q{i}" for i in range(len(xt))])
    from repro.core.partyblock import PartyBlock
    req = []
    for i, name in enumerate(part.party_names):
        gid = part.feat_gid[i][part.feat_gid[i] >= 0]
        req.append(PartyBlock(name=name, x=xt[:, gid], ids=qids))
    rid, ids = fleet.submit_parties(req, key="pb-1")
    want_ids, want = single.serve_parties(req)
    np.testing.assert_array_equal(ids, want_ids)
    np.testing.assert_array_equal(fleet.drain()[rid], want)


# --------------------------------------------- cell kill / zero loss
def test_kill_cell_mid_traffic_loses_nothing(fleet_env):
    """Killing 1 of 4 cells with requests pending: its keyspace
    redistributes and every accepted request resolves bit-identically —
    re-routed, never dropped."""
    _, _, _, fleet, single, xt = fleet_env
    rng = np.random.default_rng(1)
    before = fleet.accepted_count
    rids = {}
    for i in range(20):
        chunk = xt[rng.integers(0, len(xt), size=int(rng.integers(1, 60)))]
        rids[fleet.submit(chunk, key=f"kill-{i}")] = chunk
    accepted = fleet.accepted_count - before
    assert accepted == len(rids)
    # kill the cell holding the most pending requests — the worst case
    victim = max(fleet.cells_up(),
                 key=lambda n: fleet.cells[n].queue.pending_requests())
    pending = fleet.cells[victim].queue.pending_requests()
    assert pending > 0
    moved = fleet.kill_cell(victim)
    assert moved == pending
    out = fleet.drain()
    dead = {d.rid for d in fleet.dead_letters}
    assert set(out) | dead == set(rids) and not dead
    for rid, chunk in rids.items():
        np.testing.assert_array_equal(out[rid], single.serve(chunk))
    m = fleet.metrics()
    assert m.cells_down >= 1 and m.rerouted >= moved
    assert alerts(m, AlertThresholds(cells_down=1))
    # routing no longer targets the dead cell
    for i in range(50):
        assert fleet.ring.route(f"post-{i}") != victim


def test_kill_last_cell_refused():
    x, y = make_classification(200, 8, 2, seed=3)
    ff = fit_federated_forest(x, y, 2, ForestParams(
        n_estimators=2, max_depth=4, n_bins=16, seed=0))
    fleet = ServingFleet([ForestServer.from_forest(ff, buckets=(32,))])
    with pytest.raises(RuntimeError, match="last cell"):
        fleet.kill_cell("cell0")


def test_health_fail_drains_cell(fleet_env):
    """A cell whose substrate health check reports a dead party it cannot
    serve around (allow_degraded off) is drained via the kill path."""
    _, model, _, _, single, xt = fleet_env
    # cells on their OWN substrates (serve_fleet shares the session's one;
    # per-cell health needs per-cell substrates — the distributed case)
    servers = [ForestServer.from_forest(model, buckets=(64,)).warmup()
               for _ in range(2)]
    fleet = ServingFleet({"a": servers[0], "b": servers[1]})
    rid = fleet.submit(xt[:40], key="health-1")
    victim = fleet.cells[fleet.ring.route("health-1")]
    # fault seam: this cell's substrate now reports party 0 dead
    victim.server.substrate.health = lambda: {0: None, 1: 0.01, 2: 0.01}
    health = fleet.check_health()
    assert health[victim.name] is False
    assert victim.state == "down" and victim.name not in fleet.ring
    out = fleet.drain()
    np.testing.assert_array_equal(out[rid], single.serve(xt[:40]))


# ----------------------------------------------------- admission control
def test_rate_limit_sheds_typed(fleet_env):
    fed, model, cfg, fleet, _, xt = fleet_env
    t = [0.0]
    servers = [c.server for c in fleet.cells.values()][:2]
    limited = ServingFleet({f"r{i}": s for i, s in enumerate(servers)},
                           rate_limit_rows_per_s=100.0, rate_burst=100.0,
                           clock=lambda: t[0])
    limited.submit(xt[:100], key="a")
    with pytest.raises(FleetOverloadError) as ei:
        limited.submit(xt[:5], key="b")
    assert ei.value.reason == "rate_limit"
    assert limited.shed_counts["rate_limit"] == 1
    t[0] = 1.0                               # bucket refills with the clock
    limited.submit(xt[:5], key="b")
    assert len(limited.drain()) == 2


def test_queue_depth_sheds_typed_per_cell(fleet_env):
    fed, model, cfg, fleet, _, xt = fleet_env
    servers = [c.server for c in fleet.cells.values()][:2]
    bulk = ServingFleet({f"q{i}": s for i, s in enumerate(servers)},
                        max_queue_rows=64)
    shed = 0
    for i in range(20):
        try:
            bulk.submit(xt[:60], key=f"jam-{i}")
        except FleetOverloadError as err:
            assert err.reason == "queue_depth"
            assert err.cell in bulk.cells    # names the full bulkhead
            shed += 1
    assert shed > 0 and bulk.shed_counts["queue_depth"] == shed
    assert bulk.metrics().shed_total == shed
    bulk.drain()                             # admitted requests still serve


# ------------------------------------------------ poison + dead letters
def test_poison_request_dead_letters_others_survive(fleet_env):
    """A request that fails binning poisons its wave; the fleet quarantines
    it, retries solo, and dead-letters it — innocent requests coalesced into
    the same wave still serve, bit-identically."""
    _, _, _, fleet, single, xt = fleet_env
    good = {}
    for i in range(6):
        chunk = xt[i * 8:(i + 1) * 8]
        good[fleet.submit(chunk, key=f"good-{i}")] = chunk
    bad_rows = np.zeros((5, xt.shape[1] + 3))        # wrong feature count:
    bad = fleet.submit(bad_rows, key="poison-1")     # bins fail in the pump
    out = fleet.drain()
    assert set(out) == set(good)
    for rid, chunk in good.items():
        np.testing.assert_array_equal(out[rid], single.serve(chunk))
    letters = [d for d in fleet.dead_letters if d.rid == bad]
    assert len(letters) == 1
    d = letters[0]
    assert d.key == "poison-1" and d.x.shape == bad_rows.shape
    assert isinstance(d.error, PoisonedWaveError)
    assert d.poisons == fleet.max_poison_retries + 1
    assert fleet.metrics().dead_letters >= 1


# ------------------------------------- RequestQueue multi-producer safety
def test_request_queue_concurrent_submit_is_atomic(fleet_env):
    """Satellite regression: submits racing from many threads must not
    interleave partially — unique rids, every request enqueued exactly
    once, and a subsequent drain serves each one correctly."""
    _, _, _, _, single, xt = fleet_env
    queue = RequestQueue(single)
    n_threads, per_thread = 8, 25
    rid_lists = [[] for _ in range(n_threads)]
    chunks: dict = {}
    barrier = threading.Barrier(n_threads)

    def producer(t):
        barrier.wait()                       # maximal contention
        for j in range(per_thread):
            chunk = xt[(t * per_thread + j) % 100:][:3 + (j % 5)]
            rid = queue.submit(chunk)
            rid_lists[t].append(rid)
            chunks[rid] = chunk              # dict write: GIL-atomic per key

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    rids = [r for lst in rid_lists for r in lst]
    assert len(rids) == len(set(rids)) == n_threads * per_thread
    assert queue.pending_requests() == len(rids)
    assert queue.pending_rows() == sum(len(c) for c in chunks.values())
    out = queue.drain()
    assert set(out) == set(rids)
    for rid in rids:
        np.testing.assert_array_equal(out[rid], single.serve(chunks[rid]))


# ------------------------------------------- per-cell bucket autotune
def test_fleet_autotune_per_cell_no_recompile_of_survivors(fleet_env):
    """autotune_buckets=True on a cached fleet re-derives buckets PER CELL
    from that cell's own traffic; surviving buckets keep their executables
    (per-cell compile counter grows only by genuinely new buckets)."""
    fed, model, _, _, single, xt = fleet_env
    cfg = ServeConfig(buckets=(32, 128), autotune_buckets=True)
    fleet = fed.serve_fleet(model, cfg, n_cells=2).warmup()
    # skewed per-cell traffic: tiny requests to one cell, big to the other;
    # drain per request so each cell's wave_stats reflect ITS row sizes
    # (one drain over a full queue would coalesce the skew away)
    names = fleet.cells_up()
    small_cell, big_cell = names[0], names[1]
    seen = {small_cell: 0, big_cell: 0}
    for i in range(200):
        key = f"t-{i}"
        target = fleet.ring.route(key)
        size = 4 if target == small_cell else 120
        fleet.submit(xt[:size], key=key)
        fleet.drain()
        seen[target] += 1
        if min(seen.values()) >= 12:         # both cells past min_observations
            break
    pre = {n: (tuple(c.server.buckets), c.server.compile_count)
           for n, c in fleet.cells.items()}
    retuned = fed.serve_fleet(model, cfg, n_cells=2)
    assert retuned is fleet                  # cache hit, tuned in place
    for n, cell in fleet.cells.items():
        warm_buckets, warm_compiles = pre[n]
        cell.server.warmup()                 # compile any new buckets now
        survivors = set(warm_buckets) & set(cell.server.buckets)
        new = set(cell.server.buckets) - set(warm_buckets)
        # compile-once per epoch: only genuinely new buckets compile
        assert cell.server.compile_count == warm_compiles + len(new), \
            f"cell {n} recompiled surviving buckets {survivors}"
    # the two cells saw different traffic -> tuned independently
    tuned = {n: tuple(c.server.buckets) for n, c in fleet.cells.items()}
    assert tuned[small_cell] != tuned[big_cell]
    # and the retuned fleet still serves bit-identically
    rid = fleet.submit(xt[:50], key="after-tune")
    np.testing.assert_array_equal(fleet.drain()[rid], single.serve(xt[:50]))


# ------------------------------------------------- zero stats record
def test_fresh_server_stats_zero_record(fleet_env):
    """ModelServer.stats() on a never-served engine is a well-formed zero
    record, so fleet aggregation needs no special casing."""
    fed, model, cfg, _, _, _ = fleet_env
    fleet = fed.serve_fleet(model, cfg, n_cells=2, max_queue_rows=1024)
    for cell in fleet.cells.values():
        s = cell.server.stats()
        assert s["waves"] == s["rows"] == 0
        assert s["p50_ms"] == s["p95_ms"] == s["p99_ms"] == 0.0
        assert s["rows_per_s"] == 0.0 and s["comm_bytes_total"] == 0
    m = fleet.metrics()                      # just-spawned fleet aggregates
    assert m.rows == 0 and m.rows_per_s == 0.0 and m.p99_ms == 0.0
    assert m.cells_up == 2 and not alerts(m, AlertThresholds(cells_down=1))


def test_busy_seconds_unions_overlaps():
    assert busy_seconds([(0.0, 1.0), (0.5, 2.0), (3.0, 4.0)]) == 3.0
    assert busy_seconds([]) == 0.0


# ------------------------------------------- "auto" build-knob resolution
def test_auto_build_params_bit_identical():
    """frontier_cap='auto' / trees_per_batch='auto' resolve at fit time and
    build the same forest bit-for-bit as explicit settings (the knobs are
    perf-only); explicit ints pass through untouched."""
    import jax
    x, y = make_classification(300, 12, 2, seed=0)
    base = dict(n_estimators=4, max_depth=6, n_bins=16, seed=1)
    p_auto = ForestParams(frontier_cap="auto", trees_per_batch="auto",
                          **base)
    assert p_auto.needs_resolution
    ff_auto = fit_federated_forest(x, y, 3, p_auto)
    assert not ff_auto.params.needs_resolution
    assert isinstance(ff_auto.params.frontier_cap, int)
    ff_dense = fit_federated_forest(x, y, 3, ForestParams(
        frontier_cap=0, trees_per_batch=1, **base))
    for a, b in zip(jax.tree.leaves(ff_auto.trees_),
                    jax.tree.leaves(ff_dense.trees_)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # override escape hatch: explicit values never touched
    p_expl = ForestParams(frontier_cap=96, trees_per_batch=2, **base)
    assert p_expl.resolved(300) is p_expl
    with pytest.raises(ValueError, match="auto"):
        ForestParams(frontier_cap="adaptive", **base)
    with pytest.raises(ValueError, match="auto"):
        ForestParams(trees_per_batch="max", **base)


def test_auto_params_rejected_by_program_builder():
    from repro.federation import programs
    from repro.federation.substrate import default_substrate
    p = ForestParams(frontier_cap="auto", n_bins=16)
    with pytest.raises(ValueError, match="resolved"):
        programs.forest_fit_program(default_substrate(None), p)
