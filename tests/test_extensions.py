"""Tests for the beyond-paper extensions: federated boosting, break-point
recovery (the paper's §4.1 claim), feature importance, hist subtraction."""
import numpy as np
import pytest

from repro.core import ForestParams, FederatedForest, fit_federated_forest
from repro.core.boosting import BoostParams, FederatedBoosting
from repro.core.party import make_vertical_partition
from repro.data import make_classification, make_regression
from repro.data.metrics import accuracy, rmse


def test_boosting_regression_beats_mean():
    x, y = make_regression(600, 16, seed=1)
    part = make_vertical_partition(x[:450], 3, 32)
    fb = FederatedBoosting(BoostParams(task="regression", n_rounds=25,
                                       max_depth=4)).fit(part, y[:450])
    pred = fb.predict(x[450:])
    base = rmse(y[450:], np.full(150, y[:450].mean()))
    assert rmse(y[450:], pred) < 0.6 * base


def test_boosting_binary_classification():
    x, y = make_classification(700, 20, 2, seed=2)
    part = make_vertical_partition(x[:500], 4, 32)
    fb = FederatedBoosting(BoostParams(task="binary", n_rounds=25,
                                       max_depth=3)).fit(part, y[:500])
    assert accuracy(y[500:], fb.predict(x[500:])) > 0.8


def test_boosting_training_loss_monotone():
    """Each boosting round must not increase training loss (learning-rate
    damped Newton steps on a convex objective)."""
    x, y = make_regression(300, 10, seed=3)
    part = make_vertical_partition(x, 2, 16)
    fb = FederatedBoosting(BoostParams(task="regression", n_rounds=10,
                                       learning_rate=0.3)).fit(part, y)
    losses = []
    f = np.full(len(y), fb.base_)
    import jax.numpy as jnp
    xb = jnp.asarray(part.xb)
    for trees in fb.trees_:
        f = f + fb.params.learning_rate * np.asarray(fb._pred_run(trees, xb)[0])
        losses.append(float(np.mean((f - y) ** 2)))
    assert all(b <= a + 1e-6 for a, b in zip(losses, losses[1:])), losses


def test_breakpoint_recovery_identical_forest(tmp_path):
    """Paper §4.1: a fit interrupted and resumed from checkpoints produces
    the identical model."""
    x, y = make_classification(400, 12, 2, seed=5)
    p = ForestParams(n_estimators=6, max_depth=4, n_bins=16, seed=9)
    part = make_vertical_partition(x, 3, p.n_bins)

    straight = FederatedForest(p).fit(part, y)

    # simulate a crash: run only the first chunk, then "restart"
    interrupted = FederatedForest(p)
    try:
        orig = interrupted.fit_resumable
        calls = {"n": 0}
        # run to completion the normal way, but verify resume path by doing
        # two chunks manually
    finally:
        pass
    a = FederatedForest(p).fit_resumable(part, y, str(tmp_path / "a"),
                                         trees_per_chunk=2)
    # second fit resumes from the finished checkpoint (start == n_estimators)
    b = FederatedForest(p).fit_resumable(part, y, str(tmp_path / "a"),
                                         trees_per_chunk=2)
    np.testing.assert_array_equal(straight.predict(x), a.predict(x))
    np.testing.assert_array_equal(a.predict(x), b.predict(x))


def test_partial_checkpoint_resume(tmp_path):
    """Kill after one chunk; a fresh fit resumes and matches the straight run."""
    from repro import ckpt
    x, y = make_classification(300, 10, 2, seed=7)
    p = ForestParams(n_estimators=4, max_depth=4, n_bins=16, seed=3)
    part = make_vertical_partition(x, 2, p.n_bins)
    d = str(tmp_path / "ck")

    # straight run for reference
    ref = FederatedForest(p).fit(part, y)
    # full resumable run, then delete the final checkpoint to simulate a
    # crash after the first chunk
    FederatedForest(p).fit_resumable(part, y, d, trees_per_chunk=2)
    import shutil
    shutil.rmtree(f"{d}/step_{4:08d}")
    assert ckpt.latest_step(d) == 2
    resumed = FederatedForest(p).fit_resumable(part, y, d, trees_per_chunk=2)
    np.testing.assert_array_equal(ref.predict(x), resumed.predict(x))


def test_feature_importance_views():
    x, y = make_classification(400, 16, 2, n_informative=4, seed=11)
    p = ForestParams(n_estimators=5, max_depth=5, n_bins=16, seed=2)
    ff = fit_federated_forest(x, y, 4, p)
    imp = ff.feature_importance()
    assert imp.shape == (16,)
    assert imp.sum() == pytest.approx(1.0)
    # party views partition the master view
    party_sum = sum(ff.feature_importance(f"party:{i}") *  # noqa: W504
                    ff.feature_importance(f"party:{i}").sum() /
                    max(ff.feature_importance(f"party:{i}").sum(), 1e-12)
                    for i in range(4))
    # each split is owned by exactly one party: union of party split counts
    # == master split counts (up to the shared normalization)
    trees = ff.trees_
    import jax
    t = jax.tree.map(np.asarray, trees)
    owned = sum(int(t.has_split[i].sum()) for i in range(4))
    assert owned == int((t.owner[0] >= 0).sum())


def test_hist_subtraction_lossless_classification():
    x, y = make_classification(500, 18, 2, seed=13)
    pa = ForestParams(n_estimators=4, max_depth=6, n_bins=16, seed=1)
    pb = ForestParams(n_estimators=4, max_depth=6, n_bins=16, seed=1,
                      hist_subtraction=True)
    a = fit_federated_forest(x, y, 3, pa).predict(x)
    b = fit_federated_forest(x, y, 3, pb).predict(x)
    np.testing.assert_array_equal(a, b)
