"""Hypothesis property tests on the system's invariants.

Invariants under test:
  P1  (paper Prop. 1) one-round intersection prediction == classical routed
      prediction, for arbitrary data/partitions/params.
  P2  losslessness: FF(M) == FF(1), arbitrary M and contiguous partitions.
  P3  leaf partition: in the complete tree, every test sample lands in exactly
      one leaf per tree (S^l ∩ S^g = ∅ and ∪ S^l = all).
  P4  membership monotonicity: a party's candidate leaf set is always a
      superset of the true assignment (w* ⊆ W_i in the paper's proof).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ForestParams, fit_federated_forest, prediction, protocol
from repro.data import make_classification

SETTINGS = dict(max_examples=15, deadline=None)


@st.composite
def forest_case(draw):
    seed = draw(st.integers(0, 10_000))
    n = draw(st.integers(60, 220))
    f = draw(st.integers(3, 18))
    m = draw(st.integers(2, min(6, f)))
    depth = draw(st.integers(2, 5))
    n_bins = draw(st.sampled_from([4, 8, 16]))
    n_estimators = draw(st.integers(1, 4))
    n_classes = draw(st.sampled_from([2, 3]))
    x, y = make_classification(n, f, n_classes, seed=seed)
    p = ForestParams(n_classes=n_classes, n_estimators=n_estimators,
                     max_depth=depth, n_bins=n_bins, seed=seed % 97)
    return x, y, m, p


@given(forest_case())
@settings(**SETTINGS)
def test_p1_oneround_equals_classical(case):
    x, y, m, p = case
    ff = fit_federated_forest(x, y, m, p)
    np.testing.assert_array_equal(ff.predict(x), ff.predict_classical(x))


@given(forest_case())
@settings(**SETTINGS)
def test_p2_lossless_vs_centralized(case):
    x, y, m, p = case
    central = fit_federated_forest(x, y, 1, p)
    fed = fit_federated_forest(x, y, m, p)
    np.testing.assert_array_equal(central.predict(x), fed.predict(x))


def _leaf_masks(ff, x):
    """(M, T, N, nn) per-party candidate masks + (T, N, nn) intersection."""
    xb = ff.partition_.bin_test(x)

    def per_party(trees, xbp):
        def one(t):
            return prediction.tree_leaf_membership(t, xbp, ff.params)
        return jax.lax.map(one, trees)

    mem = protocol.run_simulated(per_party, (ff.trees_, jnp.asarray(xb)))
    return np.asarray(mem), np.asarray(mem.all(0))


@given(forest_case())
@settings(**SETTINGS)
def test_p3_exactly_one_leaf_per_sample(case):
    x, y, m, p = case
    ff = fit_federated_forest(x, y, m, p)
    _, inter = _leaf_masks(ff, x)
    assert (inter.sum(-1) == 1).all(), "complete-tree leaves must partition samples"


@given(forest_case())
@settings(**SETTINGS)
def test_p4_party_masks_superset_of_truth(case):
    x, y, m, p = case
    ff = fit_federated_forest(x, y, m, p)
    mem, inter = _leaf_masks(ff, x)
    for i in range(m):
        assert (mem[i] >= inter).all(), "w* must be a subset of every W_i"
